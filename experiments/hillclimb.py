"""Plan-space hillclimbs: hypothesis -> change -> measure, per dataset cell.

The measurement backend of the plan autotuner
(``repro.engine.autotune``): each cell is a synthetic tensor x a
``PlanSpace``; the tuner's analytic+exact stages pick a starting spec and
the measured greedy hill-climb walks single-knob neighbors, timing the
real jitted ``all_modes`` dispatch. Deterministic under the cell's seed.

Run:  PYTHONPATH=src python experiments/hillclimb.py
Writes experiments/hillclimb/<cell>.json; benchmarks/fig10 reads the
chosen knobs back when recording autotuned-plan timings.

Env knobs (CI smoke uses tiny values): HILL_CELLS, HILL_NNZ, HILL_RANK,
HILL_ITERS, HILL_SEED.
"""
import dataclasses
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
import repro.engine as engine                              # noqa: E402
from repro.core import datasets                            # noqa: E402
from repro.core.plancache import PlanCache                 # noqa: E402
from repro.engine import PlanSpace, PlanSpec, make_engine  # noqa: E402
from repro.engine.autotune import autotune                 # noqa: E402

OUT = os.path.join(os.path.dirname(__file__), "hillclimb")

NNZ = int(os.environ.get("HILL_NNZ", 50_000))
RANK = int(os.environ.get("HILL_RANK", 16))
ITERS = int(os.environ.get("HILL_ITERS", 3))
SEED = int(os.environ.get("HILL_SEED", 0))

# (cell name, dims, zipf skew) — the skew sweep is the hypothesis axis:
# dedup + compact should win as skew grows, rect should only ever win flat.
CELLS = [
    ("zipf_skew_low", (4000, 3000, 2000), 1.1),
    ("zipf_skew_mid", (4000, 3000, 2000), 1.5),
    ("zipf_skew_high", (4000, 3000, 2000), 2.0),
]


def plan_space() -> PlanSpace:
    return PlanSpace(
        backend=("pallas_fused",),
        schedule=("compact", "rect"),
        block_p=(64, 128, 256),
        dedup=(True, False),
        base=PlanSpec(backend="pallas_fused"),
    )


def measure_spec(spec: PlanSpec, coo, factors, iters: int = ITERS,
                 cache: PlanCache | None = None) -> float:
    """Median wall time of one jitted ``all_modes`` sweep under ``spec``
    (compile excluded via warmup; plans served through ``cache``)."""
    state = make_engine(coo, spec, cache=cache)
    outs, state = engine.all_modes(state, factors)  # warmup: trace+compile
    jax_block = getattr(outs[0], "block_until_ready", None)
    times = []
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        outs, state = engine.all_modes(state, factors)
        if jax_block is not None:
            outs[0].block_until_ready()
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def run_cell(name: str, dims, zipf_a: float, seed: int = SEED) -> dict:
    t = datasets.zipf_tensor(dims, NNZ, a=zipf_a, seed=seed)
    coo = (t.indices, t.values, t.dims)
    rng = np.random.default_rng(seed)
    factors = tuple(rng.standard_normal((d, RANK)).astype(np.float32)
                    for d in t.dims)
    cache = PlanCache()
    result = autotune(
        t.indices, t.values, t.dims, plan_space(), seed=seed, cache=cache,
        measure=lambda spec: measure_spec(spec, coo, factors, cache=cache))
    return {
        "cell": name,
        "dims": list(dims),
        "nnz": t.nnz,
        "zipf_a": zipf_a,
        "seed": seed,
        "best": dataclasses.asdict(result.best),
        "default": dataclasses.asdict(result.default),
        "modeled": {repr(s): c for s, c in result.modeled.items()},
        "measured_s": {repr(s): v for s, v in result.measured.items()},
        "trace": [{**step, "spec": dataclasses.asdict(step["spec"])}
                  for step in result.trace],
        "plan_cache": cache.stats(),
        "ok": True,
    }


def main():
    os.makedirs(OUT, exist_ok=True)
    only = os.environ.get("HILL_CELLS")
    for name, dims, zipf_a in CELLS:
        if only and name not in only.split(","):
            continue
        path = os.path.join(OUT, f"{name}.json")
        if os.path.exists(path):
            print("cached", path)
            continue
        try:
            rec = run_cell(name, dims, zipf_a)
            best = rec["best"]
            print(f"OK {name}: best P={best['block_p']} "
                  f"schedule={best['schedule']} dedup={best['dedup']} "
                  f"({len(rec['trace']) - 1} hill-climb moves)")
        except Exception as e:
            import traceback
            rec = {"cell": name, "ok": False, "error": str(e),
                   "trace_py": traceback.format_exc()}
            print("FAIL", name, e)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
