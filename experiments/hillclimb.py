"""§Perf Phase-2 hillclimbs: three cells, hypothesis -> change -> measure.

Run AFTER the baseline sweep:  PYTHONPATH=src python experiments/hillclimb.py
Writes experiments/hillclimb/<cell>__<opt>.json; report renders the log.
"""
import dataclasses
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.launch.dryrun import lower_cell_with_variants  # noqa: E402
from repro.configs import get_config                       # noqa: E402

OUT = os.path.join(os.path.dirname(__file__), "hillclimb")
os.makedirs(OUT, exist_ok=True)

EXPERIMENTS = [
    # (arch, shape, tag, cfg-transform, cast_once)
    ("tinyllama-1.1b", "train_4k", "cast_once", None, True),
    ("tinyllama-1.1b", "train_4k", "no_sp",
     lambda c: dataclasses.replace(c, seq_shard_carry=False), False),
    ("tinyllama-1.1b", "train_4k", "no_sp_cast",
     lambda c: dataclasses.replace(c, seq_shard_carry=False), True),
    ("command-r-plus-104b", "train_4k", "cast_once", None, True),
    ("qwen2.5-3b", "decode_32k", "kv_quant",
     lambda c: dataclasses.replace(c, kv_quant=True), False),
]


def main():
    for arch, shape, tag, tf, cast in EXPERIMENTS:
        path = os.path.join(OUT, f"{arch}__{shape}__{tag}.json")
        if os.path.exists(path):
            print("cached", path)
            continue
        cfg = get_config(arch)
        if tf is not None:
            cfg = tf(cfg)
        try:
            rec = lower_cell_with_variants(arch, shape, cfg=cfg,
                                           cast_once=cast)
            rec["opt_tag"] = tag
            rec["ok"] = True
            print(f"OK {arch} {shape} {tag}: peak "
                  f"{rec['memory']['peak_per_device_gb']:.2f} GB "
                  f"coll {rec['collectives_per_device']['total']/1e9:.2f} GB")
        except Exception as e:
            import traceback
            rec = {"ok": False, "error": str(e),
                   "trace": traceback.format_exc()}
            print("FAIL", arch, shape, tag, e)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()


EXPERIMENTS_ROUND2 = [
    # inference: SP carries cost a gather/layer but save nothing (no bwd)
    ("recurrentgemma-9b", "prefill_32k", "no_sp_infer",
     lambda c: dataclasses.replace(c, seq_shard_carry=False), False),
    ("command-r-plus-104b", "prefill_32k", "no_sp_infer",
     lambda c: dataclasses.replace(c, seq_shard_carry=False), False),
    # int8 KV for the two decode cells closest to the HBM limit
    ("command-r-plus-104b", "decode_32k", "kv_quant",
     lambda c: dataclasses.replace(c, kv_quant=True), False),
    ("qwen3-moe-235b-a22b", "decode_32k", "kv_quant",
     lambda c: dataclasses.replace(c, kv_quant=True), False),
]


def round2():
    global EXPERIMENTS
    EXPERIMENTS = EXPERIMENTS_ROUND2
    main()
