"""CPD via Alternating Least Squares on top of the spMTTKRP engine.

For each mode d (Eq. 1 of the paper):
    M_d   = X_(d) * KRP(Y_w, w != d)          <- the paper's kernel
    V_d   = hadamard_{w != d} (Y_w^T Y_w)      (R x R)
    Y_d   = M_d @ pinv(V_d); column-normalize -> lambda

Fit is computed with the standard sparse-CPD identity:
    ||X - X_hat||^2 = ||X||^2 - 2<X, X_hat> + ||X_hat||^2
    <X, X_hat>      = sum_r lambda_r * sum_i M_last[i, r] * Y_last[i, r]
    ||X_hat||^2     = lambda^T (hadamard_w Y_w^T Y_w) lambda
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .flycoo import FlycooTensor
from .mttkrp import MTTKRPExecutor, mttkrp_ref


def init_factors(key, dims: Sequence[int], rank: int) -> list[jax.Array]:
    keys = jax.random.split(key, len(dims))
    return [jax.random.uniform(k, (d, rank), jnp.float32) for k, d in
            zip(keys, dims)]


def gram(f: jax.Array) -> jax.Array:
    return f.T @ f


@jax.jit
def _als_update(mttkrp_out, grams_other, eps=1e-8):
    """Y_d = M_d @ pinv(hadamard of other grams); normalize columns."""
    v = grams_other[0]
    for g in grams_other[1:]:
        v = v * g
    # Solve M @ pinv(V): V is PSD (R x R). Relative ridge keeps overcomplete
    # ALS (rank > true rank) stable when V becomes singular.
    r = v.shape[0]
    ridge = eps + 1e-6 * jnp.trace(v) / r
    v = v + ridge * jnp.eye(r, dtype=v.dtype)
    y = jnp.linalg.solve(v.T, mttkrp_out.T).T
    lam = jnp.linalg.norm(y, axis=0)
    lam = jnp.where(lam < eps, 1.0, lam)
    return y / lam, lam


@dataclasses.dataclass
class CPDResult:
    factors: list[jax.Array]
    lam: jax.Array
    fits: list[float]


def cp_als(
    tensor: FlycooTensor,
    rank: int,
    iters: int = 10,
    key=None,
    backend: str = "xla",
    interpret: bool = False,
    track_fit: bool = True,
) -> CPDResult:
    """Run CPD-ALS for ``iters`` sweeps over all modes (paper Alg. 5 outer)."""
    if key is None:
        key = jax.random.PRNGKey(0)
    n = tensor.nmodes
    factors = init_factors(key, tensor.dims, rank)
    lam = jnp.ones((rank,), jnp.float32)
    exe = MTTKRPExecutor(tensor, backend=backend, interpret=interpret)
    norm_x_sq = float(np.sum(tensor.values.astype(np.float64) ** 2))

    fits = []
    for _ in range(iters):
        m_last = None
        for d in range(n):
            m = exe.step(factors)  # mode-d MTTKRP + dynamic remap
            grams_other = [gram(factors[w]) for w in range(n) if w != d]
            y, lam = _als_update(m, tuple(grams_other))
            factors[d] = y
            m_last = m
        if track_fit:
            fits.append(_fit(norm_x_sq, m_last, factors, lam))
    return CPDResult(factors=factors, lam=lam, fits=fits)


def _fit(norm_x_sq: float, m_last, factors, lam) -> float:
    n = len(factors)
    inner = jnp.sum(m_last * (factors[n - 1] * lam[None, :]))
    g = gram(factors[0])
    for f in factors[1:]:
        g = g * gram(f)
    norm_est_sq = lam @ g @ lam
    resid_sq = jnp.maximum(norm_x_sq - 2 * inner + norm_est_sq, 0.0)
    return float(1.0 - jnp.sqrt(resid_sq) / np.sqrt(norm_x_sq))


def cp_als_reference(indices, values, dims, rank, iters=10, key=None):
    """Oracle ALS using plain COO mttkrp_ref (no FLYCOO) for tests."""
    if key is None:
        key = jax.random.PRNGKey(0)
    n = len(dims)
    factors = init_factors(key, dims, rank)
    lam = jnp.ones((rank,), jnp.float32)
    norm_x_sq = float(np.sum(np.asarray(values, np.float64) ** 2))
    indices = jnp.asarray(indices)
    values = jnp.asarray(values)
    fits = []
    for _ in range(iters):
        m_last = None
        for d in range(n):
            m = mttkrp_ref(indices, values, factors, d, dims[d])
            grams_other = [gram(factors[w]) for w in range(n) if w != d]
            y, lam = _als_update(m, tuple(grams_other))
            factors[d] = y
            m_last = m
        fits.append(_fit(norm_x_sq, m_last, factors, lam))
    return CPDResult(factors=factors, lam=lam, fits=fits)
