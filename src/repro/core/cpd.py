"""CPD via Alternating Least Squares on top of the functional spMTTKRP engine.

For each mode d (Eq. 1 of the paper):
    M_d   = X_(d) * KRP(Y_w, w != d)          <- the paper's kernel
    V_d   = hadamard_{w != d} (Y_w^T Y_w)      (R x R)
    Y_d   = M_d @ pinv(V_d); column-normalize -> lambda

A full ALS sweep is ONE traced program: ``engine.all_modes`` runs the mode
rotation as a jitted ``lax.scan`` and the Gauss-Seidel factor update rides
inside it as the scan's ``fold`` hook — no per-mode host dispatch, and the
layout rotation (the paper's T_in/T_out swap) never leaves the device.

Fit is computed with the standard sparse-CPD identity:
    ||X - X_hat||^2 = ||X||^2 - 2<X, X_hat> + ||X_hat||^2
    <X, X_hat>      = sum_r lambda_r * sum_i M_last[i, r] * Y_last[i, r]
    ||X_hat||^2     = lambda^T (hadamard_w Y_w^T Y_w) lambda
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.engine import ExecutionConfig
from repro.obs.metrics import gauge as _obs_gauge
from repro.obs.trace import span
from repro.resilience import chaos as _chaos
from repro.resilience import guard as _guard
from repro.resilience.ladder import (classify, next_backend,
                                     record_degradation, resolve_policy)
from repro.resilience.snapshot import as_store, fingerprint

from .flycoo import FlycooTensor
from .mttkrp import mttkrp_ref


def init_factors(key, dims: Sequence[int], rank: int) -> list[jax.Array]:
    keys = jax.random.split(key, len(dims))
    return [jax.random.uniform(k, (d, rank), jnp.float32) for k, d in
            zip(keys, dims)]


def gram(f: jax.Array) -> jax.Array:
    return f.T @ f


@jax.jit
def _als_update(mttkrp_out, grams_other, eps=1e-8):
    """Y_d = M_d @ pinv(hadamard of other grams); normalize columns."""
    v = grams_other[0]
    for g in grams_other[1:]:
        v = v * g
    # Solve M @ pinv(V): V is PSD (R x R). Relative ridge keeps overcomplete
    # ALS (rank > true rank) stable when V becomes singular.
    r = v.shape[0]
    ridge = eps + 1e-6 * jnp.trace(v) / r
    v = v + ridge * jnp.eye(r, dtype=v.dtype)
    y = jnp.linalg.solve(v.T, mttkrp_out.T).T
    lam = jnp.linalg.norm(y, axis=0)
    lam = jnp.where(lam < eps, 1.0, lam)
    return y / lam, lam


def _als_fold(d: int, m_d, factors, lam):
    """Gauss-Seidel update for mode ``d``, traced inside the engine scan."""
    n = len(factors)
    grams_other = tuple(gram(factors[w]) for w in range(n) if w != d)
    y, lam = _als_update(m_d, grams_other)
    return tuple(factors[:d]) + (y,) + tuple(factors[d + 1:]), lam


#: Ridge strength the recovery fold replays a rolled-back sweep under —
#: strong enough to dominate a near-singular gram product that NaN'd the
#: plain solve, small enough to leave a well-conditioned sweep's fixed
#: point essentially unchanged.
RECOVERY_EPS = 1e-3


def _als_fold_recovery(d: int, m_d, factors, lam):
    """The Gauss-Seidel update under the stronger :data:`RECOVERY_EPS`
    ridge — used to replay a sweep after a NaN/Inf burst (see
    ``resilience.guard``). A separate module-level callable because the
    fold's identity is part of the engine's jit cache key."""
    n = len(factors)
    grams_other = tuple(gram(factors[w]) for w in range(n) if w != d)
    y, lam = _als_update(m_d, grams_other, RECOVERY_EPS)
    return tuple(factors[:d]) + (y,) + tuple(factors[d + 1:]), lam


@dataclasses.dataclass
class CPDResult:
    factors: list[jax.Array]
    lam: jax.Array
    fits: list[float]


def cp_als(
    tensor: FlycooTensor,
    rank: int,
    iters: int = 10,
    key=None,
    config: ExecutionConfig | None = None,
    backend: str | None = None,
    interpret: bool | None = None,
    track_fit: bool = True,
    mesh=None,
    dist=None,
    *,
    ladder=None,
    checkpoint=None,
    checkpoint_every: int = 1,
    resume: bool = False,
) -> CPDResult:
    """Run CPD-ALS for ``iters`` sweeps over all modes (paper Alg. 5 outer).

    Execution policy comes from ``config``; ``backend``/``interpret`` are
    legacy conveniences that build one (mutually exclusive with ``config``).

    With ``mesh`` (a ``jax.sharding.Mesh`` or ``repro.sharding.ShardingCtx``)
    the engine state shards over the mesh's data axis and every sweep runs
    as ONE ``engine.dist.dist_all_modes`` program — the same scanned fold,
    distributed. ``tensor``'s partition counts must divide over the mesh
    (build with ``core.distributed.build_sharded_flycoo``); ``dist`` is an
    optional ``engine.DistConfig`` (its ``model_axis`` must stay ``None`` —
    the ALS fold needs the full rank on every device).

    Resilience (see :mod:`repro.resilience`):

    * ``ladder``: ``True`` / a :class:`repro.resilience.LadderPolicy`
      enables the degradation ladder — a compile/lowering failure steps
      the backend down ``pallas_fused -> pallas -> xla -> ref`` and
      rebuilds the engine state (bitwise-identical output, every rung) —
      plus the per-sweep NaN/Inf guard: on a burst the sweep is rolled
      back and replayed under the stronger :data:`RECOVERY_EPS` ridge.
      Every transition lands on the obs registry; nothing degrades
      silently.
    * ``checkpoint``: a directory or :class:`repro.resilience.
      SnapshotStore`; every ``checkpoint_every`` completed sweeps the
      ``(factors, lam, fits)`` state is snapshotted atomically under the
      problem fingerprint. ``resume=True`` restores the newest intact
      snapshot *for the same problem* (tensor bytes + rank + config +
      key) and replays only the remaining sweeps — bitwise-identical
      final factors vs an uninterrupted run, because at a sweep boundary
      the layout has rotated back to its start arrangement and
      ``(factors, lam)`` are the complete dynamic state.

    Distributed resilience (``mesh`` given): snapshots are written in the
    sharded v2 format (per-device factor shards + mesh fingerprint, see
    :mod:`repro.resilience.snapshot`) but the problem fingerprint is
    mesh-independent — a run killed on 4 devices resumes on 2 (or 1)
    bitwise-identically, re-sharding onto the *current* mesh. With a
    ladder, two extra rungs activate: an exchange failure steps
    ``collective_permute -> all_gather`` (bitwise-identical by the
    exchange parity guarantee), and a lost device shrinks the mesh —
    the engine state is re-planned and re-sharded on the survivors and
    the run rolls back to the latest snapshot (or the sweep boundary),
    never silently. Transient dispatch failures retry with the same
    seeded backoff stream uploads use.
    """
    if config is None:
        config = ExecutionConfig(backend=backend or "xla",
                                 interpret=interpret)
    elif backend is not None or interpret is not None:
        raise ValueError("pass either config or backend/interpret, not both")
    policy = resolve_policy(ladder)
    if key is None:
        key = jax.random.PRNGKey(0)
    n = tensor.nmodes
    factors = tuple(init_factors(key, tensor.dims, rank))
    lam = jnp.ones((rank,), jnp.float32)
    mesh_raw = None
    if mesh is not None:
        from repro.sharding import ShardingCtx

        if isinstance(mesh, ShardingCtx):
            mesh_raw = mesh.mesh
            if dist is None:
                # ALS folds inside the sweep, which needs the full rank
                # on every device — never inherit the ctx's tp axis here.
                dist = engine.DistConfig(data_axis=mesh.data_axis)
        else:
            mesh_raw = mesh
    elif dist is not None:
        raise ValueError("dist config given without a mesh")

    def build_state(cfg):
        st = engine.init(tensor, cfg)
        if mesh is not None:
            st = engine.dist.shard_state(st, mesh, dist)
        return st

    state = build_state(config)
    if mesh is None:
        sweep = engine.all_modes
    else:
        sweep = functools.partial(engine.dist.dist_all_modes,
                                  policy=policy)
    norm_x_sq = float(np.sum(tensor.values.astype(np.float64) ** 2))

    store = as_store(checkpoint)
    fits: list = []
    first = 0
    fp = None
    if store is not None:
        fp = fingerprint(tensor.indices, tensor.values, tensor.dims, rank,
                         config=config, key=key,
                         extra="resident" if mesh is None else "dist")
        if resume:
            snap = store.latest(fp)
            if snap is not None:
                factors = tuple(jnp.asarray(f) for f in snap.factors)
                lam = jnp.asarray(snap.lam)
                fits = list(snap.fits)
                first = snap.sweep
    backend_steps = 0
    i = first
    while i < iters:
        cz = _chaos.active()
        if cz is not None:
            cz.maybe_kill(i)
        prev = (factors, lam)
        rewind = None
        # One dispatch per sweep: scan over modes, ALS update in the fold.
        with span("cpd.sweep", sweep=i, streamed=False) as sp:
            fold = _als_fold
            while True:
                try:
                    outs, state, factors, lam = sweep(
                        state, factors, fold=fold, carry=lam)
                except Exception as exc:
                    if policy is None:
                        raise
                    kind = classify(exc)
                    # Compile/lowering failures happen before any factor
                    # update (the sweep is one program): step the backend
                    # down a rung, rebuild the state from the tensor (at a
                    # sweep boundary the layout bitwise-equals a fresh
                    # init), and retry the sweep.
                    if kind == "compile" \
                            and backend_steps < policy.max_backend_steps:
                        nb = next_backend(state.config.backend)
                        if nb is None:
                            raise
                        backend_steps += 1
                        record_degradation("compile", state.config.backend,
                                           nb, site="cpd.backend", sweep=i)
                        state = build_state(dataclasses.replace(
                            state.config, backend=nb))
                        continue
                    # Exchange failure: step collective_permute ->
                    # all_gather (bitwise-identical by the exchange parity
                    # guarantee) without re-sharding — only the traced
                    # program changes.
                    if kind == "exchange" and mesh is not None \
                            and state.dist.exchange == "permute":
                        record_degradation(
                            "exchange", "permute", "all_gather",
                            site="cpd.exchange", sweep=i)
                        dist = dataclasses.replace(state.dist,
                                                   exchange="all_gather")
                        state = state.replace(dist=dist)
                        continue
                    # Device loss: shrink to the largest viable surviving
                    # mesh, re-plan + re-shard there, and roll back to the
                    # latest snapshot (or this sweep's boundary state).
                    if kind == "device_lost" and mesh is not None:
                        lost = getattr(exc, "lost", 1)
                        old_n = int(state.n_dev)
                        new_mesh = engine.dist.surviving_mesh(
                            mesh_raw, lost,
                            [p.kappa for p in tensor.plans],
                            data_axis=(dist.data_axis if dist is not None
                                       else "data"))
                        new_n = int(np.asarray(
                            new_mesh.devices).reshape(-1).size)
                        record_degradation("device_lost", old_n, new_n,
                                           site="cpd.mesh", sweep=i,
                                           lost=lost)
                        mesh = mesh_raw = new_mesh
                        # Restore from the latest snapshot when there is
                        # one (the real-loss path: device buffers are
                        # gone); otherwise the in-memory sweep-boundary
                        # state is already `prev`, untouched by the
                        # failed dispatch.
                        resume_at = i
                        snap = store.latest(fp) if store is not None \
                            else None
                        if snap is not None:
                            factors = tuple(jnp.asarray(f)
                                            for f in snap.factors)
                            lam = jnp.asarray(snap.lam)
                            fits = list(snap.fits)
                            resume_at = snap.sweep
                        state = build_state(state.config)
                        if resume_at == i:
                            prev = (factors, lam)
                            continue
                        rewind = resume_at
                        break
                    raise
                if cz is not None:
                    factors = tuple(cz.mangle_factors(i, factors))
                if policy is not None \
                        and not _guard.all_finite(factors, lam):
                    if fold is _als_fold_recovery:
                        raise FloatingPointError(
                            f"NaN/Inf burst in sweep {i} persisted "
                            "through the ridge-recovery replay")
                    # Roll back and replay under the stronger ridge: the
                    # layout is bitwise back at its start arrangement, so
                    # the replay sees exactly the pre-sweep problem.
                    _guard.record_recovery("nan_rollback", sweep=i,
                                           streamed=False)
                    factors, lam = prev
                    fold = _als_fold_recovery
                    continue
                break
            if rewind is None and track_fit:
                fit = _fit(norm_x_sq, outs[n - 1], factors, lam)
                fits.append(fit)
                sp.set("fit", float(fit))
                _obs_gauge("cpd_fit", "latest ALS fit per tier").set(
                    "resident", float(fit))
        if rewind is not None:
            i = rewind
            continue
        if store is not None and ((i + 1) % checkpoint_every == 0
                                  or i + 1 == iters):
            if mesh is not None:
                store.save(fp, i + 1, list(factors), np.asarray(lam),
                           fits, mesh=mesh_raw, dist=state.dist)
            else:
                store.save(fp, i + 1, [np.asarray(f) for f in factors],
                           np.asarray(lam), fits)
        i += 1
    return CPDResult(factors=list(factors), lam=lam, fits=fits)


def _fit(norm_x_sq: float, m_last, factors, lam) -> float:
    n = len(factors)
    inner = jnp.sum(m_last * (factors[n - 1] * lam[None, :]))
    g = gram(factors[0])
    for f in factors[1:]:
        g = g * gram(f)
    norm_est_sq = lam @ g @ lam
    resid_sq = jnp.maximum(norm_x_sq - 2 * inner + norm_est_sq, 0.0)
    return float(1.0 - jnp.sqrt(resid_sq) / np.sqrt(norm_x_sq))


def cp_als_reference(indices, values, dims, rank, iters=10, key=None):
    """Oracle ALS using plain COO mttkrp_ref (no FLYCOO) for tests."""
    if key is None:
        key = jax.random.PRNGKey(0)
    n = len(dims)
    factors = init_factors(key, dims, rank)
    lam = jnp.ones((rank,), jnp.float32)
    norm_x_sq = float(np.sum(np.asarray(values, np.float64) ** 2))
    indices = jnp.asarray(indices)
    values = jnp.asarray(values)
    fits = []
    for _ in range(iters):
        m_last = None
        for d in range(n):
            m = mttkrp_ref(indices, values, factors, d, dims[d])
            grams_other = [gram(factors[w]) for w in range(n) if w != d]
            y, lam = _als_update(m, tuple(grams_other))
            factors[d] = y
            m_last = m
        fits.append(_fit(norm_x_sq, m_last, factors, lam))
    return CPDResult(factors=factors, lam=lam, fits=fits)
