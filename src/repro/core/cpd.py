"""CPD via Alternating Least Squares on top of the functional spMTTKRP engine.

For each mode d (Eq. 1 of the paper):
    M_d   = X_(d) * KRP(Y_w, w != d)          <- the paper's kernel
    V_d   = hadamard_{w != d} (Y_w^T Y_w)      (R x R)
    Y_d   = M_d @ pinv(V_d); column-normalize -> lambda

A full ALS sweep is ONE traced program: ``engine.all_modes`` runs the mode
rotation as a jitted ``lax.scan`` and the Gauss-Seidel factor update rides
inside it as the scan's ``fold`` hook — no per-mode host dispatch, and the
layout rotation (the paper's T_in/T_out swap) never leaves the device.

Fit is computed with the standard sparse-CPD identity:
    ||X - X_hat||^2 = ||X||^2 - 2<X, X_hat> + ||X_hat||^2
    <X, X_hat>      = sum_r lambda_r * sum_i M_last[i, r] * Y_last[i, r]
    ||X_hat||^2     = lambda^T (hadamard_w Y_w^T Y_w) lambda
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.engine import ExecutionConfig
from repro.obs.metrics import gauge as _obs_gauge
from repro.obs.trace import span
from repro.resilience import chaos as _chaos
from repro.resilience import guard as _guard
from repro.resilience.ladder import (classify, next_backend,
                                     record_degradation, resolve_policy)
from repro.resilience.snapshot import as_store, fingerprint

from .flycoo import FlycooTensor
from .mttkrp import mttkrp_ref


def init_factors(key, dims: Sequence[int], rank: int) -> list[jax.Array]:
    keys = jax.random.split(key, len(dims))
    return [jax.random.uniform(k, (d, rank), jnp.float32) for k, d in
            zip(keys, dims)]


def gram(f: jax.Array) -> jax.Array:
    return f.T @ f


@jax.jit
def _als_update(mttkrp_out, grams_other, eps=1e-8):
    """Y_d = M_d @ pinv(hadamard of other grams); normalize columns."""
    v = grams_other[0]
    for g in grams_other[1:]:
        v = v * g
    # Solve M @ pinv(V): V is PSD (R x R). Relative ridge keeps overcomplete
    # ALS (rank > true rank) stable when V becomes singular.
    r = v.shape[0]
    ridge = eps + 1e-6 * jnp.trace(v) / r
    v = v + ridge * jnp.eye(r, dtype=v.dtype)
    y = jnp.linalg.solve(v.T, mttkrp_out.T).T
    lam = jnp.linalg.norm(y, axis=0)
    lam = jnp.where(lam < eps, 1.0, lam)
    return y / lam, lam


def _als_fold(d: int, m_d, factors, lam):
    """Gauss-Seidel update for mode ``d``, traced inside the engine scan."""
    n = len(factors)
    grams_other = tuple(gram(factors[w]) for w in range(n) if w != d)
    y, lam = _als_update(m_d, grams_other)
    return tuple(factors[:d]) + (y,) + tuple(factors[d + 1:]), lam


#: Ridge strength the recovery fold replays a rolled-back sweep under —
#: strong enough to dominate a near-singular gram product that NaN'd the
#: plain solve, small enough to leave a well-conditioned sweep's fixed
#: point essentially unchanged.
RECOVERY_EPS = 1e-3


def _als_fold_recovery(d: int, m_d, factors, lam):
    """The Gauss-Seidel update under the stronger :data:`RECOVERY_EPS`
    ridge — used to replay a sweep after a NaN/Inf burst (see
    ``resilience.guard``). A separate module-level callable because the
    fold's identity is part of the engine's jit cache key."""
    n = len(factors)
    grams_other = tuple(gram(factors[w]) for w in range(n) if w != d)
    y, lam = _als_update(m_d, grams_other, RECOVERY_EPS)
    return tuple(factors[:d]) + (y,) + tuple(factors[d + 1:]), lam


@dataclasses.dataclass
class CPDResult:
    factors: list[jax.Array]
    lam: jax.Array
    fits: list[float]


def cp_als(
    tensor: FlycooTensor,
    rank: int,
    iters: int = 10,
    key=None,
    config: ExecutionConfig | None = None,
    backend: str | None = None,
    interpret: bool | None = None,
    track_fit: bool = True,
    mesh=None,
    dist=None,
    *,
    ladder=None,
    checkpoint=None,
    checkpoint_every: int = 1,
    resume: bool = False,
) -> CPDResult:
    """Run CPD-ALS for ``iters`` sweeps over all modes (paper Alg. 5 outer).

    Execution policy comes from ``config``; ``backend``/``interpret`` are
    legacy conveniences that build one (mutually exclusive with ``config``).

    With ``mesh`` (a ``jax.sharding.Mesh`` or ``repro.sharding.ShardingCtx``)
    the engine state shards over the mesh's data axis and every sweep runs
    as ONE ``engine.dist.dist_all_modes`` program — the same scanned fold,
    distributed. ``tensor``'s partition counts must divide over the mesh
    (build with ``core.distributed.build_sharded_flycoo``); ``dist`` is an
    optional ``engine.DistConfig`` (its ``model_axis`` must stay ``None`` —
    the ALS fold needs the full rank on every device).

    Resilience (see :mod:`repro.resilience`):

    * ``ladder``: ``True`` / a :class:`repro.resilience.LadderPolicy`
      enables the degradation ladder — a compile/lowering failure steps
      the backend down ``pallas_fused -> pallas -> xla -> ref`` and
      rebuilds the engine state (bitwise-identical output, every rung) —
      plus the per-sweep NaN/Inf guard: on a burst the sweep is rolled
      back and replayed under the stronger :data:`RECOVERY_EPS` ridge.
      Every transition lands on the obs registry; nothing degrades
      silently.
    * ``checkpoint``: a directory or :class:`repro.resilience.
      SnapshotStore`; every ``checkpoint_every`` completed sweeps the
      ``(factors, lam, fits)`` state is snapshotted atomically under the
      problem fingerprint. ``resume=True`` restores the newest intact
      snapshot *for the same problem* (tensor bytes + rank + config +
      key) and replays only the remaining sweeps — bitwise-identical
      final factors vs an uninterrupted run, because at a sweep boundary
      the layout has rotated back to its start arrangement and
      ``(factors, lam)`` are the complete dynamic state.
    """
    if config is None:
        config = ExecutionConfig(backend=backend or "xla",
                                 interpret=interpret)
    elif backend is not None or interpret is not None:
        raise ValueError("pass either config or backend/interpret, not both")
    policy = resolve_policy(ladder)
    if key is None:
        key = jax.random.PRNGKey(0)
    n = tensor.nmodes
    factors = tuple(init_factors(key, tensor.dims, rank))
    lam = jnp.ones((rank,), jnp.float32)
    if mesh is not None:
        from repro.sharding import ShardingCtx

        if dist is None and isinstance(mesh, ShardingCtx):
            # ALS folds inside the sweep, which needs the full rank on
            # every device — never inherit the ctx's tp axis here.
            dist = engine.DistConfig(data_axis=mesh.data_axis)
    elif dist is not None:
        raise ValueError("dist config given without a mesh")

    def build_state(cfg):
        st = engine.init(tensor, cfg)
        if mesh is not None:
            st = engine.dist.shard_state(st, mesh, dist)
        return st

    state = build_state(config)
    sweep = engine.all_modes if mesh is None else engine.dist.dist_all_modes
    norm_x_sq = float(np.sum(tensor.values.astype(np.float64) ** 2))

    store = as_store(checkpoint)
    fits: list = []
    first = 0
    fp = None
    if store is not None:
        fp = fingerprint(tensor.indices, tensor.values, tensor.dims, rank,
                         config=config, key=key,
                         extra="resident" if mesh is None else "dist")
        if resume:
            snap = store.latest(fp)
            if snap is not None:
                factors = tuple(jnp.asarray(f) for f in snap.factors)
                lam = jnp.asarray(snap.lam)
                fits = list(snap.fits)
                first = snap.sweep
    backend_steps = 0
    for i in range(first, iters):
        cz = _chaos.active()
        if cz is not None:
            cz.maybe_kill(i)
        prev = (factors, lam)
        # One dispatch per sweep: scan over modes, ALS update in the fold.
        with span("cpd.sweep", sweep=i, streamed=False) as sp:
            fold = _als_fold
            while True:
                try:
                    outs, state, factors, lam = sweep(
                        state, factors, fold=fold, carry=lam)
                except Exception as exc:
                    # Compile/lowering failures happen before any factor
                    # update (the sweep is one program): step the backend
                    # down a rung, rebuild the state from the tensor (at a
                    # sweep boundary the layout bitwise-equals a fresh
                    # init), and retry the sweep.
                    if policy is None or classify(exc) != "compile" \
                            or backend_steps >= policy.max_backend_steps:
                        raise
                    nb = next_backend(state.config.backend)
                    if nb is None:
                        raise
                    backend_steps += 1
                    record_degradation("compile", state.config.backend, nb,
                                       site="cpd.backend", sweep=i)
                    state = build_state(dataclasses.replace(
                        state.config, backend=nb))
                    continue
                if cz is not None:
                    factors = tuple(cz.mangle_factors(i, factors))
                if policy is not None \
                        and not _guard.all_finite(factors, lam):
                    if fold is _als_fold_recovery:
                        raise FloatingPointError(
                            f"NaN/Inf burst in sweep {i} persisted "
                            "through the ridge-recovery replay")
                    # Roll back and replay under the stronger ridge: the
                    # layout is bitwise back at its start arrangement, so
                    # the replay sees exactly the pre-sweep problem.
                    _guard.record_recovery("nan_rollback", sweep=i,
                                           streamed=False)
                    factors, lam = prev
                    fold = _als_fold_recovery
                    continue
                break
            if track_fit:
                fit = _fit(norm_x_sq, outs[n - 1], factors, lam)
                fits.append(fit)
                sp.set("fit", float(fit))
                _obs_gauge("cpd_fit", "latest ALS fit per tier").set(
                    "resident", float(fit))
        if store is not None and ((i + 1) % checkpoint_every == 0
                                  or i + 1 == iters):
            store.save(fp, i + 1, [np.asarray(f) for f in factors],
                       np.asarray(lam), fits)
    return CPDResult(factors=list(factors), lam=lam, fits=fits)


def _fit(norm_x_sq: float, m_last, factors, lam) -> float:
    n = len(factors)
    inner = jnp.sum(m_last * (factors[n - 1] * lam[None, :]))
    g = gram(factors[0])
    for f in factors[1:]:
        g = g * gram(f)
    norm_est_sq = lam @ g @ lam
    resid_sq = jnp.maximum(norm_x_sq - 2 * inner + norm_est_sq, 0.0)
    return float(1.0 - jnp.sqrt(resid_sq) / np.sqrt(norm_x_sq))


def cp_als_reference(indices, values, dims, rank, iters=10, key=None):
    """Oracle ALS using plain COO mttkrp_ref (no FLYCOO) for tests."""
    if key is None:
        key = jax.random.PRNGKey(0)
    n = len(dims)
    factors = init_factors(key, dims, rank)
    lam = jnp.ones((rank,), jnp.float32)
    norm_x_sq = float(np.sum(np.asarray(values, np.float64) ** 2))
    indices = jnp.asarray(indices)
    values = jnp.asarray(values)
    fits = []
    for _ in range(iters):
        m_last = None
        for d in range(n):
            m = mttkrp_ref(indices, values, factors, d, dims[d])
            grams_other = [gram(factors[w]) for w in range(n) if w != d]
            y, lam = _als_update(m, tuple(grams_other))
            factors[d] = y
            m_last = m
        fits.append(_fit(norm_x_sq, m_last, factors, lam))
    return CPDResult(factors=factors, lam=lam, fits=fits)
