"""FLYCOO-TPU sparse tensor format (paper Sec. 3, adapted per DESIGN.md Sec. 2).

A tensor element is the tuple ``<alpha_i, beta_i, val_i>`` (paper Sec. 3.5):
``beta_i``  = per-mode indices (c_0..c_{N-1}),
``alpha_i`` = per-mode remap ids (b_0..b_{N-1}) — the element's physical slot
in the mode-d kernel layout.

The mode-d *kernel layout* is rectangular (see ``partition.ModePlan``):
``kappa_d`` partitions x ``blocks_pp_d * P`` slots each. Pad slots hold
``val = 0`` and ``lrow = -1`` so they contribute nothing (DESIGN.md Sec. 2).

Per-slot arrays in layout d:
  val   (S_d,)    f32    nonzero value (0 in pads)
  idx   (S_d, N)  i32    original per-mode indices (0 in pads)
  lrow  (S_d,)    i32    relabeled row id *local to its partition* for the
                         output mode d (-1 in pads)
  dst   (S_d,)    i32    slot of the same element in layout (d+1) mod N
                         (-1 in pads) — drives dynamic remapping (Alg. 3)

``dst`` is what makes remapping "dynamic": the mode-d pass scatters its own
elements into the mode-(d+1) layout while computing mode d, exactly the
paper's Alg. 3 (unique remap ids => conflict-free scatter, Observation 1).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from .partition import ModePlan, plan_mode


@dataclasses.dataclass
class FlycooTensor:
    """A sparse tensor in FLYCOO-TPU format (host-side container).

    ``indices``/``values`` are kept in canonical (input) element order for
    reference computations; ``plans[d]`` carries each mode's kernel layout.
    """

    dims: tuple[int, ...]
    indices: np.ndarray           # (nnz, N) int32, canonical order
    values: np.ndarray            # (nnz,) float32, canonical order
    plans: list[ModePlan]

    @property
    def nmodes(self) -> int:
        return len(self.dims)

    @property
    def nnz(self) -> int:
        return int(self.values.shape[0])

    # ---------------------------------------------------------------- layout
    def layout_arrays(self, d: int) -> dict[str, np.ndarray]:
        """Materialize the mode-d kernel layout arrays (val/idx/lrow/dst)."""
        plan = self.plans[d]
        nxt = self.plans[(d + 1) % self.nmodes]
        S = plan.padded_nnz
        val = np.zeros(S, dtype=np.float32)
        idx = np.zeros((S, self.nmodes), dtype=np.int32)
        lrow = np.full(S, -1, dtype=np.int32)
        dst = np.full(S, -1, dtype=np.int32)

        slots = plan.slot_of_elem
        val[slots] = self.values
        idx[slots] = self.indices
        # local row within owning partition, in relabeled space
        rel = plan.row_relabel[self.indices[:, d]].astype(np.int64)
        lrow[slots] = (rel % plan.rows_pp).astype(np.int32)
        dst[slots] = nxt.slot_of_elem.astype(np.int32)
        return {"val": val, "idx": idx, "lrow": lrow, "dst": dst}

    # -------------------------------------------------------------- metadata
    def memory_bits_per_element(self, float_bits: int = 32) -> float:
        """Paper Sec. 3.5.1: N*log2(|X|) + sum_h log2(I_h) + delta_float."""
        n = self.nmodes
        return (
            n * math.log2(max(self.nnz, 2))
            + sum(math.log2(max(i, 2)) for i in self.dims)
            + float_bits
        )

    def load_balance(self) -> list[dict]:
        return [p.load_balance() for p in self.plans]


def build_flycoo(
    indices: np.ndarray,
    values: np.ndarray,
    dims: Sequence[int],
    kappa: int | None = None,
    rows_pp: int | None = None,
    block_p: int = 128,
) -> FlycooTensor:
    """Preprocess a COO tensor into FLYCOO-TPU format (paper Sec. 5.7 cost:
    O(nnz log nnz) per mode, touching only nonzeros — never the index space).
    """
    indices = np.ascontiguousarray(np.asarray(indices, dtype=np.int32))
    values = np.ascontiguousarray(np.asarray(values, dtype=np.float32))
    assert indices.ndim == 2 and indices.shape[0] == values.shape[0]
    n = indices.shape[1]
    assert len(dims) == n and n >= 3, "paper targets tensors of mode >= 3"
    for d in range(n):
        assert indices[:, d].min(initial=0) >= 0
        assert indices[:, d].max(initial=0) < dims[d]
    plans = [
        plan_mode(indices[:, d], int(dims[d]), d, kappa=kappa,
                  rows_pp=rows_pp, block_p=block_p)
        for d in range(n)
    ]
    return FlycooTensor(tuple(int(x) for x in dims), indices, values, plans)
