"""FLYCOO-TPU sparse tensor format (paper Sec. 3, adapted per DESIGN.md Sec. 2).

A tensor element is the tuple ``<alpha_i, beta_i, val_i>`` (paper Sec. 3.5):
``beta_i``  = per-mode indices (c_0..c_{N-1}),
``alpha_i`` = per-mode remap ids (b_0..b_{N-1}) — the element's physical slot
in the mode-d kernel layout.

The mode-d *kernel layout* is block-scheduled (see ``partition.ModePlan``):
``nblocks_d`` blocks of ``P`` slots laid out partition-major, with the
``block_part`` descriptor naming each block's owning partition. The default
``compact`` schedule emits only real blocks; ``rect`` pads every partition
to the max partition's block count (the comparison baseline). Pad slots
hold ``val = 0`` and ``lrow = -1`` so they contribute nothing.

Per-slot arrays in layout d:
  val   (S_d,)    f32    nonzero value (0 in pads)
  idx   (S_d, N)  i32    original per-mode indices (0 in pads)
  lrow  (S_d,)    i32    relabeled row id *local to its partition* for the
                         output mode d (-1 in pads)
  dst   (S_d,)    i32    slot of the same element in layout (d+1) mod N
                         (-1 in pads) — drives dynamic remapping (Alg. 3)

``dst`` is what makes remapping "dynamic": the mode-d pass scatters its own
elements into the mode-(d+1) layout while computing mode d, exactly the
paper's Alg. 3 (unique remap ids => conflict-free scatter, Observation 1).

In-block factor-row dedup
-------------------------
The fused Pallas pipeline DMAs input-factor rows into VMEM per block; on
Zipf-heavy tensors the same hot row recurs many times within one block, so
per-slot copies re-fetch it up to ``P`` times. :meth:`FlycooTensor.
dedup_tables` sorts each block's factor-row list host-side and emits

  uidx  (N-1, S_d)       per block, the ``U <= P`` *unique* rows, compacted
                         to the block's first slots (rest zero-padded);
  upos  (S_d, N-1)       per slot, the local stage position of its row
                         among the block's uniques (0 for pad slots);
  nuniq (N-1, nblocks)   per block, the unique-row count ``U``,

so the kernel issues ``U`` row DMAs instead of ``P`` and the EC body
gathers its Hadamard operands through ``upos``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from repro.obs.trace import span as _obs_span

from .partition import DEFAULT_SCHEDULE, ModePlan, plan_mode

_ROW_SENTINEL = np.iinfo(np.int32).max  # pad-slot marker; sorts last


def _dedup_tables_batched(rows: np.ndarray, nblocks: int, block_p: int):
    """Build (uidx, upos, nuniq) for ``F`` factors' per-slot row lists.

    ``rows`` is ``(F, S)`` integer with ``_ROW_SENTINEL`` marking pad
    slots; ``S == nblocks * block_p``. Fully vectorized over factors *and*
    blocks: sort each block's rows, mark firsts, compact the uniques to
    the block's front, and record every slot's position among them. All
    work happens on int32 (row ids are < 2^31 by the FLYCOO int32 index
    contract) — the batched narrow path is the dedup half of the cold-plan
    vectorization pass.
    """
    f = rows.shape[0]
    s = nblocks * block_p
    assert rows.shape == (f, s), (rows.shape, nblocks, block_p)
    rb = np.ascontiguousarray(rows, dtype=np.int32).reshape(
        f, nblocks, block_p)
    # stability is irrelevant here: equal rows share one upos/uidx entry,
    # so any permutation among equals yields identical tables
    order = np.argsort(rb, axis=2)
    srt = np.take_along_axis(rb, order, axis=2)
    isnew = np.ones(srt.shape, dtype=bool)
    isnew[:, :, 1:] = srt[:, :, 1:] != srt[:, :, :-1]
    isnew &= srt != _ROW_SENTINEL          # sentinels are not unique rows
    upos_sorted = np.maximum(
        np.cumsum(isnew, axis=2, dtype=np.int32) - 1, 0)
    upos = np.zeros(srt.shape, dtype=np.int32)
    np.put_along_axis(upos, order, upos_sorted, axis=2)
    upos[rb == _ROW_SENTINEL] = 0          # pad slots -> stage row 0
    nuniq = isnew.sum(axis=2).astype(np.int32)
    uidx = np.zeros(srt.shape, dtype=np.int32)
    fix, bix, six = np.nonzero(isnew)
    uidx[fix, bix, upos_sorted[fix, bix, six]] = srt[fix, bix, six]
    return uidx.reshape(f, s), upos.reshape(f, s), nuniq


def dedup_tables_from_rows(rows: np.ndarray, nblocks: int, block_p: int):
    """Single-factor wrapper over :func:`_dedup_tables_batched`.

    ``rows`` is ``(S,)`` with ``_ROW_SENTINEL`` marking pad slots;
    returns ``(uidx (S,), upos (S,), nuniq (nblocks,))`` int32.
    """
    uidx, upos, nuniq = _dedup_tables_batched(
        np.asarray(rows)[None, :], nblocks, block_p)
    return uidx[0], upos[0], nuniq[0]


@dataclasses.dataclass
class FlycooTensor:
    """A sparse tensor in FLYCOO-TPU format (host-side container).

    ``indices``/``values`` are kept in canonical (input) element order for
    reference computations; ``plans[d]`` carries each mode's kernel layout.
    """

    dims: tuple[int, ...]
    indices: np.ndarray           # (nnz, N) int32, canonical order
    values: np.ndarray            # (nnz,) float32, canonical order
    plans: list[ModePlan]
    # per-mode dedup tables, built lazily once (engine init + dma_row_model
    # + the autotuner's exact cost stage all consume the same tables)
    _dedup_cache: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False)

    @property
    def nmodes(self) -> int:
        return len(self.dims)

    @property
    def nnz(self) -> int:
        return int(self.values.shape[0])

    # ---------------------------------------------------------------- layout
    def layout_arrays(self, d: int) -> dict[str, np.ndarray]:
        """Materialize the mode-d kernel layout arrays (val/idx/lrow/dst)."""
        plan = self.plans[d]
        nxt = self.plans[(d + 1) % self.nmodes]
        S = plan.padded_nnz
        val = np.zeros(S, dtype=np.float32)
        idx = np.zeros((S, self.nmodes), dtype=np.int32)
        lrow = np.full(S, -1, dtype=np.int32)
        dst = np.full(S, -1, dtype=np.int32)

        slots = plan.slot_of_elem
        val[slots] = self.values
        idx[slots] = self.indices
        # local row within owning partition, in relabeled space
        rel = plan.row_relabel[self.indices[:, d]].astype(np.int64)
        lrow[slots] = (rel % plan.rows_pp).astype(np.int32)
        dst[slots] = nxt.slot_of_elem.astype(np.int32)
        return {"val": val, "idx": idx, "lrow": lrow, "dst": dst}

    def _slot_rows(self, d: int) -> np.ndarray:
        """(N-1, S_d) int32 factor row per mode-``d`` slot for every input
        mode ``w != d`` in ascending mode order (sentinel marks pads)."""
        plan = self.plans[d]
        in_modes = [w for w in range(self.nmodes) if w != d]
        rows = np.full((len(in_modes), plan.padded_nnz), _ROW_SENTINEL,
                       dtype=np.int32)
        rows[:, plan.slot_of_elem] = self.indices[:, in_modes].T
        return rows

    def dedup_tables(self, d: int):
        """Per-block factor-row dedup tables for the mode-``d`` layout.

        Returns ``(uidx (N-1, S_d) i32, upos (S_d, N-1) i32,
        nuniq (N-1, nblocks) i32)`` over the input modes ``w != d`` in
        ascending mode order (matching the kernels' factor operand order).
        Built once per mode and memoized on the tensor.
        """
        cached = self._dedup_cache.get(d)
        if cached is None:
            with _obs_span("plan.dedup_tables", mode=d):
                plan = self.plans[d]
                uidx, upos, nuniq = _dedup_tables_batched(
                    self._slot_rows(d), plan.nblocks, plan.block_p)
                cached = (uidx, np.ascontiguousarray(upos.T), nuniq)
            self._dedup_cache[d] = cached
        return cached

    def trivial_dedup_tables(self, d: int):
        """Dedup-off tables in the same ``(uidx, upos, nuniq)`` encoding.

        Every slot stages its own factor row (``upos = slot % P``,
        ``nuniq = P`` everywhere, pad slots stage row 0), so the fused
        compact kernels run unchanged but issue one row DMA per slot —
        the ``dedup=False`` point of the plan space, letting the autotuner
        price the dedup preprocessing against its DMA savings.
        """
        plan = self.plans[d]
        nm1 = self.nmodes - 1
        rows = self._slot_rows(d)
        uidx = np.where(rows == _ROW_SENTINEL, 0, rows)
        upos = np.repeat(
            (np.arange(plan.padded_nnz, dtype=np.int32)
             % plan.block_p)[:, None], nm1, axis=1)
        nuniq = np.full((nm1, plan.nblocks), plan.block_p, dtype=np.int32)
        return uidx, upos, nuniq

    def dma_row_model(self, d: int) -> dict:
        """Modeled factor-row DMA copies for the mode-``d`` fused gather:
        per-slot copies (``nblocks * P`` per input factor — what the
        non-dedup pipeline issues) vs per-block-unique copies
        (``sum nuniq``). The ratio is the in-block hot-row re-fetch factor
        the dedup stage removes."""
        plan = self.plans[d]
        nm1 = self.nmodes - 1
        _, _, nuniq = self.dedup_tables(d)
        per_slot = plan.nblocks * plan.block_p * nm1
        return {
            "per_slot_rows": int(per_slot),
            "dedup_rows": int(nuniq.sum()),
            "dedup_reduction_x": float(per_slot / max(int(nuniq.sum()), 1)),
        }

    # -------------------------------------------------------------- metadata
    def memory_bits_per_element(self, float_bits: int = 32) -> float:
        """Paper Sec. 3.5.1: N*log2(|X|) + sum_h log2(I_h) + delta_float."""
        n = self.nmodes
        return (
            n * math.log2(max(self.nnz, 2))
            + sum(math.log2(max(i, 2)) for i in self.dims)
            + float_bits
        )

    def load_balance(self) -> list[dict]:
        return [p.load_balance() for p in self.plans]


def build_flycoo(
    indices: np.ndarray,
    values: np.ndarray,
    dims: Sequence[int],
    kappa: int | Sequence[int] | None = None,
    rows_pp: int | None = None,
    block_p: int = 128,
    schedule: str = DEFAULT_SCHEDULE,
    degrees: Sequence[np.ndarray] | None = None,
    plans: Sequence[ModePlan] | None = None,
) -> FlycooTensor:
    """Preprocess a COO tensor into FLYCOO-TPU format (paper Sec. 5.7 cost:
    O(nnz log nnz) per mode, touching only nonzeros — never the index space).

    ``kappa`` may be per-mode (a sequence) — the distributed factory path
    rounds each mode's partition count to the device count. ``degrees``
    (per-mode ``bincount`` vectors) lets the plan cache hand down the
    histograms it already computed for its signature; ``plans`` skips
    :func:`plan_mode` entirely (the cache-hit path — caller guarantees the
    plans match this element list).
    """
    indices = np.ascontiguousarray(np.asarray(indices, dtype=np.int32))
    values = np.ascontiguousarray(np.asarray(values, dtype=np.float32))
    assert indices.ndim == 2 and indices.shape[0] == values.shape[0]
    n = indices.shape[1]
    assert len(dims) == n and n >= 3, "paper targets tensors of mode >= 3"
    if plans is None:
        # one transposed copy so every mode's plan reads a contiguous column
        idx_t = np.ascontiguousarray(indices.T)
        for d in range(n):
            assert idx_t[d].min(initial=0) >= 0
            assert idx_t[d].max(initial=0) < dims[d]
        kappas = ([kappa] * n if kappa is None or np.isscalar(kappa)
                  else list(kappa))
        plans = []
        for d in range(n):
            with _obs_span("plan.mode", mode=d, nnz=int(values.shape[0])):
                plans.append(plan_mode(
                    idx_t[d], int(dims[d]), d, kappa=kappas[d],
                    rows_pp=rows_pp, block_p=block_p, schedule=schedule,
                    degrees=None if degrees is None else degrees[d]))
    else:
        # cache-hit path: caller (the plan cache) guarantees the plans
        # match this element list — skip the O(nnz) validation rescan
        plans = list(plans)
        assert len(plans) == n
    return FlycooTensor(tuple(int(x) for x in dims), indices, values, plans)
