"""FLYCOO-TPU sparse tensor format (paper Sec. 3, adapted per DESIGN.md Sec. 2).

A tensor element is the tuple ``<alpha_i, beta_i, val_i>`` (paper Sec. 3.5):
``beta_i``  = per-mode indices (c_0..c_{N-1}),
``alpha_i`` = per-mode remap ids (b_0..b_{N-1}) — the element's physical slot
in the mode-d kernel layout.

The mode-d *kernel layout* is block-scheduled (see ``partition.ModePlan``):
``nblocks_d`` blocks of ``P`` slots laid out partition-major, with the
``block_part`` descriptor naming each block's owning partition. The default
``compact`` schedule emits only real blocks; ``rect`` pads every partition
to the max partition's block count (the comparison baseline). Pad slots
hold ``val = 0`` and ``lrow = -1`` so they contribute nothing.

Per-slot arrays in layout d:
  val   (S_d,)    f32    nonzero value (0 in pads)
  idx   (S_d, N)  i32    original per-mode indices (0 in pads)
  lrow  (S_d,)    i32    relabeled row id *local to its partition* for the
                         output mode d (-1 in pads)
  dst   (S_d,)    i32    slot of the same element in layout (d+1) mod N
                         (-1 in pads) — drives dynamic remapping (Alg. 3)

``dst`` is what makes remapping "dynamic": the mode-d pass scatters its own
elements into the mode-(d+1) layout while computing mode d, exactly the
paper's Alg. 3 (unique remap ids => conflict-free scatter, Observation 1).

In-block factor-row dedup
-------------------------
The fused Pallas pipeline DMAs input-factor rows into VMEM per block; on
Zipf-heavy tensors the same hot row recurs many times within one block, so
per-slot copies re-fetch it up to ``P`` times. :meth:`FlycooTensor.
dedup_tables` sorts each block's factor-row list host-side and emits

  uidx  (N-1, S_d)       per block, the ``U <= P`` *unique* rows, compacted
                         to the block's first slots (rest zero-padded);
  upos  (S_d, N-1)       per slot, the local stage position of its row
                         among the block's uniques (0 for pad slots);
  nuniq (N-1, nblocks)   per block, the unique-row count ``U``,

so the kernel issues ``U`` row DMAs instead of ``P`` and the EC body
gathers its Hadamard operands through ``upos``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from .partition import DEFAULT_SCHEDULE, ModePlan, plan_mode

_ROW_SENTINEL = np.iinfo(np.int64).max  # pad-slot marker; sorts last


def dedup_tables_from_rows(rows: np.ndarray, nblocks: int, block_p: int):
    """Build (uidx, upos, nuniq) for one factor's per-slot row list.

    ``rows`` is ``(S,)`` int64 with ``_ROW_SENTINEL`` marking pad slots;
    ``S == nblocks * block_p``. Vectorized over blocks (no per-block Python
    loop): sort each block's rows, mark firsts, compact the uniques to the
    block's front, and record every slot's position among them.
    """
    s = nblocks * block_p
    assert rows.shape == (s,), (rows.shape, nblocks, block_p)
    rb = rows.reshape(nblocks, block_p)
    order = np.argsort(rb, axis=1, kind="stable")
    srt = np.take_along_axis(rb, order, axis=1)
    isnew = np.ones((nblocks, block_p), dtype=bool)
    isnew[:, 1:] = srt[:, 1:] != srt[:, :-1]
    isnew &= srt != _ROW_SENTINEL          # sentinels are not unique rows
    upos_sorted = np.maximum(np.cumsum(isnew, axis=1) - 1, 0)
    upos = np.zeros((nblocks, block_p), dtype=np.int64)
    np.put_along_axis(upos, order, upos_sorted, axis=1)
    upos[rb == _ROW_SENTINEL] = 0          # pad slots -> stage row 0
    nuniq = isnew.sum(axis=1).astype(np.int32)
    uidx = np.zeros((nblocks, block_p), dtype=np.int64)
    bix, six = np.nonzero(isnew)
    uidx[bix, upos_sorted[bix, six]] = srt[bix, six]
    return (uidx.reshape(s).astype(np.int32),
            upos.reshape(s).astype(np.int32), nuniq)


@dataclasses.dataclass
class FlycooTensor:
    """A sparse tensor in FLYCOO-TPU format (host-side container).

    ``indices``/``values`` are kept in canonical (input) element order for
    reference computations; ``plans[d]`` carries each mode's kernel layout.
    """

    dims: tuple[int, ...]
    indices: np.ndarray           # (nnz, N) int32, canonical order
    values: np.ndarray            # (nnz,) float32, canonical order
    plans: list[ModePlan]

    @property
    def nmodes(self) -> int:
        return len(self.dims)

    @property
    def nnz(self) -> int:
        return int(self.values.shape[0])

    # ---------------------------------------------------------------- layout
    def layout_arrays(self, d: int) -> dict[str, np.ndarray]:
        """Materialize the mode-d kernel layout arrays (val/idx/lrow/dst)."""
        plan = self.plans[d]
        nxt = self.plans[(d + 1) % self.nmodes]
        S = plan.padded_nnz
        val = np.zeros(S, dtype=np.float32)
        idx = np.zeros((S, self.nmodes), dtype=np.int32)
        lrow = np.full(S, -1, dtype=np.int32)
        dst = np.full(S, -1, dtype=np.int32)

        slots = plan.slot_of_elem
        val[slots] = self.values
        idx[slots] = self.indices
        # local row within owning partition, in relabeled space
        rel = plan.row_relabel[self.indices[:, d]].astype(np.int64)
        lrow[slots] = (rel % plan.rows_pp).astype(np.int32)
        dst[slots] = nxt.slot_of_elem.astype(np.int32)
        return {"val": val, "idx": idx, "lrow": lrow, "dst": dst}

    def _slot_rows(self, d: int, w: int) -> np.ndarray:
        """(S_d,) mode-``w`` factor row per mode-``d`` slot (sentinel pads)."""
        plan = self.plans[d]
        rows = np.full(plan.padded_nnz, _ROW_SENTINEL, dtype=np.int64)
        rows[plan.slot_of_elem] = self.indices[:, w]
        return rows

    def dedup_tables(self, d: int):
        """Per-block factor-row dedup tables for the mode-``d`` layout.

        Returns ``(uidx (N-1, S_d) i32, upos (S_d, N-1) i32,
        nuniq (N-1, nblocks) i32)`` over the input modes ``w != d`` in
        ascending mode order (matching the kernels' factor operand order).
        """
        plan = self.plans[d]
        in_modes = [w for w in range(self.nmodes) if w != d]
        uidx, upos, nuniq = [], [], []
        for w in in_modes:
            u, p, n = dedup_tables_from_rows(self._slot_rows(d, w),
                                             plan.nblocks, plan.block_p)
            uidx.append(u)
            upos.append(p)
            nuniq.append(n)
        return (np.stack(uidx), np.stack(upos, axis=1), np.stack(nuniq))

    def dma_row_model(self, d: int) -> dict:
        """Modeled factor-row DMA copies for the mode-``d`` fused gather:
        per-slot copies (``nblocks * P`` per input factor — what the
        non-dedup pipeline issues) vs per-block-unique copies
        (``sum nuniq``). The ratio is the in-block hot-row re-fetch factor
        the dedup stage removes."""
        plan = self.plans[d]
        nm1 = self.nmodes - 1
        _, _, nuniq = self.dedup_tables(d)
        per_slot = plan.nblocks * plan.block_p * nm1
        return {
            "per_slot_rows": int(per_slot),
            "dedup_rows": int(nuniq.sum()),
            "dedup_reduction_x": float(per_slot / max(int(nuniq.sum()), 1)),
        }

    # -------------------------------------------------------------- metadata
    def memory_bits_per_element(self, float_bits: int = 32) -> float:
        """Paper Sec. 3.5.1: N*log2(|X|) + sum_h log2(I_h) + delta_float."""
        n = self.nmodes
        return (
            n * math.log2(max(self.nnz, 2))
            + sum(math.log2(max(i, 2)) for i in self.dims)
            + float_bits
        )

    def load_balance(self) -> list[dict]:
        return [p.load_balance() for p in self.plans]


def build_flycoo(
    indices: np.ndarray,
    values: np.ndarray,
    dims: Sequence[int],
    kappa: int | None = None,
    rows_pp: int | None = None,
    block_p: int = 128,
    schedule: str = DEFAULT_SCHEDULE,
) -> FlycooTensor:
    """Preprocess a COO tensor into FLYCOO-TPU format (paper Sec. 5.7 cost:
    O(nnz log nnz) per mode, touching only nonzeros — never the index space).
    """
    indices = np.ascontiguousarray(np.asarray(indices, dtype=np.int32))
    values = np.ascontiguousarray(np.asarray(values, dtype=np.float32))
    assert indices.ndim == 2 and indices.shape[0] == values.shape[0]
    n = indices.shape[1]
    assert len(dims) == n and n >= 3, "paper targets tensors of mode >= 3"
    for d in range(n):
        assert indices[:, d].min(initial=0) >= 0
        assert indices[:, d].max(initial=0) < dims[d]
    plans = [
        plan_mode(indices[:, d], int(dims[d]), d, kappa=kappa,
                  rows_pp=rows_pp, block_p=block_p, schedule=schedule)
        for d in range(n)
    ]
    return FlycooTensor(tuple(int(x) for x in dims), indices, values, plans)
