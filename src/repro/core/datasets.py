"""Synthetic sparse-tensor generators mirroring the paper's datasets (Table 3).

The real FROSTT / recsys tensors are not redistributable here, so we generate
synthetic tensors with (a) the same mode counts, (b) proportionally scaled
dimensions, and (c) heavy-tailed (Zipf-like) index distributions, which is the
regime the paper's degree-sorted load balancing targets. ``scale=1.0``
reproduces the published shapes; the default benchmark scale keeps laptop-size
nnz while preserving shape ratios.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .flycoo import FlycooTensor, build_flycoo

# name -> (dims, nnz) from paper Table 3.
PAPER_TENSORS: dict[str, tuple[tuple[int, ...], int]] = {
    "amazon": ((15_200_000, 43_500_000, 7_800), 233_100_000),
    "delicious": ((532_900, 17_300_000, 2_500_000, 1_400), 140_100_000),
    "music": ((23_300_000, 23_300_000, 166), 99_500_000),
    "nell1": ((2_900_000, 2_100_000, 25_500_000), 143_600_000),
    "twitch": ((15_500_000, 6_200_000, 783_900, 6_100, 6_100), 474_700_000),
    "vast": ((165_400, 11_400, 2, 100, 89), 26_000_000),
}

# Synthetic first-class datasets (not from the paper's Table 3). "zipf" is
# the skewed stress tensor for the load-balanced compact schedule and the
# in-block hot-row dedup: a steep power law (a = 2.0) concentrates nonzeros
# on a few hot rows of every mode while the dimensions stay large enough
# that benchmark scales still yield many partitions.
SYNTH_TENSORS: dict[str, tuple[tuple[int, ...], int, float]] = {
    "zipf": ((2_000_000, 1_500_000, 1_000_000), 40_000_000, 2.0),
}

DEFAULT_ZIPF_A = 1.2


@dataclasses.dataclass(frozen=True)
class TensorSpec:
    name: str
    dims: tuple[int, ...]
    nnz: int
    zipf_a: float = DEFAULT_ZIPF_A  # power-law exponent of the mode indices


def spec(name: str, scale: float = 1e-3, min_dim: int = 2,
         max_nnz: int | None = None) -> TensorSpec:
    if name in PAPER_TENSORS:
        (dims, nnz), a = PAPER_TENSORS[name], DEFAULT_ZIPF_A
    else:
        dims, nnz, a = SYNTH_TENSORS[name]
    sdims = tuple(max(min_dim, int(round(d * scale))) for d in dims)
    snnz = max(1000, int(round(nnz * scale)))
    if max_nnz is not None:
        snnz = min(snnz, max_nnz)
    return TensorSpec(name=name, dims=sdims, nnz=snnz, zipf_a=a)


def _zipf_indices(rng: np.random.Generator, dim: int, n: int,
                  a: float = DEFAULT_ZIPF_A) -> np.ndarray:
    """Heavy-tailed indices in [0, dim): Zipf ranks permuted over the dim."""
    raw = rng.zipf(a, size=n)
    idx = (raw - 1) % dim
    perm = rng.permutation(dim)  # decorrelate rank from index id
    return perm[idx].astype(np.int32)


def synthesize(ts: TensorSpec, seed: int = 0,
               dedupe: bool = True) -> tuple[np.ndarray, np.ndarray]:
    """Generate COO (indices (nnz, N), values (nnz,)) for a spec."""
    rng = np.random.default_rng(seed)
    cols = [_zipf_indices(rng, d, ts.nnz, a=ts.zipf_a) for d in ts.dims]
    indices = np.stack(cols, axis=1)
    if dedupe:
        indices = np.unique(indices, axis=0)
    values = rng.standard_normal(indices.shape[0]).astype(np.float32)
    return indices, values


def load(name: str, scale: float = 1e-3, seed: int = 0,
         max_nnz: int | None = 300_000, **flycoo_kw) -> FlycooTensor:
    ts = spec(name, scale=scale, max_nnz=max_nnz)
    indices, values = synthesize(ts, seed=seed)
    return build_flycoo(indices, values, ts.dims, **flycoo_kw)


def random_tensor(dims, nnz, seed=0, **flycoo_kw) -> FlycooTensor:
    ts = TensorSpec(name="random", dims=tuple(dims), nnz=nnz)
    indices, values = synthesize(ts, seed=seed)
    return build_flycoo(indices, values, ts.dims, **flycoo_kw)


def zipf_tensor(dims, nnz, a: float = 1.5, seed: int = 0,
                **flycoo_kw) -> FlycooTensor:
    """First-class skewed synthetic generator: every mode's indices follow
    a seeded Zipf power law with exponent ``a`` (steeper = more skew).
    This is the regime the paper's degree-sorted load balancing — and the
    compact schedule's nnz-balanced block grid — targets."""
    ts = TensorSpec(name="zipf", dims=tuple(int(d) for d in dims),
                    nnz=int(nnz), zipf_a=float(a))
    indices, values = synthesize(ts, seed=seed)
    return build_flycoo(indices, values, ts.dims, **flycoo_kw)
