"""Synthetic sparse-tensor generators mirroring the paper's datasets (Table 3).

The real FROSTT / recsys tensors are not redistributable here, so we generate
synthetic tensors with (a) the same mode counts, (b) proportionally scaled
dimensions, and (c) heavy-tailed (Zipf-like) index distributions, which is the
regime the paper's degree-sorted load balancing targets. ``scale=1.0``
reproduces the published shapes; the default benchmark scale keeps laptop-size
nnz while preserving shape ratios.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .flycoo import FlycooTensor, build_flycoo

# name -> (dims, nnz) from paper Table 3.
PAPER_TENSORS: dict[str, tuple[tuple[int, ...], int]] = {
    "amazon": ((15_200_000, 43_500_000, 7_800), 233_100_000),
    "delicious": ((532_900, 17_300_000, 2_500_000, 1_400), 140_100_000),
    "music": ((23_300_000, 23_300_000, 166), 99_500_000),
    "nell1": ((2_900_000, 2_100_000, 25_500_000), 143_600_000),
    "twitch": ((15_500_000, 6_200_000, 783_900, 6_100, 6_100), 474_700_000),
    "vast": ((165_400, 11_400, 2, 100, 89), 26_000_000),
}


@dataclasses.dataclass(frozen=True)
class TensorSpec:
    name: str
    dims: tuple[int, ...]
    nnz: int


def spec(name: str, scale: float = 1e-3, min_dim: int = 2,
         max_nnz: int | None = None) -> TensorSpec:
    dims, nnz = PAPER_TENSORS[name]
    sdims = tuple(max(min_dim, int(round(d * scale))) for d in dims)
    snnz = max(1000, int(round(nnz * scale)))
    if max_nnz is not None:
        snnz = min(snnz, max_nnz)
    return TensorSpec(name=name, dims=sdims, nnz=snnz)


def _zipf_indices(rng: np.random.Generator, dim: int, n: int,
                  a: float = 1.2) -> np.ndarray:
    """Heavy-tailed indices in [0, dim): Zipf ranks permuted over the dim."""
    raw = rng.zipf(a, size=n)
    idx = (raw - 1) % dim
    perm = rng.permutation(dim)  # decorrelate rank from index id
    return perm[idx].astype(np.int32)


def synthesize(ts: TensorSpec, seed: int = 0,
               dedupe: bool = True) -> tuple[np.ndarray, np.ndarray]:
    """Generate COO (indices (nnz, N), values (nnz,)) for a spec."""
    rng = np.random.default_rng(seed)
    cols = [_zipf_indices(rng, d, ts.nnz) for d in ts.dims]
    indices = np.stack(cols, axis=1)
    if dedupe:
        indices = np.unique(indices, axis=0)
    values = rng.standard_normal(indices.shape[0]).astype(np.float32)
    return indices, values


def load(name: str, scale: float = 1e-3, seed: int = 0,
         max_nnz: int | None = 300_000, **flycoo_kw) -> FlycooTensor:
    ts = spec(name, scale=scale, max_nnz=max_nnz)
    indices, values = synthesize(ts, seed=seed)
    return build_flycoo(indices, values, ts.dims, **flycoo_kw)


def random_tensor(dims, nnz, seed=0, **flycoo_kw) -> FlycooTensor:
    ts = TensorSpec(name="random", dims=tuple(dims), nnz=nnz)
    indices, values = synthesize(ts, seed=seed)
    return build_flycoo(indices, values, ts.dims, **flycoo_kw)
