"""Tensor partitioning scheme (paper Alg. 1) + TPU row relabeling.

Per output mode d:
  1. order mode-d vertices (output factor rows) by the number of incident
     nonzeros (hyperedge degree), descending;
  2. deal vertices cyclically over ``kappa`` partitions (paper Sec. 3.4.1
     cites Graham's 4/3; the cyclic deal is round-robin-on-sorted, whose
     provable makespan bound is mean + d_max <= 2*OPT, matching the 4/3
     regime whenever the max vertex degree is small vs. the mean load —
     the sparse-tensor common case; property-tested in tests/);
  3. every nonzero joins the partition owning its mode-d vertex, so each
     output row is owned by exactly one partition (paper Observation 2).

TPU adaptation (see DESIGN.md Sec. 2): vertices are *relabeled* so partition
``j`` owns the contiguous row range ``[j*rows_pp, (j+1)*rows_pp)``. This lets
a Pallas output BlockSpec map partition -> VMEM row tile. Relabeling permutes
rows only; the per-partition degree multiset (and hence the 4/3 bound) is
unchanged.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

# Default tile knobs (DESIGN.md Sec. 2: kappa is a VMEM tiling knob on TPU,
# not a core count). rows_pp * R * 4B must fit comfortably in VMEM.
DEFAULT_ROWS_PER_PARTITION = 512
DEFAULT_BLOCK_P = 128  # nonzeros per kernel block (sublane-aligned)


@dataclasses.dataclass(frozen=True)
class ModePlan:
    """Host-side preprocessing output for one output mode ``d``.

    The *kernel layout* for mode d is rectangular: ``kappa`` partitions, each
    padded to ``blocks_pp`` blocks of ``block_p`` slots; physical length is
    ``kappa * blocks_pp * block_p``. Pad slots carry ``val = 0, lrow = -1``.
    """

    mode: int
    kappa: int                   # number of partitions
    rows_pp: int                 # relabeled rows per partition (row tile height)
    block_p: int                 # nonzeros per kernel block (paper's P)
    blocks_pp: int               # blocks per partition (rectangular grid)
    dim: int                     # I_d
    # vertex relabeling: old row id -> relabeled row id in [0, kappa*rows_pp)
    row_relabel: np.ndarray      # (I_d,) int32
    # element -> physical slot in this mode's kernel layout (compact order)
    slot_of_elem: np.ndarray     # (nnz,) int64
    # per-partition true nonzero counts (for load-balance reporting)
    part_nnz: np.ndarray         # (kappa,) int64

    @property
    def padded_nnz(self) -> int:
        return self.kappa * self.blocks_pp * self.block_p

    @property
    def relabeled_rows(self) -> int:
        return self.kappa * self.rows_pp

    def load_balance(self) -> dict:
        """Max/mean partition load; paper Sec 3.4.1 bounds max <= 4/3 OPT.

        OPT >= max(mean, max vertex degree); we report the achieved ratio
        against that lower bound.
        """
        loads = self.part_nnz.astype(np.float64)
        mean = float(loads.mean())
        return {
            "max": float(loads.max()),
            "mean": mean,
            "imbalance": float(loads.max() / max(mean, 1e-9)),
        }


def choose_kappa(dim: int, rows_pp: int = DEFAULT_ROWS_PER_PARTITION) -> int:
    return max(1, math.ceil(dim / rows_pp))


def plan_mode(
    indices_d: np.ndarray,
    dim: int,
    mode: int,
    kappa: int | None = None,
    rows_pp: int | None = None,
    block_p: int = DEFAULT_BLOCK_P,
) -> ModePlan:
    """Run Alg. 1 for one mode and derive the rectangular kernel layout.

    Args:
      indices_d: (nnz,) mode-d index of every nonzero.
      dim: I_d.
      mode: d (bookkeeping only).
      kappa: partition count; default sized so row tiles fit VMEM.
      rows_pp: rows per partition; derived from kappa when not given.
    """
    indices_d = np.asarray(indices_d, dtype=np.int64)
    nnz = indices_d.shape[0]
    if kappa is None:
        kappa = choose_kappa(dim, rows_pp or DEFAULT_ROWS_PER_PARTITION)
    kappa = min(kappa, dim)  # never more partitions than rows
    rows_pp = math.ceil(dim / kappa)

    # --- Alg. 1 step 1: vertices sorted by degree (descending, stable). ---
    degrees = np.bincount(indices_d, minlength=dim)
    vsort = np.argsort(-degrees, kind="stable")  # (I_d,) vertex ids

    # --- Alg. 1 step 2: cyclic deal over kappa partitions. ---
    # vertex vsort[i] -> partition i % kappa, local row i // kappa.
    part_of_rank = np.arange(dim) % kappa
    local_of_rank = np.arange(dim) // kappa
    row_relabel = np.empty(dim, dtype=np.int64)
    row_relabel[vsort] = part_of_rank * rows_pp + local_of_rank
    part_of_vertex = np.empty(dim, dtype=np.int64)
    part_of_vertex[vsort] = part_of_rank

    # --- Alg. 1 step 3: collect hyperedges per partition; assign remap ids.
    part_of_elem = part_of_vertex[indices_d]
    part_nnz = np.bincount(part_of_elem, minlength=kappa)

    # Rectangular layout: partition j occupies slots [j*T*P, (j+1)*T*P).
    blocks_pp = max(1, math.ceil(int(part_nnz.max(initial=0)) / block_p))
    stride = blocks_pp * block_p

    # Position of each element within its partition: stable sort by partition,
    # then rank within group. (Remap id b_d = j*stride + rank.)
    order = np.argsort(part_of_elem, kind="stable")
    rank_within = np.empty(nnz, dtype=np.int64)
    part_starts = np.concatenate([[0], np.cumsum(part_nnz)])
    rank_within[order] = np.arange(nnz) - part_starts[part_of_elem[order]]
    slot_of_elem = part_of_elem * stride + rank_within

    return ModePlan(
        mode=mode,
        kappa=int(kappa),
        rows_pp=int(rows_pp),
        block_p=int(block_p),
        blocks_pp=int(blocks_pp),
        dim=int(dim),
        row_relabel=row_relabel.astype(np.int32),
        slot_of_elem=slot_of_elem,
        part_nnz=part_nnz,
    )
