"""Tensor partitioning scheme (paper Alg. 1) + TPU row relabeling.

Per output mode d:
  1. order mode-d vertices (output factor rows) by the number of incident
     nonzeros (hyperedge degree), descending;
  2. deal vertices cyclically over ``kappa`` partitions (paper Sec. 3.4.1
     cites Graham's 4/3; the cyclic deal is round-robin-on-sorted, whose
     provable makespan bound is mean + d_max <= 2*OPT, matching the 4/3
     regime whenever the max vertex degree is small vs. the mean load —
     the sparse-tensor common case; property-tested in tests/);
  3. every nonzero joins the partition owning its mode-d vertex, so each
     output row is owned by exactly one partition (paper Observation 2).

TPU adaptation (see DESIGN.md Sec. 2): vertices are *relabeled* so partition
``j`` owns the contiguous row range ``[j*rows_pp, (j+1)*rows_pp)``. This lets
a Pallas output BlockSpec map partition -> VMEM row tile. Relabeling permutes
rows only; the per-partition degree multiset (and hence the 4/3 bound) is
unchanged.

Block schedules
---------------
The kernel layout packs each partition's nonzeros into blocks of ``block_p``
slots. Two schedules exist (paper challenge (3): balanced block workloads):

``compact`` (default)
    Partition ``j`` gets exactly ``ceil(part_nnz[j] / P)`` blocks (min 1, so
    every output row tile is visited and zero-initialized); blocks are laid
    out partition-major and the ``(nblocks,)`` ``block_part`` descriptor
    records each block's owning partition. The Pallas grid walks only real
    work; on skewed (power-law) tensors this removes the pad blocks the
    rectangular layout spends most of its grid on.

``rect``
    Every partition is padded to the max partition's block count
    (``blocks_pp = ceil(max part_nnz / P)``); partition ``j`` owns the slot
    stride ``[j*blocks_pp*P, (j+1)*blocks_pp*P)``. Kept as the comparison
    baseline — ``block_part`` is materialized for it too, so descriptor-
    driven consumers treat both schedules uniformly.

Pad slots carry ``val = 0, lrow = -1`` in either schedule.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

# Default tile knobs (DESIGN.md Sec. 2: kappa is a VMEM tiling knob on TPU,
# not a core count). rows_pp * R * 4B must fit comfortably in VMEM.
DEFAULT_ROWS_PER_PARTITION = 512
DEFAULT_BLOCK_P = 128  # nonzeros per kernel block (sublane-aligned)

SCHEDULES = ("compact", "rect")
DEFAULT_SCHEDULE = "compact"


@dataclasses.dataclass(frozen=True)
class ModePlan:
    """Host-side preprocessing output for one output mode ``d``.

    The *kernel layout* for mode d is ``nblocks`` blocks of ``block_p``
    slots (physical length ``nblocks * block_p``), laid out partition-major;
    ``block_part[b]`` is the partition owning block ``b``. Under the
    ``rect`` schedule every partition holds exactly ``blocks_pp`` blocks;
    under ``compact`` only its real ``ceil(part_nnz/P)`` blocks (min 1).
    Pad slots carry ``val = 0, lrow = -1``.
    """

    mode: int
    kappa: int                   # number of partitions
    rows_pp: int                 # relabeled rows per partition (row tile height)
    block_p: int                 # nonzeros per kernel block (paper's P)
    blocks_pp: int               # max blocks of any partition (rect grid width)
    dim: int                     # I_d
    schedule: str                # "compact" | "rect" block schedule
    nblocks: int                 # total kernel blocks in the layout
    # vertex relabeling: old row id -> relabeled row id in [0, kappa*rows_pp)
    row_relabel: np.ndarray      # (I_d,) int32
    # element -> physical slot in this mode's kernel layout (compact order)
    slot_of_elem: np.ndarray     # (nnz,) int32 (int64 iff padded_nnz >= 2^31)
    # per-partition true nonzero counts (for load-balance reporting)
    part_nnz: np.ndarray         # (kappa,) int64
    # block -> owning partition descriptor (nondecreasing, partition-major)
    block_part: np.ndarray       # (nblocks,) int32
    # max vertex degree (the d_max term of the OPT lower bound)
    max_degree: int

    @property
    def padded_nnz(self) -> int:
        return self.nblocks * self.block_p

    @property
    def relabeled_rows(self) -> int:
        return self.kappa * self.rows_pp

    @property
    def pad_block_fraction(self) -> float:
        """Fraction of kernel blocks carrying zero real nonzeros."""
        real = np.ceil(self.part_nnz / self.block_p).sum()
        return float(1.0 - real / max(self.nblocks, 1))

    def load_balance(self) -> dict:
        """Max/mean partition load; paper Sec 3.4.1 bounds max <= 4/3 OPT.

        OPT >= max(mean, max vertex degree): no schedule can beat the mean
        load, and the partition owning the hottest vertex carries at least
        its degree. ``imbalance`` is the achieved max against that lower
        bound (``imbalance_vs_mean`` keeps the mean-only ratio for
        reference — it overstates imbalance when one vertex dominates).
        """
        loads = self.part_nnz.astype(np.float64)
        mean = float(loads.mean())
        opt_lb = max(mean, float(self.max_degree))
        return {
            "max": float(loads.max()),
            "mean": mean,
            "max_degree": float(self.max_degree),
            "opt_lower_bound": opt_lb,
            "imbalance": float(loads.max() / max(opt_lb, 1e-9)),
            "imbalance_vs_mean": float(loads.max() / max(mean, 1e-9)),
        }


def choose_kappa(dim: int, rows_pp: int = DEFAULT_ROWS_PER_PARTITION) -> int:
    return max(1, math.ceil(dim / rows_pp))


def _part_dtype(kappa: int):
    """Narrowest dtype holding partition ids — the stable (radix) argsort
    over per-element partitions is the cold-plan hot spot, and radix cost
    scales with key width (uint16 sorts ~2x faster than int64)."""
    return np.uint16 if kappa <= 0xFFFF else np.int32


def _block_layout(part_nnz: np.ndarray, kappa: int, block_p: int,
                  schedule: str):
    """Block schedule: partition j owns part_blocks[j] consecutive blocks.
    Min 1 block per partition so every output row tile is visited (and
    zero-initialized) by the kernel grid even when the partition is empty.
    Returns ``(blocks_pp, block_start (kappa+1,), nblocks, block_part)``."""
    blocks_pp = max(1, math.ceil(int(part_nnz.max(initial=0)) / block_p))
    if schedule == "rect":
        part_blocks = np.full(kappa, blocks_pp, dtype=np.int64)
    else:
        part_blocks = np.maximum(1, -(-part_nnz // block_p))
    block_start = np.concatenate([[0], np.cumsum(part_blocks)])  # (kappa+1,)
    nblocks = int(block_start[-1])
    block_part = np.repeat(np.arange(kappa), part_blocks).astype(np.int32)
    return blocks_pp, block_start, nblocks, block_part


def _slots_for(indices_d: np.ndarray, part_of_vertex: np.ndarray,
               part_nnz: np.ndarray, block_start: np.ndarray,
               block_p: int) -> np.ndarray:
    """Element -> physical slot (the order-dependent half of a plan).

    Stable rank within the owning partition (sorted by partition, ranks in
    element order), then ``slot = block_start[j] * P + rank``. Value-equal
    to :func:`plan_mode_reference`'s two-gather formulation, but as one
    per-partition offset repeat + one scatter over narrow dtypes.
    """
    nnz = indices_d.shape[0]
    part_of_elem = part_of_vertex[indices_d]
    order = np.argsort(part_of_elem, kind="stable")  # radix on narrow ints
    # In partition-sorted order, slot = arange + (partition's first slot -
    # partition's first element rank); scatter back to element order.
    part_starts = np.concatenate([[0], np.cumsum(part_nnz[:-1])])
    offs = block_start[:-1] * block_p - part_starts    # (kappa,)
    padded = int(block_start[-1]) * block_p
    dtype = np.int32 if padded < 2**31 else np.int64
    slot_sorted = (np.arange(nnz, dtype=dtype)
                   + np.repeat(offs.astype(dtype), part_nnz))
    slot_of_elem = np.empty(nnz, dtype=dtype)
    slot_of_elem[order] = slot_sorted
    return slot_of_elem


def plan_mode(
    indices_d: np.ndarray,
    dim: int,
    mode: int,
    kappa: int | None = None,
    rows_pp: int | None = None,
    block_p: int = DEFAULT_BLOCK_P,
    schedule: str = DEFAULT_SCHEDULE,
    degrees: np.ndarray | None = None,
) -> ModePlan:
    """Run Alg. 1 for one mode and derive the block-scheduled kernel layout.

    Vectorized cold path: narrow (int32/uint16) sort keys and a single
    rank scatter — bitwise-identical plans to the pre-autotuner
    :func:`plan_mode_reference` (property-tested), ~2x faster on skewed
    benchmark tensors.

    Args:
      indices_d: (nnz,) mode-d index of every nonzero.
      dim: I_d.
      mode: d (bookkeeping only).
      kappa: partition count; default sized so row tiles fit VMEM.
      rows_pp: rows per partition; derived from kappa when not given.
      schedule: ``"compact"`` emits only real blocks plus the block->
        partition descriptor; ``"rect"`` pads every partition to the max
        partition's block count (the comparison baseline).
      degrees: optional precomputed ``np.bincount(indices_d, minlength=dim)``
        — the plan cache computes per-mode degrees for its signature and
        hands them down so a cache miss never re-counts.
    """
    if schedule not in SCHEDULES:
        raise ValueError(f"schedule {schedule!r} not in {SCHEDULES}")
    # build_flycoo hands us column views of a (nnz, N) array; the fancy
    # gathers below are ~10% faster on a contiguous copy.
    indices_d = np.ascontiguousarray(indices_d)
    if kappa is None:
        kappa = choose_kappa(dim, rows_pp or DEFAULT_ROWS_PER_PARTITION)
    kappa = min(kappa, dim)  # never more partitions than rows
    rows_pp = math.ceil(dim / kappa)

    # --- Alg. 1 step 1: vertices sorted by degree (descending, stable). ---
    if degrees is None:
        degrees = np.bincount(indices_d, minlength=dim)
    neg = -degrees.astype(np.int32) if degrees.max(initial=0) < 2**31 \
        else -degrees
    vsort = np.argsort(neg, kind="stable")  # (I_d,) vertex ids

    # --- Alg. 1 step 2: cyclic deal over kappa partitions. ---
    # vertex vsort[i] -> partition i % kappa, local row i // kappa.
    rank = np.arange(dim, dtype=np.int32)
    part_of_rank = rank % kappa
    row_relabel = np.empty(dim, dtype=np.int32)
    row_relabel[vsort] = part_of_rank * rows_pp + rank // kappa
    part_of_vertex = np.empty(dim, dtype=_part_dtype(kappa))
    part_of_vertex[vsort] = part_of_rank.astype(part_of_vertex.dtype)

    # --- Alg. 1 step 3: collect hyperedges per partition; assign remap ids.
    # Partition loads come from the dealt degrees directly (column sums of
    # the rank-major deal) — no second nnz-sized bincount needed.
    dsort = degrees[vsort]
    pad = (-dim) % kappa
    if pad:
        dsort = np.concatenate([dsort, np.zeros(pad, dtype=dsort.dtype)])
    part_nnz = dsort.reshape(-1, kappa).sum(axis=0, dtype=np.int64)
    blocks_pp, block_start, nblocks, block_part = _block_layout(
        part_nnz, kappa, block_p, schedule)
    slot_of_elem = _slots_for(indices_d, part_of_vertex, part_nnz,
                              block_start, block_p)

    return ModePlan(
        mode=mode,
        kappa=int(kappa),
        rows_pp=int(rows_pp),
        block_p=int(block_p),
        blocks_pp=int(blocks_pp),
        dim=int(dim),
        schedule=schedule,
        nblocks=nblocks,
        row_relabel=row_relabel,
        slot_of_elem=slot_of_elem,
        part_nnz=part_nnz,
        block_part=block_part,
        max_degree=int(degrees.max(initial=0)),
    )


# --------------------------------------------------------------------------
# Partition-aligned chunking of a block schedule (the out-of-core tier).
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ChunkSchedule:
    """Partition-aligned slicing of one mode's block schedule into chunks.

    Chunk ``c`` owns partitions ``[part_start[c], part_start[c+1])`` whose
    blocks are contiguous in the (partition-major) kernel layout, starting
    at global block ``block_start[c]`` — so a chunk is a contiguous slot
    range ``[block_start[c]*P, block_start[c+1]*P)`` of the mode's layout.
    Because every output row is owned by exactly one partition (paper
    Observation 2), per-chunk elementwise computations touch disjoint
    output rows and concatenate bitwise-exactly into the full result.

    All chunks are padded to the uniform ``(chunk_kappa, chunk_blocks)``
    shape (max real partitions / blocks of any chunk) so the streaming
    engine compiles ONE program per mode; pad blocks repeat the last real
    local partition (descriptor stays nondecreasing) and carry all-pad
    slots.
    """

    part_start: np.ndarray      # (nchunks+1,) int64 partition boundaries
    block_start: np.ndarray     # (nchunks+1,) int64 global block boundaries
    chunk_kappa: int            # uniform (max) partitions per chunk
    chunk_blocks: int           # uniform (max) real blocks per chunk
    block_p: int

    @property
    def nchunks(self) -> int:
        return len(self.part_start) - 1

    @property
    def chunk_slots(self) -> int:
        """Uniform padded slot count of one resident chunk."""
        return self.chunk_blocks * self.block_p

    def bounds(self, c: int) -> tuple[int, int, int, int]:
        """``(p0, p1, b0, b1)`` — chunk ``c``'s partition and block range."""
        return (int(self.part_start[c]), int(self.part_start[c + 1]),
                int(self.block_start[c]), int(self.block_start[c + 1]))


def chunk_schedule(plan: ModePlan, target_slots: int) -> ChunkSchedule:
    """Greedily pack whole partitions into chunks of <= ``target_slots``
    kernel slots (min one partition per chunk, so a partition larger than
    the target still forms a — then oversized — chunk of its own).

    Works for both schedules: the per-partition block counts come from the
    ``block_part`` descriptor, which ``rect`` materializes too.
    """
    target_blocks = max(1, target_slots // plan.block_p)
    part_blocks = np.bincount(plan.block_part, minlength=plan.kappa)
    starts = [0]
    acc = 0
    for j in range(plan.kappa):
        nb = int(part_blocks[j])
        if acc and acc + nb > target_blocks:
            starts.append(j)
            acc = 0
        acc += nb
    starts.append(plan.kappa)
    part_start = np.asarray(starts, dtype=np.int64)
    cum_blocks = np.concatenate([[0], np.cumsum(part_blocks)])
    block_start = cum_blocks[part_start]
    chunk_kappa = int(np.diff(part_start).max())
    chunk_blocks = int(np.diff(block_start).max())
    return ChunkSchedule(part_start=part_start, block_start=block_start,
                         chunk_kappa=chunk_kappa, chunk_blocks=chunk_blocks,
                         block_p=plan.block_p)


def chunk_bpart(plan: ModePlan, cs: ChunkSchedule, c: int) -> np.ndarray:
    """Chunk-local block -> partition descriptor, rebased to the chunk's
    first partition and padded to the uniform ``chunk_blocks`` length (pad
    blocks repeat the last real local partition, as in the distributed
    engine's device-local descriptors)."""
    p0, _, b0, b1 = cs.bounds(c)
    seg = plan.block_part[b0:b1].astype(np.int32) - np.int32(p0)
    out = np.empty(cs.chunk_blocks, dtype=np.int32)
    out[:len(seg)] = seg
    out[len(seg):] = seg[-1]
    return out


def plan_from_structure(indices_d: np.ndarray, base: ModePlan) -> ModePlan:
    """Rebuild a plan for a *reordered* element list from a cached one.

    Everything order-invariant — the degree sort, the cyclic deal, the
    relabeling and the block layout — is reused from ``base`` verbatim
    (shared arrays); only the order-dependent ``slot_of_elem`` is
    recomputed. Caller must guarantee ``indices_d`` has exactly ``base``'s
    degree multiset per vertex (the plan cache verifies per-mode degree
    equality before taking this path); the result is then bitwise-equal to
    a cold :func:`plan_mode` on ``indices_d``.
    """
    part_of_vertex = (base.row_relabel // base.rows_pp).astype(
        _part_dtype(base.kappa))
    block_start = np.concatenate(
        [[0], np.cumsum(np.bincount(base.block_part,
                                    minlength=base.kappa))])
    slot_of_elem = _slots_for(np.asarray(indices_d), part_of_vertex,
                              base.part_nnz, block_start, base.block_p)
    return dataclasses.replace(base, slot_of_elem=slot_of_elem)


def plan_mode_reference(
    indices_d: np.ndarray,
    dim: int,
    mode: int,
    kappa: int | None = None,
    rows_pp: int | None = None,
    block_p: int = DEFAULT_BLOCK_P,
    schedule: str = DEFAULT_SCHEDULE,
) -> ModePlan:
    """Pre-autotuner ``plan_mode`` kept verbatim: the bitwise parity oracle
    for the vectorized path and the fig10 cold-plan speedup baseline
    (CI gates the vectorized path at >= 2x on the zipf dataset)."""
    if schedule not in SCHEDULES:
        raise ValueError(f"schedule {schedule!r} not in {SCHEDULES}")
    indices_d = np.asarray(indices_d, dtype=np.int64)
    nnz = indices_d.shape[0]
    if kappa is None:
        kappa = choose_kappa(dim, rows_pp or DEFAULT_ROWS_PER_PARTITION)
    kappa = min(kappa, dim)  # never more partitions than rows
    rows_pp = math.ceil(dim / kappa)

    degrees = np.bincount(indices_d, minlength=dim)
    vsort = np.argsort(-degrees, kind="stable")  # (I_d,) vertex ids

    part_of_rank = np.arange(dim) % kappa
    local_of_rank = np.arange(dim) // kappa
    row_relabel = np.empty(dim, dtype=np.int64)
    row_relabel[vsort] = part_of_rank * rows_pp + local_of_rank
    part_of_vertex = np.empty(dim, dtype=np.int64)
    part_of_vertex[vsort] = part_of_rank

    part_of_elem = part_of_vertex[indices_d]
    part_nnz = np.bincount(part_of_elem, minlength=kappa)

    blocks_pp = max(1, math.ceil(int(part_nnz.max(initial=0)) / block_p))
    if schedule == "rect":
        part_blocks = np.full(kappa, blocks_pp, dtype=np.int64)
    else:
        part_blocks = np.maximum(1, -(-part_nnz // block_p))
    block_start = np.concatenate([[0], np.cumsum(part_blocks)])  # (kappa+1,)
    nblocks = int(block_start[-1])
    block_part = np.repeat(np.arange(kappa), part_blocks).astype(np.int32)

    order = np.argsort(part_of_elem, kind="stable")
    rank_within = np.empty(nnz, dtype=np.int64)
    part_starts = np.concatenate([[0], np.cumsum(part_nnz)])
    rank_within[order] = np.arange(nnz) - part_starts[part_of_elem[order]]
    slot_of_elem = block_start[part_of_elem] * block_p + rank_within

    return ModePlan(
        mode=mode,
        kappa=int(kappa),
        rows_pp=int(rows_pp),
        block_p=int(block_p),
        blocks_pp=int(blocks_pp),
        dim=int(dim),
        schedule=schedule,
        nblocks=nblocks,
        row_relabel=row_relabel.astype(np.int32),
        slot_of_elem=slot_of_elem,
        part_nnz=part_nnz,
        block_part=block_part,
        max_degree=int(degrees.max(initial=0)),
    )
