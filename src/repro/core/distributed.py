"""Distributed spMTTKRP via shard_map (DESIGN.md §6).

Cluster-scope version of the paper's Observation 2: partitions (and hence
their owned output rows) are dealt to devices along the ``data`` axis, so
elementwise computation needs NO cross-device reduction — each device
segment-sums into rows it exclusively owns. The rank dimension optionally
shards over ``model`` (MTTKRP is embarrassingly parallel over rank; only
the R x R grams need cross-rank collectives, and R is tiny).

Dynamic remapping across devices (an element's next-mode partition may live
on another device) is a static permutation; the baseline implementation
exchanges via all_gather + local scatter-slice. A collective_permute
schedule over the known exchange pattern is the documented optimization.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .flycoo import FlycooTensor, build_flycoo
from .mttkrp import compute_lrow

try:
    from jax import shard_map as _shard_map

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map_old(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=False)

P = jax.sharding.PartitionSpec


def build_sharded_flycoo(indices, values, dims, n_dev: int,
                         rows_pp: int = 512, block_p: int = 128,
                         parts_per_dev: int | None = None) -> FlycooTensor:
    """FLYCOO preprocessing with kappa forced to a multiple of n_dev, so
    each device owns an equal, contiguous run of partitions/rows/slots."""
    import math

    from .partition import plan_mode

    indices = np.asarray(indices, np.int32)
    values = np.asarray(values, np.float32)
    plans = []
    for d in range(len(dims)):
        kappa = max(1, math.ceil(dims[d] / rows_pp))
        kappa = max(n_dev, ((kappa + n_dev - 1) // n_dev) * n_dev)
        plans.append(plan_mode(indices[:, d], int(dims[d]), d, kappa=kappa,
                               block_p=block_p))
    t = FlycooTensor(tuple(int(x) for x in dims), indices, values, plans)
    return t


class DistributedMTTKRP:
    """Alg. 5 with partitions sharded over the mesh's ``data`` axis and
    (optionally) rank over ``model``."""

    def __init__(self, tensor: FlycooTensor, mesh, data_axis: str = "data",
                 model_axis: str | None = None):
        self.tensor = tensor
        self.mesh = mesh
        self.da = data_axis
        self.ma = model_axis
        self.n_dev = mesh.shape[data_axis]
        for p in tensor.plans:
            assert p.kappa % self.n_dev == 0, (p.kappa, self.n_dev)
        self.row_relabel = [jnp.asarray(p.row_relabel) for p in tensor.plans]
        arrs = tensor.layout_arrays(0)
        alpha = np.stack([self._alpha_for_mode(d)
                          for d in range(tensor.nmodes)], axis=1)
        dev = jax.sharding.NamedSharding(mesh, P(data_axis))
        dev2 = jax.sharding.NamedSharding(mesh, P(data_axis, None))
        self.layout = {
            "val": jax.device_put(jnp.asarray(arrs["val"]), dev),
            "idx": jax.device_put(jnp.asarray(arrs["idx"]), dev2),
            "alpha": jax.device_put(jnp.asarray(alpha), dev2),
        }
        self.current_mode = 0

    def _alpha_for_mode(self, d: int) -> np.ndarray:
        p0, pd = self.tensor.plans[0], self.tensor.plans[d]
        col = np.full(p0.padded_nnz, -1, dtype=np.int32)
        col[p0.slot_of_elem] = pd.slot_of_elem.astype(np.int32)
        return col

    def step(self, factors):
        d = self.current_mode
        plan = self.tensor.plans[d]
        nxt = (d + 1) % self.tensor.nmodes
        nplan = self.tensor.plans[nxt]
        out_rel, self.layout = _sharded_mode_step(
            self.layout, tuple(factors), self.row_relabel[d],
            mesh=self.mesh, da=self.da, ma=self.ma, mode=d,
            rows_pp=plan.rows_pp, blocks_pp=plan.blocks_pp,
            block_p=plan.block_p, kappa=plan.kappa,
            next_size=nplan.padded_nnz, nmodes=self.tensor.nmodes)
        out = jnp.take(out_rel, self.row_relabel[d], axis=0)
        self.current_mode = nxt
        return out

    def all_modes(self, factors):
        assert self.current_mode == 0
        return [self.step(factors) for _ in range(self.tensor.nmodes)]


@functools.partial(
    jax.jit,
    static_argnames=("mesh", "da", "ma", "mode", "rows_pp", "blocks_pp",
                     "block_p", "kappa", "next_size", "nmodes"))
def _sharded_mode_step(layout, factors, row_relabel_d, *, mesh, da, ma,
                       mode, rows_pp, blocks_pp, block_p, kappa, next_size,
                       nmodes):
    n_dev = mesh.shape[da]
    kappa_loc = kappa // n_dev
    stride = blocks_pp * block_p

    fac_spec = P(None, ma) if ma else P(None, None)

    def local_fn(val, idx, alpha, rr, *facs):
        # ---- elementwise computation on owned partitions (Obs. 2). ----
        alive = alpha[:, mode] >= 0
        lrow = compute_lrow(idx[:, mode], rr, rows_pp, alive)
        partials = val[:, None].astype(jnp.float32)
        for w, f in enumerate(facs):
            if w == mode:
                continue
            partials = partials * jnp.take(f, idx[:, w], axis=0,
                                           mode="fill", fill_value=0.0)
        slot = jnp.arange(val.shape[0], dtype=jnp.int32)
        part = slot // stride                      # local partition id
        gid = jnp.where(lrow < 0, 0, part * rows_pp + lrow)
        out_loc = jax.ops.segment_sum(
            partials, gid, num_segments=kappa_loc * rows_pp)

        # ---- dynamic remapping (Obs. 1): static permutation exchange. ----
        # Baseline: all_gather elements, scatter into the full next layout,
        # keep the local slice. (collective_permute schedule = future opt.)
        vg = jax.lax.all_gather(val, da, tiled=True)
        ig = jax.lax.all_gather(idx, da, tiled=True)
        ag = jax.lax.all_gather(alpha, da, tiled=True)
        alive_g = ag[:, mode] >= 0
        dst = jnp.where(alive_g, ag[:, (mode + 1) % nmodes], next_size)
        nval = jnp.zeros((next_size,), jnp.float32).at[dst].set(
            vg, mode="drop")
        nidx = jnp.zeros((next_size, nmodes), jnp.int32).at[dst].set(
            ig, mode="drop")
        nalpha = jnp.full((next_size, nmodes), -1, jnp.int32).at[dst].set(
            ag, mode="drop")
        shard_sz = next_size // n_dev
        me = jax.lax.axis_index(da)
        sl = lambda a: jax.lax.dynamic_slice_in_dim(  # noqa: E731
            a, me * shard_sz, shard_sz, axis=0)
        return out_loc, sl(nval), sl(nidx), sl(nalpha)

    out_specs = (P(da, ma) if ma else P(da, None),
                 P(da), P(da, None), P(da, None))
    out_loc, nval, nidx, nalpha = shard_map(
        local_fn, mesh,
        in_specs=(P(da), P(da, None), P(da, None), P(None),
                  *([fac_spec] * len(factors))),
        out_specs=out_specs,
    )(layout["val"], layout["idx"], layout["alpha"], row_relabel_d,
      *factors)
    return out_loc, {"val": nval, "idx": nidx, "alpha": nalpha}
