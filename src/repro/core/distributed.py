"""Distributed spMTTKRP — deprecated stateful shims over ``repro.engine.dist``.

The implementation moved to :mod:`repro.engine.dist`: a sharded pytree
``DistState`` (``shard_state``) executed by pure functions
(``dist_mttkrp`` / ``dist_all_modes`` — the latter ONE jitted ``lax.scan``
under ``shard_map``), with the dynamic remap exchanged via a precomputed
static ``collective_permute`` schedule instead of this module's original
``all_gather`` of the full element list (that baseline survives as
``DistConfig(exchange="all_gather")`` for measurement). See DESIGN.md §6
and the migration table in :mod:`repro.core`.

This module keeps the original surface alive:

  * :func:`build_sharded_flycoo` — FLYCOO preprocessing with per-device
    partition rounding, now delegating to
    :meth:`repro.engine.ExecutionConfig.kappa_for`;
  * :class:`DistributedMTTKRP` — a thin deprecation shim over the new
    subsystem (mirroring how ``MTTKRPExecutor`` shims ``repro.engine``).
    Unlike the original it works from *any* resident mode (the
    ``current_mode == 0`` assertion is gone) and gained ``reset()``.

New code should import from :mod:`repro.engine.dist`.
"""
from __future__ import annotations

import warnings
from typing import Sequence

import jax
import numpy as np

from repro import engine as _engine
from repro.engine import ExecutionConfig
from repro.engine.dist import (DistConfig, dist_all_modes, dist_mttkrp,
                               shard_map, shard_state)  # noqa: F401

from .flycoo import FlycooTensor
from .partition import plan_mode


def build_sharded_flycoo(indices, values, dims, n_dev: int,
                         rows_pp: int = 512, block_p: int = 128,
                         schedule: str | None = None) -> FlycooTensor:
    """FLYCOO preprocessing with kappa forced to a multiple of n_dev, so
    each device owns an equal, contiguous run of partitions (and hence
    rows and blocks — the compact schedule keeps blocks partition-major).
    The rounding rule lives in :meth:`ExecutionConfig.kappa_for`."""
    indices = np.asarray(indices, np.int32)
    values = np.asarray(values, np.float32)
    cfg = ExecutionConfig(rows_pp=rows_pp, block_p=block_p,
                          **({} if schedule is None
                             else {"schedule": schedule}))
    plans = [
        plan_mode(indices[:, d], int(dims[d]), d,
                  kappa=cfg.kappa_for(int(dims[d]), n_dev), block_p=block_p,
                  schedule=cfg.schedule)
        for d in range(len(dims))
    ]
    return FlycooTensor(tuple(int(x) for x in dims), indices, values, plans)


class DistributedMTTKRP:
    """DEPRECATED stateful wrapper around :mod:`repro.engine.dist`.

    Threads an immutable sharded ``DistState`` through the functional API.
    ``all_modes`` works from *any* resident mode and ``reset()`` returns to
    the pristine start-mode layout, matching the ``MTTKRPExecutor`` shim.
    The remap exchange defaults to the collective_permute schedule; pass
    ``exchange="all_gather"`` for the original baseline.
    """

    def __init__(self, tensor: FlycooTensor, mesh, data_axis: str = "data",
                 model_axis: str | None = None, exchange: str = "permute"):
        warnings.warn(
            "DistributedMTTKRP is deprecated; use repro.engine.dist "
            "(shard_state/dist_mttkrp/dist_all_modes) — see repro.core "
            "docstring for the migration table", DeprecationWarning,
            stacklevel=2)
        self.tensor = tensor
        self.mesh = mesh
        self.da = data_axis
        self.ma = model_axis
        self.n_dev = mesh.shape[data_axis]
        self.config = ExecutionConfig()
        self.dist = DistConfig(data_axis=data_axis, model_axis=model_axis,
                               exchange=exchange)
        self._dstate = shard_state(_engine.init(tensor, self.config), mesh,
                                   self.dist)
        self.row_relabel = list(self._dstate.relabel)

    # ------------------------------------------------------------ state view
    @property
    def state(self):
        """The underlying functional ``DistState`` (read-only)."""
        return self._dstate

    @property
    def current_mode(self) -> int:
        return self._dstate.mode

    @property
    def layout(self) -> dict:
        """Mesh-sharded global layout arrays (device-major numbering)."""
        return {"val": self._dstate.val, "idx": self._dstate.idx,
                "alpha": self._dstate.alpha}

    # ------------------------------------------------------------ execution
    def step(self, factors: Sequence[jax.Array]) -> jax.Array:
        """MTTKRP for the current mode + cross-device remap; rotate."""
        out, self._dstate = dist_mttkrp(self._dstate, tuple(factors))
        return out

    def all_modes(self, factors: Sequence[jax.Array]) -> list[jax.Array]:
        """All-modes MTTKRP (one scanned shard_map dispatch), from ANY
        current mode; returns outputs indexed by mode d."""
        outs, self._dstate = dist_all_modes(self._dstate, tuple(factors))
        return outs

    def reset(self) -> None:
        """Return to the pristine start-mode sharded layout."""
        self._dstate = shard_state(_engine.init(self.tensor, self.config),
                                   self.mesh, self.dist)
