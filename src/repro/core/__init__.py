"""Core paper contribution: FLYCOO-TPU spMTTKRP + CPD-ALS (see DESIGN.md)."""
from .flycoo import FlycooTensor, build_flycoo
from .partition import ModePlan, plan_mode, choose_kappa
from .mttkrp import MTTKRPExecutor, mttkrp_ref, mode_step
from .cpd import CPDResult, cp_als, cp_als_reference, init_factors
from . import datasets

__all__ = [
    "FlycooTensor", "build_flycoo", "ModePlan", "plan_mode", "choose_kappa",
    "MTTKRPExecutor", "mttkrp_ref", "mode_step", "CPDResult", "cp_als",
    "cp_als_reference", "init_factors", "datasets",
]
