"""Core paper contribution: FLYCOO-TPU spMTTKRP + CPD-ALS (see DESIGN.md).

Engine API
----------
The spMTTKRP execution engine is functional (:mod:`repro.engine`): a
pytree ``EngineState`` (layout arrays + relabel tables + static mode
plans) threaded through pure functions, with execution policy in a frozen
``ExecutionConfig`` (backend registry ``xla | pallas | pallas_fused |
ref``, interpret, block_p, kappa policy, VMEM budget, precision, donation,
remap fusion):

    from repro import engine
    from repro.engine import ExecutionConfig

    state = engine.init(tensor, ExecutionConfig(backend="pallas"))
    out, state = engine.mttkrp(state, factors)       # one mode + remap
    outs, state = engine.all_modes(state, factors)   # ONE jitted lax.scan

``engine.all_modes`` runs the whole mode rotation (paper Alg. 5) as a
single jitted ``lax.scan`` with donated layout buffers — the T_in/T_out
swap without host round-trips — and works from any resident mode.

``backend="pallas_fused"`` selects the zero-HBM-intermediate Pallas
pipeline: factor rows are gathered *inside* the kernel grid (no
``(S, N-1, R)`` HBM intermediate) and the Alg. 3 remap scatter is emitted
by the same kernel pass (``ExecutionConfig(fuse_remap=False)`` restores
the XLA scatter path for comparison). ``backend="pallas"`` remains the
unfused-gather baseline the paper's fusion argument is measured against.

Plan factory, cache, and autotuner
---------------------------------
Raw COO -> running engine is one declarative call. A frozen
``engine.PlanSpec`` names every searchable knob (backend, schedule,
block_p, kappa policy, rows_pp, VMEM budget, dedup, fuse_remap,
exchange) and ``engine.make_engine`` replaces the scattered
build_flycoo/ExecutionConfig/shard_state plumbing:

    from repro.engine import PlanSpec, PlanSpace, make_engine, autotune

    state = make_engine((indices, values, dims),
                        PlanSpec(backend="pallas_fused", block_p=256))
    dstate = make_engine((indices, values, dims), spec, mesh=mesh)

``make_engine`` routes layout construction through a host-side
**plan cache** (:mod:`repro.core.plancache`) keyed on a sparsity
signature (dims, nnz, quantized per-mode degree histograms): an
identical element list is an identity hit (>= 10x faster than even the
vectorized cold plan; CI-gated), a permuted one is a structural hit
that rebuilds only ``slot_of_elem`` via ``plan_from_structure``, and
cached plans are bitwise-equal to freshly built ones. Pass
``cache=False`` to force a cold build, or your own ``PlanCache`` to
scope eviction. ``engine.autotune.autotune(indices, values, dims,
PlanSpace(...))`` searches the knob space per tensor: an analytic cost
model over nnz-per-slice histograms ranks the space, exact modeled
cost (pad slots + dedup DMA rows) picks the winner — never worse than
the default spec — and an optional measured hill-climb refines it,
deterministically under a fixed seed.

Multi-device execution lives in :mod:`repro.engine.dist`: ``shard_state``
places an ``EngineState`` over a mesh's ``data`` axis and
``dist_all_modes`` runs the rotation as one scanned ``shard_map`` program,
exchanging the remap via a precomputed static ``collective_permute``
schedule (the old per-mode ``all_gather`` of the full element list remains
as ``DistConfig(exchange="all_gather")`` for comparison).

Migration from the deprecated stateful executors:

  ===============================  =====================================
  old (stateful, deprecated)       new (functional)
  ===============================  =====================================
  ``MTTKRPExecutor(t, backend=b)`` ``s = engine.init(t,
                                   ExecutionConfig(backend=b))``
  ``exe.step(factors)``            ``out, s = engine.mttkrp(s, factors)``
  ``exe.all_modes(factors)``       ``outs, s = engine.all_modes(s,
                                   factors)``
  ``exe.layout`` / ``current_mode``  ``s.val``/``s.idx``/``s.alpha`` /
                                     ``s.mode``
  ``backend="..."`` kwargs         ``ExecutionConfig`` + backend registry
  ``DistributedMTTKRP(t, mesh)``   ``ds = engine.dist.shard_state(
                                   engine.init(t), mesh)``
  ``dist_exe.step(factors)``       ``out, ds = engine.dist.dist_mttkrp(
                                   ds, factors)``
  ``dist_exe.all_modes(factors)``  ``outs, ds = engine.dist.
                                   dist_all_modes(ds, factors)``
  ===============================  =====================================
"""
from .flycoo import FlycooTensor, build_flycoo
from .partition import ModePlan, plan_mode, choose_kappa
from .mttkrp import MTTKRPExecutor, mttkrp_ref, mode_step
from .cpd import CPDResult, cp_als, cp_als_reference, init_factors
from . import datasets

__all__ = [
    "FlycooTensor", "build_flycoo", "ModePlan", "plan_mode", "choose_kappa",
    "MTTKRPExecutor", "mttkrp_ref", "mode_step", "CPDResult", "cp_als",
    "cp_als_reference", "init_factors", "datasets",
]
