"""Host-side plan cache keyed on a tensor sparsity signature.

The fig10 preprocessing wall is ``build_flycoo``: every mode pays a degree
sort plus a partition sort over the nonzeros. In the streaming regime
(AMPED, arxiv 2507.15121) the same tensor — or a reordered/re-sampled
tensor with the *same sparsity structure* — is decomposed repeatedly, so
re-planning from scratch is pure waste. This module caches ``ModePlan``
lists and serves them back at three levels:

``hit`` (identity)
    The exact same element list (bitwise-equal ``indices``) was planned
    before under the same knobs: the cached plans are returned verbatim.
    Cost is one ``memcmp``-speed array compare — no histogram, no sort.
    This is the >= 10x path CI gates.

``structural`` (signature)
    A *permutation* of a previously planned tensor (same per-mode degree
    vectors, different element order): everything order-invariant — the
    degree sort, the cyclic deal, the relabeling, the block layout — is
    reused and only ``slot_of_elem`` is rebuilt
    (:func:`repro.core.partition.plan_from_structure`). The result is
    bitwise-equal to a cold plan of the reordered list (property-tested).

``miss``
    Cold :func:`repro.core.flycoo.build_flycoo`, with the per-mode degree
    histograms the cache computed for its signature handed down so the
    cold path never re-counts.

The **sparsity signature** is ``(dims, nnz, per-mode quantized degree
histograms)`` — the histogram buckets nnz-per-slice counts by
``floor(log2(degree))``, so it is invariant under nnz-order permutation
and cheap to compare; structural hits are then *verified* by exact
per-mode degree equality before any plan is reused (each mode's plan
structure is a function of that mode's degree vector alone, so equality
is sufficient for bitwise-correct reuse).
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
from typing import Sequence

import numpy as np

from repro.obs.metrics import counter as _obs_counter
from repro.obs.trace import span as _obs_span
from repro.resilience import chaos as _chaos
from repro.resilience.snapshot import payload_digest

from .flycoo import FlycooTensor, build_flycoo
from .partition import ModePlan, plan_from_structure


def sparsity_signature(
    indices: np.ndarray,
    dims: Sequence[int],
    degrees: Sequence[np.ndarray] | None = None,
) -> tuple:
    """Permutation-invariant sparsity signature of a COO tensor.

    ``(dims, nnz, per-mode histogram of floor(log2(degree)) buckets)`` as
    a nested tuple (hashable — usable as a dict key). Tensors that differ
    in dims, nnz, or any mode's quantized nnz-per-slice histogram are
    guaranteed distinct; equal signatures are a *candidate* match only
    (the cache verifies exact degree equality before reuse).
    """
    indices = np.asarray(indices)
    nnz, n = indices.shape
    if degrees is None:
        degrees = [np.bincount(indices[:, d], minlength=int(dims[d]))
                   for d in range(n)]
    hists = []
    for d in range(n):
        deg = degrees[d]
        pos = deg[deg > 0]
        # bucket by floor(log2(degree)): 64 buckets cover any int64 degree
        buckets = np.bincount(
            np.log2(pos.astype(np.float64)).astype(np.int64), minlength=1)
        hists.append(tuple(int(c) for c in buckets))
    return (tuple(int(x) for x in dims), int(nnz), tuple(hists))


def _blob_payload_order(arrays: dict, nmodes: int) -> dict:
    """The canonical array order the disk-blob digest is computed over —
    identical at save and load time regardless of npz member order."""
    ordered = {"indices": arrays["indices"], "meta": arrays["meta"]}
    for d in range(nmodes):
        for part in ("relabel", "slot", "partnnz", "bpart"):
            ordered[f"{part}{d}"] = arrays[f"{part}{d}"]
    return ordered


@dataclasses.dataclass
class _Entry:
    """One cached element list: its indices (for the identity compare),
    per-mode degrees (for structural verification + cold-path handdown),
    and plans per knob setting."""

    indices: np.ndarray                       # (nnz, N) int32 canonical
    degrees: list[np.ndarray]                 # per-mode bincounts
    hist_key: tuple                           # quantized-histogram part
    plans: dict[tuple, list[ModePlan]]        # knob key -> per-mode plans


class PlanCache:
    """In-process plan cache; see module docstring for the three levels.

    ``get_tensor`` is a drop-in for :func:`build_flycoo`; inspect
    ``last_outcome`` (``"hit" | "structural" | "miss"``) and the
    ``hits/structural_hits/misses`` counters for cache behavior.

    With ``path=<dir>`` the cache also persists across processes: every
    cold plan is written as a content-addressed npz blob (key = sha256 of
    dims/nnz/knobs + the exact per-mode degree vectors) with an atomic
    tmp-then-rename, and an in-memory miss falls back to loading the blob
    before re-planning — a streaming run never pays the fig10 plan wall
    twice. Disk loads count as ``hit`` (stored element list bitwise-equal)
    or ``structural`` (same degrees, new order: ``slot_of_elem`` rebuilt),
    exactly mirroring the in-memory levels; ``disk_loads`` / ``disk_saves``
    count the traffic.
    """

    def __init__(self, max_entries: int = 32, path: str | None = None):
        self.max_entries = max_entries
        self.path = os.fspath(path) if path is not None else None
        self._by_key: dict[tuple, list[_Entry]] = {}
        self._order: list[tuple] = []          # FIFO eviction
        self.hits = 0
        self.structural_hits = 0
        self.misses = 0
        self.disk_loads = 0
        self.disk_saves = 0
        self.disk_corrupt = 0
        self.last_outcome: str | None = None
        self._stream_plans: dict[str, object] = {}
        self.stream_hits = 0
        self.stream_misses = 0

    # ------------------------------------------------------------------ api
    def get_tensor(
        self,
        indices: np.ndarray,
        values: np.ndarray,
        dims: Sequence[int],
        kappa: int | Sequence[int] | None = None,
        rows_pp: int | None = None,
        block_p: int = 128,
        schedule: str = "compact",
    ) -> FlycooTensor:
        with _obs_span("plan.cache_lookup") as sp:
            t = self._get_tensor(indices, values, dims, kappa=kappa,
                                 rows_pp=rows_pp, block_p=block_p,
                                 schedule=schedule)
            sp.set("outcome", self.last_outcome)
            _obs_counter(
                "plan_cache_outcomes",
                "plan cache lookups by level (hit/structural/miss)",
            ).inc(self.last_outcome)
            return t

    def _get_tensor(
        self,
        indices: np.ndarray,
        values: np.ndarray,
        dims: Sequence[int],
        kappa: int | Sequence[int] | None = None,
        rows_pp: int | None = None,
        block_p: int = 128,
        schedule: str = "compact",
    ) -> FlycooTensor:
        indices = np.ascontiguousarray(np.asarray(indices, dtype=np.int32))
        dims_t = tuple(int(x) for x in dims)
        nnz = int(indices.shape[0])
        key = (dims_t, nnz)
        knob_kappa = (kappa if kappa is None or np.isscalar(kappa)
                      else tuple(int(k) for k in kappa))
        knobs = (knob_kappa, rows_pp, int(block_p), schedule)
        entries = self._by_key.get(key, [])

        # -- level 1: identity hit (bitwise-equal element list) ----------
        for e in entries:
            if e.indices is indices or np.array_equal(e.indices, indices):
                plans = e.plans.get(knobs)
                if plans is not None:
                    self.hits += 1
                    self.last_outcome = "hit"
                    return build_flycoo(indices, values, dims_t,
                                        plans=plans)
                # known structure under new knobs: try disk, else
                # cold-plan reusing the degree histograms (skips every
                # bincount)
                t = self._disk_load(indices, values, dims_t, knobs,
                                    e.degrees, schedule)
                if t is not None:
                    e.plans[knobs] = t.plans
                    return t
                t = build_flycoo(indices, values, dims_t, kappa=kappa,
                                 rows_pp=rows_pp, block_p=block_p,
                                 schedule=schedule, degrees=e.degrees)
                e.plans[knobs] = t.plans
                self._disk_save(t, knobs, e.degrees)
                self.misses += 1
                self.last_outcome = "miss"
                return t

        # -- level 2: structural hit (same degrees, new element order) ---
        idx_t = np.ascontiguousarray(indices.T)
        degrees = [np.bincount(idx_t[d], minlength=dims_t[d])
                   for d in range(indices.shape[1])]
        _, _, hist_key = sparsity_signature(indices, dims_t,
                                            degrees=degrees)
        for e in entries:
            if e.hist_key != hist_key:
                continue
            if not all(np.array_equal(a, b)
                       for a, b in zip(e.degrees, degrees)):
                continue
            base = e.plans.get(knobs)
            if base is None:
                continue
            plans = [plan_from_structure(idx_t[d], base[d])
                     for d in range(indices.shape[1])]
            self._insert(key, _Entry(indices, e.degrees, hist_key,
                                     {knobs: plans}))
            self.structural_hits += 1
            self.last_outcome = "structural"
            return build_flycoo(indices, values, dims_t, plans=plans)

        # -- level 2.5: disk blob (persisted by an earlier process) ------
        t = self._disk_load(indices, values, dims_t, knobs, degrees,
                            schedule)
        if t is not None:
            self._insert(key, _Entry(t.indices, degrees, hist_key,
                                     {knobs: t.plans}))
            return t

        # -- level 3: miss (cold plan; degrees handed down) --------------
        t = build_flycoo(indices, values, dims_t, kappa=kappa,
                         rows_pp=rows_pp, block_p=block_p,
                         schedule=schedule, degrees=degrees)
        self._insert(key, _Entry(t.indices, degrees, hist_key,
                                 {knobs: t.plans}))
        self._disk_save(t, knobs, degrees)
        self.misses += 1
        self.last_outcome = "miss"
        return t

    def get_stream_plan(self, key: str, builder):
        """Structural tier for streamed chunk plans: ``key`` digests the
        plan geometry + chunk-sizing knobs (``engine.stream.
        _stream_plan_key``); ``builder`` runs on a miss. A degraded
        replan (chunk-budget halving) whose budget point was chunked
        before — or a re-init/resume of the same tensor — returns the
        memoized ``StreamPlan`` (frozen, safely shared). Outcomes land on
        the ``stream_replan_outcomes`` obs counter."""
        plan = self._stream_plans.get(key)
        outcome = "hit" if plan is not None else "miss"
        if plan is None:
            plan = builder()
            self._stream_plans[key] = plan
            self.stream_misses += 1
        else:
            self.stream_hits += 1
        _obs_counter(
            "stream_replan_outcomes",
            "streamed chunk-plan lookups by level (hit/miss)",
        ).inc(outcome)
        return plan

    def stats(self) -> dict:
        return {
            "hits": self.hits,
            "structural_hits": self.structural_hits,
            "misses": self.misses,
            "disk_loads": self.disk_loads,
            "disk_saves": self.disk_saves,
            "disk_corrupt": self.disk_corrupt,
            "stream_hits": self.stream_hits,
            "stream_misses": self.stream_misses,
            "entries": sum(len(v) for v in self._by_key.values()),
        }

    def clear(self) -> None:
        self._by_key.clear()
        self._order.clear()
        self._stream_plans.clear()

    # ------------------------------------------------------- disk persistence
    def _disk_key(self, dims_t: tuple, nnz: int, knobs: tuple,
                  degrees: Sequence[np.ndarray]) -> str:
        """Content address: dims/nnz/knobs plus the exact per-mode degree
        vectors — permutations of one tensor share a blob (structural
        reuse across processes), different sparsity never collides."""
        h = hashlib.sha256()
        h.update(repr((dims_t, nnz, knobs)).encode())
        for deg in degrees:
            h.update(np.ascontiguousarray(deg, dtype=np.int64).tobytes())
        return h.hexdigest()

    def _disk_load(self, indices, values, dims_t, knobs, degrees,
                   schedule) -> FlycooTensor | None:
        """Load-on-miss: reconstruct plans from a persisted blob, serving
        an identity hit (stored element list bitwise-equal) or a
        structural one (``slot_of_elem`` rebuilt for the new order).

        Every load is checksum-verified against the digest the blob was
        written with (:func:`repro.resilience.snapshot.payload_digest`).
        A torn, truncated, or bit-rotten blob — anything that fails to
        parse or verify — is *quarantined* (renamed ``*.corrupt``) and
        the lookup falls through to a cold rebuild, which re-persists a
        fresh blob: the disk tier self-heals instead of wedging the run.
        """
        if self.path is None:
            return None
        fn = os.path.join(
            self.path,
            self._disk_key(dims_t, len(indices), knobs, degrees) + ".npz")
        if not os.path.exists(fn):
            return None
        try:
            with np.load(fn) as blob:
                arrays = {name: blob[name] for name in blob.files}
            stored_idx = arrays["indices"]
            meta = arrays["meta"]
            stored_digest = bytes(arrays["digest"]).decode()
            ordered = _blob_payload_order(arrays, len(dims_t))
            if payload_digest(ordered) != stored_digest:
                raise ValueError(f"plan blob digest mismatch: {fn}")
            plans = []
            for d in range(indices.shape[1]):
                kappa, rows_pp, block_p, blocks_pp, dim, nblocks, \
                    max_degree = (int(x) for x in meta[d])
                plans.append(ModePlan(
                    mode=d, kappa=kappa, rows_pp=rows_pp, block_p=block_p,
                    blocks_pp=blocks_pp, dim=dim, schedule=schedule,
                    nblocks=nblocks, row_relabel=arrays[f"relabel{d}"],
                    slot_of_elem=arrays[f"slot{d}"],
                    part_nnz=arrays[f"partnnz{d}"],
                    block_part=arrays[f"bpart{d}"], max_degree=max_degree))
        except Exception:
            self._quarantine(fn)
            return None
        self.disk_loads += 1
        if np.array_equal(stored_idx, indices):
            self.hits += 1
            self.last_outcome = "hit"
        else:
            idx_t = np.ascontiguousarray(indices.T)
            plans = [plan_from_structure(idx_t[d], plans[d])
                     for d in range(indices.shape[1])]
            self.structural_hits += 1
            self.last_outcome = "structural"
        return build_flycoo(indices, values, dims_t, plans=plans)

    def _disk_save(self, t: FlycooTensor, knobs: tuple,
                   degrees: Sequence[np.ndarray]) -> None:
        """Persist a cold plan: content-addressed npz, atomic write (tmp
        file in the same directory, then ``os.replace``), payload digest
        embedded so :meth:`_disk_load` can verify integrity."""
        if self.path is None:
            return
        os.makedirs(self.path, exist_ok=True)
        key = self._disk_key(t.dims, t.nnz, knobs, degrees)
        fn = os.path.join(self.path, key + ".npz")
        if os.path.exists(fn):
            return
        arrays = {"indices": t.indices,
                  "meta": np.asarray(
                      [[p.kappa, p.rows_pp, p.block_p, p.blocks_pp, p.dim,
                        p.nblocks, p.max_degree] for p in t.plans],
                      dtype=np.int64)}
        for d, p in enumerate(t.plans):
            arrays[f"relabel{d}"] = p.row_relabel
            arrays[f"slot{d}"] = p.slot_of_elem
            arrays[f"partnnz{d}"] = p.part_nnz
            arrays[f"bpart{d}"] = p.block_part
        digest = payload_digest(_blob_payload_order(arrays, t.nmodes))
        arrays["digest"] = np.frombuffer(digest.encode(), dtype=np.uint8)
        tmp = os.path.join(self.path, f".tmp-{os.getpid()}-{key}")
        with open(tmp, "wb") as f:
            np.savez(f, **arrays)
        os.replace(tmp, fn)
        self.disk_saves += 1
        cz = _chaos.active()
        if cz is not None:
            cz.on_disk_save(fn)

    def _quarantine(self, fn: str) -> None:
        """Move a corrupt blob aside (``*.corrupt``) so the cold rebuild's
        fresh ``_disk_save`` can land in its place."""
        self.disk_corrupt += 1
        _obs_counter("plan_cache_outcomes",
                     "plan cache lookups by level (hit/structural/miss)"
                     ).inc("disk_corrupt")
        with _obs_span("plan.cache_quarantine",
                       path=os.path.basename(fn)):
            try:
                os.replace(fn, fn + ".corrupt")
            except OSError:
                pass

    # ------------------------------------------------------------- internal
    def _insert(self, key: tuple, entry: _Entry) -> None:
        self._by_key.setdefault(key, []).append(entry)
        self._order.append(key)
        while len(self._order) > self.max_entries:
            old = self._order.pop(0)
            bucket = self._by_key.get(old)
            if bucket:
                bucket.pop(0)
                if not bucket:
                    del self._by_key[old]


#: Process-wide default cache (``repro.engine.factory.make_engine`` uses it
#: unless handed an explicit one).
DEFAULT_CACHE = PlanCache()


def cached_build_flycoo(indices, values, dims, **knobs) -> FlycooTensor:
    """:func:`build_flycoo` through :data:`DEFAULT_CACHE`."""
    return DEFAULT_CACHE.get_tensor(indices, values, dims, **knobs)
