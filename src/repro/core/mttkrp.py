"""spMTTKRP along all modes (paper Alg. 2/4/5) on the FLYCOO-TPU layout.

Runtime state for the current mode-d layout (device arrays; pads hold
val=0, idx=0, alpha=-1):

  val   (S_d,)    f32
  idx   (S_d, N)  i32   beta  — original per-mode indices
  alpha (S_d, N)  i32   alpha — the element's slot in *every* mode layout
                        (alpha[s, d] == s for live slots in layout d)

One ``mode_step`` jit performs, exactly as the paper's thread block does
(Alg. 4): (a) elementwise computation for mode d (Alg. 2) and (b) dynamic
tensor remapping into the mode-(d+1) layout (Alg. 3). Remapping is a
conflict-free scatter because remap ids are unique (Observation 1); output
accumulation needs no cross-partition reduction because every output row is
owned by one partition (Observation 2) — in XLA terms the segment-sum within
a partition's contiguous relabeled row block, in Pallas terms a VMEM-resident
one-hot MXU accumulation.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .flycoo import FlycooTensor


# --------------------------------------------------------------------------
# Reference oracle (canonical COO order, no FLYCOO machinery).
# --------------------------------------------------------------------------
def mttkrp_ref(indices, values, factors, mode: int, dim: int):
    """Pure-jnp oracle: out[i_d, r] = sum_nnz val * prod_{w!=d} F_w[i_w, r]."""
    partials = values[:, None].astype(jnp.float32)
    for w, f in enumerate(factors):
        if w == mode:
            continue
        partials = partials * f[indices[:, w]]
    return jax.ops.segment_sum(partials, indices[:, mode], num_segments=dim)


# --------------------------------------------------------------------------
# Mode-d elementwise computation on the kernel layout (Alg. 2 + 4).
# --------------------------------------------------------------------------
def _gather_partials(layout, factors, mode: int):
    """ell(r) = val * prod_{w != d} Y_w[c_w, r]  (Alg. 2 lines 7-13)."""
    val, idx = layout["val"], layout["idx"]
    partials = val[:, None].astype(jnp.float32)
    for w, f in enumerate(factors):
        if w == mode:
            continue
        partials = partials * jnp.take(f, idx[:, w], axis=0, mode="fill",
                                       fill_value=0.0)
    return partials


def _ec_xla(layout, factors, mode: int, *, rows_pp, blocks_pp, block_p,
            kappa):
    """XLA backend: segment-sum into the relabeled row space.

    Pads have alpha[s, d] = -1 => lrow -1 => routed to a dump row with
    val = 0 (contributes nothing).
    """
    partials = _gather_partials(layout, factors, mode)
    stride = blocks_pp * block_p
    slot = jnp.arange(layout["val"].shape[0], dtype=jnp.int32)
    part = slot // stride
    lrow = layout["lrow"]
    gid = jnp.where(lrow < 0, 0, part * rows_pp + lrow)
    return jax.ops.segment_sum(partials, gid, num_segments=kappa * rows_pp)


def _ec_pallas(layout, factors, mode: int, interpret: bool, *, kappa,
               rows_pp, blocks_pp, block_p):
    from repro.kernels import ops as kops

    partials_in = []  # gathered input rows, kernel multiplies them
    for w, f in enumerate(factors):
        if w == mode:
            continue
        partials_in.append(jnp.take(f, layout["idx"][:, w], axis=0,
                                    mode="fill", fill_value=0.0))
    gathered = jnp.stack(partials_in, axis=1)  # (S, N-1, R)
    return kops.mttkrp_fused(
        gathered,
        layout["val"],
        layout["lrow"],
        kappa=kappa,
        rows_pp=rows_pp,
        blocks_pp=blocks_pp,
        block_p=block_p,
        interpret=interpret,
    )


def compute_lrow(idx_d, row_relabel_d, rows_pp: int, alive):
    """Recompute local row ids after a remap (relabel table lookup)."""
    rel = jnp.take(row_relabel_d, idx_d, axis=0, mode="fill", fill_value=0)
    return jnp.where(alive, rel % rows_pp, -1)


@functools.partial(
    jax.jit,
    static_argnames=("mode", "rows_pp", "blocks_pp", "block_p", "kappa",
                     "next_size", "backend", "interpret"),
)
def mode_step(layout, factors, row_relabel_d, *, mode: int, rows_pp: int,
              blocks_pp: int, block_p: int, kappa: int, next_size: int,
              backend: str = "xla", interpret: bool = False):
    """One iteration of Alg. 5's mode loop: EC (Alg. 2) + remap (Alg. 3).

    Returns (out_rel, next_layout). ``out_rel`` is the mode-d MTTKRP result
    in relabeled row space; caller maps back with ``row_relabel``.
    """
    nmodes = layout["idx"].shape[1]
    alive = layout["alpha"][:, mode] >= 0
    lrow = compute_lrow(layout["idx"][:, mode], row_relabel_d, rows_pp, alive)
    ec_layout = {"val": layout["val"], "idx": layout["idx"], "lrow": lrow}
    if backend == "pallas":
        out_rel = _ec_pallas(ec_layout, factors, mode, interpret,
                             kappa=kappa, rows_pp=rows_pp,
                             blocks_pp=blocks_pp, block_p=block_p)
    else:
        out_rel = _ec_xla(ec_layout, factors, mode, rows_pp=rows_pp,
                          blocks_pp=blocks_pp, block_p=block_p, kappa=kappa)

    # ---- Alg. 3: dynamic remap into the mode-(d+1) layout. -----------------
    nxt = (mode + 1) % nmodes
    dst = layout["alpha"][:, nxt]
    sdst = jnp.where(alive, dst, next_size)  # park pads out of range -> drop
    next_layout = {
        "val": jnp.zeros((next_size,), jnp.float32)
        .at[sdst].set(layout["val"], mode="drop", unique_indices=True),
        "idx": jnp.zeros((next_size, nmodes), jnp.int32)
        .at[sdst].set(layout["idx"], mode="drop", unique_indices=True),
        "alpha": jnp.full((next_size, nmodes), -1, jnp.int32)
        .at[sdst].set(layout["alpha"], mode="drop", unique_indices=True),
    }
    return out_rel, next_layout


# --------------------------------------------------------------------------
# Host-side driver (Alg. 5).
# --------------------------------------------------------------------------
class MTTKRPExecutor:
    """Executes spMTTKRP along all modes with dynamic remapping (Alg. 5).

    Holds device copies of the relabel tables and the *current* layout; the
    layout rotates through the modes as computation proceeds, exactly like
    the paper's T_in/T_out swap — one live tensor copy plus the remap target.
    """

    def __init__(self, tensor: FlycooTensor, backend: str = "xla",
                 interpret: bool = False):
        self.tensor = tensor
        self.backend = backend
        self.interpret = interpret
        self.plans = tensor.plans
        # note: out_user[v] = out_rel[row_relabel[v]] (relabel is old->new)
        self.row_relabel = [jnp.asarray(p.row_relabel) for p in self.plans]
        arrs = tensor.layout_arrays(0)
        alpha = np.stack(
            [self._alpha_for_mode(d) for d in range(tensor.nmodes)], axis=1
        )
        self.layout = {
            "val": jnp.asarray(arrs["val"]),
            "idx": jnp.asarray(arrs["idx"]),
            "alpha": jnp.asarray(alpha),
        }
        self.current_mode = 0

    def _alpha_for_mode(self, d: int) -> np.ndarray:
        """alpha column d, laid out physically in mode-0 slots."""
        p0 = self.tensor.plans[0]
        pd = self.tensor.plans[d]
        col = np.full(p0.padded_nnz, -1, dtype=np.int32)
        col[p0.slot_of_elem] = pd.slot_of_elem.astype(np.int32)
        return col

    def step(self, factors: Sequence[jax.Array]) -> jax.Array:
        """Compute MTTKRP for the current mode; remap to the next; rotate."""
        d = self.current_mode
        plan = self.plans[d]
        nxt = (d + 1) % self.tensor.nmodes
        out_rel, next_layout = mode_step(
            self.layout,
            tuple(factors),
            self.row_relabel[d],
            mode=d,
            rows_pp=plan.rows_pp,
            blocks_pp=plan.blocks_pp,
            block_p=plan.block_p,
            kappa=plan.kappa,
            next_size=self.plans[nxt].padded_nnz,
            backend=self.backend,
            interpret=self.interpret,
        )
        out = jnp.take(out_rel, self.row_relabel[d], axis=0)  # un-relabel
        self.layout = next_layout
        self.current_mode = nxt
        return out

    def all_modes(self, factors: Sequence[jax.Array]) -> list[jax.Array]:
        assert self.current_mode == 0, "executor must be at mode 0"
        return [self.step(factors) for _ in range(self.tensor.nmodes)]
