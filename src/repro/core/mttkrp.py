"""spMTTKRP along all modes (paper Alg. 2/4/5) — deprecated stateful shims.

The implementation now lives in :mod:`repro.engine` as pure functions over
a pytree ``EngineState`` (``engine.init`` / ``engine.mttkrp`` /
``engine.all_modes`` — the latter a single jitted ``lax.scan`` over the
mode rotation). This module keeps the original surface alive:

  * :func:`mttkrp_ref` — the COO oracle (unchanged, still the test anchor);
  * :func:`mode_step` — the one-mode EC+remap jit, now resolving its
    elementwise-computation backend through the engine's registry instead
    of string dispatch;
  * :class:`MTTKRPExecutor` — a thin deprecation shim over the engine.
    It no longer requires starting at mode 0 and gained ``reset()``.

New code should import from :mod:`repro.engine`. Migration table:

  ===============================  =====================================
  old (stateful)                   new (functional)
  ===============================  =====================================
  ``MTTKRPExecutor(t, backend=b)`` ``s = engine.init(t,
                                   ExecutionConfig(backend=b))``
  ``exe.step(factors)``            ``out, s = engine.mttkrp(s, factors)``
  ``exe.all_modes(factors)``       ``outs, s = engine.all_modes(s,
                                   factors)``
  ``exe.layout["val"]`` etc.       ``s.val`` / ``s.idx`` / ``s.alpha``
  ``exe.current_mode``             ``s.mode``
  ===============================  =====================================
"""
from __future__ import annotations

import functools
import warnings
from typing import Sequence

import jax
import jax.numpy as jnp

from repro import engine as _engine
from repro.engine import ExecutionConfig
from repro.engine.backends import compute_lrow, get_backend  # noqa: F401
from repro.engine.state import ModeStatic

from .flycoo import FlycooTensor


# --------------------------------------------------------------------------
# Reference oracle (canonical COO order, no FLYCOO machinery).
# --------------------------------------------------------------------------
def mttkrp_ref(indices, values, factors, mode: int, dim: int):
    """Pure-jnp oracle: out[i_d, r] = sum_nnz val * prod_{w!=d} F_w[i_w, r]."""
    partials = values[:, None].astype(jnp.float32)
    for w, f in enumerate(factors):
        if w == mode:
            continue
        partials = partials * f[indices[:, w]]
    return jax.ops.segment_sum(partials, indices[:, mode], num_segments=dim)


# --------------------------------------------------------------------------
# Compat wrappers over the engine's backend registry (benchmarks import
# these; the registry is the source of truth).
# --------------------------------------------------------------------------
def _ec_xla(layout, factors, mode: int, *, rows_pp, blocks_pp, block_p,
            kappa, schedule: str = "rect", nblocks: int = -1):
    """Compact-schedule layouts must carry the ``bpart`` descriptor in
    ``layout`` (pass ``schedule="compact"``/``nblocks`` from the plan)."""
    plan = ModeStatic(kappa=kappa, rows_pp=rows_pp, blocks_pp=blocks_pp,
                      block_p=block_p, dim=0, nblocks=nblocks,
                      schedule=schedule)
    return get_backend("xla")(layout, tuple(factors), mode, plan=plan,
                              config=ExecutionConfig())


def _ec_pallas(layout, factors, mode: int, interpret: bool, *, kappa,
               rows_pp, blocks_pp, block_p, schedule: str = "rect",
               nblocks: int = -1):
    plan = ModeStatic(kappa=kappa, rows_pp=rows_pp, blocks_pp=blocks_pp,
                      block_p=block_p, dim=0, nblocks=nblocks,
                      schedule=schedule)
    config = ExecutionConfig(backend="pallas", interpret=interpret)
    return get_backend("pallas")(layout, tuple(factors), mode, plan=plan,
                                 config=config)


@functools.partial(
    jax.jit,
    static_argnames=("mode", "rows_pp", "blocks_pp", "block_p", "kappa",
                     "next_size", "backend", "interpret", "schedule",
                     "nblocks"),
)
def mode_step(layout, factors, row_relabel_d, *, mode: int, rows_pp: int,
              blocks_pp: int, block_p: int, kappa: int, next_size: int,
              backend: str = "xla", interpret: bool = False,
              schedule: str = "rect", nblocks: int = -1):
    """One iteration of Alg. 5's mode loop: EC (Alg. 2) + remap (Alg. 3).

    Returns (out_rel, next_layout). ``out_rel`` is the mode-d MTTKRP result
    in relabeled row space; caller maps back with ``row_relabel``. Kept for
    per-mode benchmarking; the scanned path is ``engine.all_modes``. Under
    ``schedule="compact"`` pass ``nblocks`` and put the plan's ``bpart``
    descriptor in ``layout``.
    """
    nmodes = layout["idx"].shape[1]
    plan = ModeStatic(kappa=kappa, rows_pp=rows_pp, blocks_pp=blocks_pp,
                      block_p=block_p, dim=int(row_relabel_d.shape[0]),
                      nblocks=nblocks, schedule=schedule)
    s = layout["val"].shape[0]
    if s != plan.padded_nnz:
        # The usual cause: a compact-schedule layout (build_flycoo's
        # default) driven with the rect-default kwargs. A balanced compact
        # layout coincides with the rect one slot-for-slot, so equal sizes
        # are always safe; unequal means wrong partition arithmetic ahead.
        raise ValueError(
            f"layout has {s} slots but the {schedule!r} schedule expects "
            f"{plan.padded_nnz}; for compact-schedule plans pass "
            "schedule='compact', nblocks=plan.nblocks and include "
            "layout['bpart'] (= plan.block_part)")
    if schedule == "compact" and layout.get("bpart") is None:
        raise KeyError(
            "compact-schedule layout needs the 'bpart' block->partition "
            "descriptor (plan.block_part)")
    config = ExecutionConfig(backend=backend, interpret=interpret)
    alive = layout["alpha"][:, mode] >= 0
    lrow = compute_lrow(layout["idx"][:, mode], row_relabel_d, rows_pp, alive)
    ec_layout = {"val": layout["val"], "idx": layout["idx"], "lrow": lrow,
                 "bpart": layout.get("bpart")}
    out_rel = get_backend(config)(ec_layout, tuple(factors), mode, plan=plan,
                                  config=config)

    # ---- Alg. 3: dynamic remap into the mode-(d+1) layout. -----------------
    nxt = (mode + 1) % nmodes
    dst = layout["alpha"][:, nxt]
    sdst = jnp.where(alive, dst, next_size)  # park pads out of range -> drop
    next_layout = {
        "val": jnp.zeros((next_size,), jnp.float32)
        .at[sdst].set(layout["val"], mode="drop", unique_indices=True),
        "idx": jnp.zeros((next_size, nmodes), jnp.int32)
        .at[sdst].set(layout["idx"], mode="drop", unique_indices=True),
        "alpha": jnp.full((next_size, nmodes), -1, jnp.int32)
        .at[sdst].set(layout["alpha"], mode="drop", unique_indices=True),
    }
    return out_rel, next_layout


# --------------------------------------------------------------------------
# Deprecated host-side driver (Alg. 5) — delegates to repro.engine.
# --------------------------------------------------------------------------
class MTTKRPExecutor:
    """DEPRECATED stateful wrapper around :mod:`repro.engine`.

    The executor used to own mutable layout state and a host-side mode
    loop; it now merely threads an immutable ``EngineState`` through the
    functional API. Unlike the original, ``all_modes`` works from *any*
    resident mode (the mode-0 assertion is gone) and ``reset()`` returns
    the executor to the mode-0 layout.
    """

    def __init__(self, tensor: FlycooTensor, backend: str = "xla",
                 interpret: bool = False):
        warnings.warn(
            "MTTKRPExecutor is deprecated; use repro.engine "
            "(init/mttkrp/all_modes) — see repro.core.mttkrp docstring "
            "for the migration table", DeprecationWarning, stacklevel=2)
        self.tensor = tensor
        self.backend = backend
        self.interpret = interpret
        self.plans = tensor.plans
        # interpret=False historically meant "library default", which off-TPU
        # must interpret anyway; map it to the config's auto mode.
        self.config = ExecutionConfig(backend=backend,
                                      interpret=True if interpret else None)
        self._state = _engine.init(tensor, self.config)
        # note: out_user[v] = out_rel[row_relabel[v]] (relabel is old->new)
        self.row_relabel = list(self._state.relabel)

    # ------------------------------------------------------------ state view
    @property
    def state(self):
        """The underlying functional ``EngineState`` (read-only)."""
        return self._state

    @property
    def current_mode(self) -> int:
        return self._state.mode

    @property
    def layout(self) -> dict:
        """Resident layout sliced to the current mode's padded size
        (the engine stores it padded to the uniform S_max)."""
        sd = self.plans[self._state.mode].padded_nnz
        return {"val": self._state.val[:sd], "idx": self._state.idx[:sd],
                "alpha": self._state.alpha[:sd]}

    # ------------------------------------------------------------ execution
    def step(self, factors: Sequence[jax.Array]) -> jax.Array:
        """Compute MTTKRP for the current mode; remap to the next; rotate."""
        out, self._state = _engine.mttkrp(self._state, tuple(factors))
        return out

    def all_modes(self, factors: Sequence[jax.Array]) -> list[jax.Array]:
        """All-modes MTTKRP (one scanned dispatch), from ANY current mode;
        returns outputs indexed by mode d."""
        outs, self._state = _engine.all_modes(self._state, tuple(factors))
        return outs

    def reset(self) -> None:
        """Return to the pristine mode-0 layout (re-derives device state
        from the host tensor; cheap relative to preprocessing)."""
        self._state = _engine.init(self.tensor, self.config)
