"""RWKV-6 "Finch" block (arXiv:2404.05892): data-dependent-decay time-mix +
squared-ReLU channel-mix.

Training/prefill uses the chunked linear-attention algebra (GLA-style): per
chunk of length c, intra-chunk terms are (c x c) masked matmuls and the
(hd x hd) per-head state crosses chunks in a *Python* loop (static chunk
count, no while loop -> exact HLO costs). Decays are normalized to the chunk
end so every materialized exponential is <= exp(sum |log w| over one chunk)
— safe for the RWKV init regime (w0 ≈ -5 ⇒ per-step log-decay ≈ -7e-3).

Decode runs the exact recurrence with the (hd_k x hd_v) state cached; the
``wkv6`` Pallas kernel is the TPU serving path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..sharding import shard
from .common import ModelConfig, dense_init

HEAD_DIM = 64
LORA_DIM = 64


def n_heads(cfg: ModelConfig) -> int:
    return cfg.d_model // HEAD_DIM


def init_rwkv_block(cfg: ModelConfig, key) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    h = n_heads(cfg)
    ks = jax.random.split(key, 10)
    return {
        # time-mix
        "mu": jnp.full((5, d), 0.5, cfg.pdtype),          # r,k,v,g,w shifts
        "wr": dense_init(ks[0], (d, d), cfg.pdtype),
        "wk_t": dense_init(ks[1], (d, d), cfg.pdtype),
        "wv_t": dense_init(ks[2], (d, d), cfg.pdtype),
        "wg": dense_init(ks[3], (d, d), cfg.pdtype),
        "w0": jnp.full((d,), -5.0, jnp.float32),          # decay bias
        "wa_lora": dense_init(ks[4], (d, LORA_DIM), cfg.pdtype),
        "wb_lora": jnp.zeros((LORA_DIM, d), cfg.pdtype),  # zero-init lora out
        "u": dense_init(ks[5], (h, HEAD_DIM), jnp.float32, scale=0.5),
        "ln_x": jnp.ones((d,), cfg.pdtype),               # per-head norm
        "w_out_t": dense_init(ks[6], (d, d), cfg.pdtype),
        # channel-mix
        "mu_c": jnp.full((2, d), 0.5, cfg.pdtype),        # k, r shifts
        "wk_c": dense_init(ks[7], (d, f), cfg.pdtype),
        "wv_c": dense_init(ks[8], (f, d), cfg.pdtype),
        "wr_c": dense_init(ks[9], (d, d), cfg.pdtype),
    }


def _shift(x, last=None):
    """Token shift: x_{t-1} (zeros / cached last token at t=0)."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def _mix(x, xs, mu_row):
    return x + mu_row.astype(x.dtype) * (xs - x)


def _decay(params, xw, cfg: ModelConfig):
    """log w_t = -exp(w0 + tanh(x W_a) W_b)  (negative, data-dependent)."""
    dt = cfg.cdtype
    lora = jnp.tanh(xw @ params["wa_lora"].astype(dt)) \
        @ params["wb_lora"].astype(dt)
    raw = params["w0"].astype(jnp.float32) + lora.astype(jnp.float32)
    return -jnp.exp(raw)


def _heads(x, h):
    b, s, d = x.shape
    return x.reshape(b, s, h, HEAD_DIM).transpose(0, 2, 1, 3)  # (B,H,S,hd)


def _headnorm(y, scale, h):
    """Per-head RMS norm over hd (stand-in for RWKV's GroupNorm)."""
    b, hh, s, hd = y.shape
    yf = y.astype(jnp.float32)
    yf = yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)
    yf = yf.transpose(0, 2, 1, 3).reshape(b, s, hh * hd)
    return yf * scale.astype(jnp.float32)


def time_mix(params, x, cfg: ModelConfig, chunk: int | None = None):
    """Full-sequence WKV6; x: (B, S, D)."""
    b, s, d = x.shape
    if chunk is None:  # bound the unrolled chunk count (cost-mode compiles)
        chunk = 32 if s <= 512 else 256
    h = n_heads(cfg)
    dt = cfg.cdtype
    xs = _shift(x)
    r = _mix(x, xs, params["mu"][0]) @ params["wr"].astype(dt)
    k = _mix(x, xs, params["mu"][1]) @ params["wk_t"].astype(dt)
    v = _mix(x, xs, params["mu"][2]) @ params["wv_t"].astype(dt)
    g = _mix(x, xs, params["mu"][3]) @ params["wg"].astype(dt)
    lw = _decay(params, _mix(x, xs, params["mu"][4]), cfg)  # (B,S,D) f32

    r, k, v = (_heads(t, h).astype(jnp.float32) for t in (r, k, v))
    lw = _heads(lw, h)
    u = params["u"].astype(jnp.float32)                      # (H, hd)

    c = min(chunk, s)
    assert s % c == 0, (s, c)
    nc = s // c
    state0 = jnp.zeros((b, h, HEAD_DIM, HEAD_DIM), jnp.float32)

    def one_chunk(state, inp):
        rc, kc, vc, lwc = inp                # (B, H, c, hd) each
        e = jnp.cumsum(lwc, axis=2)          # inclusive
        ce = e - lwc                         # exclusive
        e_end = e[:, :, -1:, :]
        r_in = rc * jnp.exp(ce)              # exponents <= 0: safe
        y_inter = jnp.einsum("bhck,bhkv->bhcv", r_in, state)
        k2 = kc * jnp.exp(e_end - e)         # <= 0: safe
        r3 = rc * jnp.exp(ce - e_end)        # bounded by chunk decay mass
        scores = jnp.einsum("bhck,bhsk->bhcs", r3, k2)
        mask = jnp.tril(jnp.ones((c, c), bool), k=-1)        # strict s < t
        scores = jnp.where(mask[None, None], scores, 0.0)
        y_intra = jnp.einsum("bhcs,bhsv->bhcv", scores, vc)
        coef = jnp.einsum("bhck,hk->bhc", rc * kc, u)        # bonus s == t
        y_bonus = coef[..., None] * vc
        new_state = jnp.exp(e_end)[..., 0, :, None] * state + \
            jnp.einsum("bhsk,bhsv->bhkv", k2, vc)
        return new_state, y_inter + y_intra + y_bonus

    def chunked(t):  # (B, H, S, hd) -> (nc, B, H, c, hd)
        return jnp.moveaxis(
            t.reshape(b, h, nc, c, HEAD_DIM), 2, 0)

    xs = (chunked(r), chunked(k), chunked(v), chunked(lw))
    from .layers import cost_mode
    one_chunk_ckpt = jax.checkpoint(one_chunk)  # rebuild intra-chunk mats
    if cost_mode():  # unrolled: exact HLO cost for roofline variants
        state, ys = state0, []
        for i in range(nc):
            state, yc = one_chunk_ckpt(state,
                                       jax.tree.map(lambda t: t[i], xs))
            ys.append(yc)
        y = jnp.concatenate(ys, axis=2)
    else:            # scanned: one chunk's buffers live at a time
        _, ys = jax.lax.scan(one_chunk_ckpt, state0, xs)
        y = jnp.moveaxis(ys, 0, 2).reshape(b, h, s, HEAD_DIM)
    y = _headnorm(y, params["ln_x"], h).astype(dt)
    out = (y * jax.nn.silu(g)) @ params["w_out_t"].astype(dt)
    return shard(out, "dp", None, None)


def time_mix_decode(params, x, cache, cfg: ModelConfig):
    """x: (B, 1, D); cache: {"state": (B,H,hd,hd), "last": (B,1,D)}."""
    b, _, d = x.shape
    h = n_heads(cfg)
    dt = cfg.cdtype
    xs = cache["last"].astype(x.dtype)
    r = _mix(x, xs, params["mu"][0]) @ params["wr"].astype(dt)
    k = _mix(x, xs, params["mu"][1]) @ params["wk_t"].astype(dt)
    v = _mix(x, xs, params["mu"][2]) @ params["wv_t"].astype(dt)
    g = _mix(x, xs, params["mu"][3]) @ params["wg"].astype(dt)
    lw = _decay(params, _mix(x, xs, params["mu"][4]), cfg)

    rh = r.reshape(b, h, HEAD_DIM).astype(jnp.float32)
    kh = k.reshape(b, h, HEAD_DIM).astype(jnp.float32)
    vh = v.reshape(b, h, HEAD_DIM).astype(jnp.float32)
    wh = jnp.exp(lw.reshape(b, h, HEAD_DIM))
    u = params["u"].astype(jnp.float32)
    s0 = cache["state"]
    kv = kh[..., :, None] * vh[..., None, :]                 # (B,H,hd,hd)
    y = jnp.einsum("bhk,bhkv->bhv", rh * u[None], kv) \
        + jnp.einsum("bhk,bhkv->bhv", rh, s0)
    state = wh[..., :, None] * s0 + kv
    y = _headnorm(y[:, :, None, :], params["ln_x"], h).astype(dt)
    out = (y * jax.nn.silu(g)) @ params["w_out_t"].astype(dt)
    return out, {"state": state, "last": x}


def channel_mix(params, x, cfg: ModelConfig, last=None):
    dt = cfg.cdtype
    xs = _shift(x, last)
    xk = _mix(x, xs, params["mu_c"][0])
    xr = _mix(x, xs, params["mu_c"][1])
    kk = jnp.square(jax.nn.relu(xk @ params["wk_c"].astype(dt)))
    kk = shard(kk, "dp", None, "tp")
    out = jax.nn.sigmoid(xr @ params["wr_c"].astype(dt)) * \
        (kk @ params["wv_c"].astype(dt))
    return shard(out, "dp", None, None)


def make_rwkv_cache(cfg: ModelConfig, batch: int) -> dict:
    h = n_heads(cfg)
    return {
        "state": jnp.zeros((batch, h, HEAD_DIM, HEAD_DIM), jnp.float32),
        "last": jnp.zeros((batch, 1, cfg.d_model), cfg.cdtype),
        "last_c": jnp.zeros((batch, 1, cfg.d_model), cfg.cdtype),
    }
