"""Model configuration + shared primitives (norms, init, dtype policy).

Params are plain nested dicts of jnp arrays ("pytree modules"): every layer
is an ``init_*(cfg, key) -> params`` plus an ``apply_*(params, x, ...)`` pair.
Layers of the same kind are stacked on a leading axis and driven by
``lax.scan`` so HLO size is O(1) in depth (512-chip compiles stay small).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# Configuration
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    kind: str                      # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    # block behaviour
    norm: str = "rmsnorm"          # rmsnorm | layernorm | layernorm_np
    act: str = "swiglu"            # swiglu | geglu | gelu
    qkv_bias: bool = False
    qk_norm: bool = False
    parallel_block: bool = False   # command-r style attn || mlp
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # hybrid (griffin) / ssm
    block_pattern: tuple[str, ...] = ("attn",)   # cycle of block kinds
    window: int = 0                # sliding window for "local" attention
    lru_width: int = 0
    conv_width: int = 4
    # enc-dec (whisper)
    n_enc_layers: int = 0
    # vlm (paligemma)
    n_img_tokens: int = 0
    # dtypes / memory
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "full"            # full | dots | none
    # distribution / serving knobs (§Perf hillclimb levers)
    seq_shard_carry: bool = True   # Megatron-SP: store scan carries S/tp
    kv_quant: bool = False         # int8 KV cache (per-row scales)
    # technique attachment (DESIGN.md §4): CPD-factorized embedding
    cpd_embedding: bool = False
    cpd_rank: int = 0

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        return ((self.vocab + 127) // 128) * 128

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def stages(self) -> list[tuple[tuple[str, ...], int]]:
        """Split n_layers into (pattern-cycle, repeat) stages for scan."""
        pat = self.block_pattern
        full, rem = divmod(self.n_layers, len(pat))
        out = []
        if full:
            out.append((pat, full))
        if rem:
            out.append((pat[:rem], 1))
        return out

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND roofline and reporting)."""
        d, hd = self.d_model, self.hd
        attn = d * (self.n_heads + 2 * self.n_kv_heads) * hd \
            + self.n_heads * hd * d
        if self.qkv_bias:
            attn += (self.n_heads + 2 * self.n_kv_heads) * hd
        gated = self.act in ("swiglu", "geglu")
        mlp = d * self.d_ff * (3 if gated else 2)
        if self.n_experts:
            mlp = self.n_experts * mlp + d * self.n_experts  # + router
        rec = 0
        if "rec" in self.block_pattern:
            w = self.lru_width or d
            # in/out proj + gates + conv
            rec = 2 * d * w + 2 * w * w // 1 + 3 * w + self.conv_width * w
        counts = {"attn": attn + mlp, "local": attn + mlp,
                  "rec": rec + mlp, "moe": attn + mlp,
                  "rwkv": 0, "enc": attn + mlp, "dec": 2 * attn + mlp}
        if self.kind == "ssm":
            # rwkv6: time-mix (r,k,v,g,w,o = 6 d^2 approx + loras) + channel mix
            tm = 5 * d * d + d * d + 7 * 32 * d * 2
            cm = 2 * d * self.d_ff
            per_layer = tm + cm
            total = self.n_layers * per_layer
        else:
            total = 0
            for pat, rep in self.stages():
                for kind in pat:
                    total += counts[kind] * rep
            if self.n_enc_layers:
                total += self.n_enc_layers * (attn + mlp)
        emb = self.vocab_padded * d
        total += emb if self.tie_embeddings else 2 * emb
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k experts only)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        gated = self.act in ("swiglu", "geglu")
        dense_mlp = d * self.d_ff * (3 if gated else 2)
        saved = (self.n_experts - self.top_k) * dense_mlp * self.n_layers
        return self.param_count() - saved


# --------------------------------------------------------------------------
# Primitives
# --------------------------------------------------------------------------
@jax.custom_jvp
def opt_barrier(x):
    """``lax.optimization_barrier`` that is transparent to autodiff.

    The barrier only constrains XLA scheduling (here: pinning a bf16 cast
    before a gather/all-gather so collectives move bf16, not the f32
    masters); mathematically it is the identity, so its tangent/cotangent
    is the identity too. ``lax.optimization_barrier`` itself has no
    differentiation rule, which made every ``value_and_grad`` over these
    models raise — the custom JVP scopes the barrier to the primal
    computation, where it matters.
    """
    return jax.lax.optimization_barrier(x)


@opt_barrier.defjvp
def _opt_barrier_jvp(primals, tangents):
    (x,), (t,) = primals, tangents
    return opt_barrier(x), t


def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32)
            * std).astype(dtype)


def init_norm(cfg: ModelConfig, with_bias: bool = False):
    if cfg.norm == "layernorm_np":
        return {}  # OLMo: non-parametric LN
    p = {"scale": jnp.ones((cfg.d_model,), cfg.pdtype)}
    if cfg.norm == "layernorm" and with_bias:
        p["bias"] = jnp.zeros((cfg.d_model,), cfg.pdtype)
    return p


def apply_norm(params, x, cfg: ModelConfig, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    else:  # layernorm / layernorm_np
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
        xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    if params:
        xf = xf * params["scale"].astype(jnp.float32)
        if "bias" in params:
            xf = xf + params["bias"].astype(jnp.float32)
    return xf.astype(x.dtype)


def rms_head_norm(x, scale, eps: float = 1e-6):
    """QK-norm (per-head RMS norm), qwen3 style."""
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (xf * scale.astype(jnp.float32)).astype(x.dtype)


def activate(h_gate, h_up, act: str):
    if act == "swiglu":
        return jax.nn.silu(h_gate) * h_up
    if act == "geglu":
        return jax.nn.gelu(h_gate) * h_up
    raise ValueError(act)
