"""NN substrate: pytree modules, stage scans, block zoo."""
from .common import ModelConfig
from .transformer import (apply_block, decode_step, forward, init_cache,
                          init_model)

__all__ = ["ModelConfig", "forward", "decode_step", "init_model",
           "init_cache", "apply_block"]
