"""Top-k MoE with sort-based capacity dispatch + expert parallelism.

Production path (mesh active): ``shard_map`` over (dp..., model) — tokens
stay on their dp shard, experts live on the ``model`` axis, dispatch crosses
``model`` with a single pair of all_to_alls (DESIGN.md §6). Expert weights
arrive fsdp-sharded on d_model and are all-gathered per layer (FSDP
semantics, honest collective bytes).

Fallback path (no mesh): identical math on one device.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .. import sharding
from .common import ModelConfig, dense_init, activate

try:  # jax >= 0.6 new api
    from jax import shard_map as _shard_map

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map_old(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=False)


def init_moe(cfg: ModelConfig, key) -> dict:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_ff
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, e), jnp.float32, scale=0.02),
        "w_gate": dense_init(ks[1], (e, d, f), cfg.pdtype),
        "w_up": dense_init(ks[2], (e, d, f), cfg.pdtype),
        "w_down": dense_init(ks[3], (e, f, d), cfg.pdtype),
    }


def _route(xt, router, top_k: int):
    """Token->expert assignment. Returns (weights, expert ids) (T, k)."""
    scores = (xt.astype(jnp.float32) @ router.astype(jnp.float32))
    probs = jax.nn.softmax(scores, axis=-1)
    topv, topi = jax.lax.top_k(probs, top_k)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)  # qwen3 renorm
    return topv, topi


def _dispatch(xt, eids, n_experts: int, capacity: int):
    """Sort-based capacity dispatch (dropping): returns buffer (E, C, D),
    plus (slot, keep) to invert the dispatch."""
    t_tok, k = eids.shape
    tk = t_tok * k
    flat_e = eids.reshape(tk)
    flat_t = jnp.repeat(jnp.arange(t_tok, dtype=jnp.int32), k)
    order = jnp.argsort(flat_e)
    se, st = flat_e[order], flat_t[order]
    first = jnp.searchsorted(se, jnp.arange(n_experts), side="left")
    pos_in_e = jnp.arange(tk, dtype=jnp.int32) - first[se].astype(jnp.int32)
    keep = pos_in_e < capacity
    slot = jnp.where(keep, se * capacity + pos_in_e, n_experts * capacity)
    buf = jnp.zeros((n_experts * capacity, xt.shape[-1]), xt.dtype)
    buf = buf.at[slot].set(jnp.where(keep[:, None], xt[st], 0), mode="drop")
    return buf.reshape(n_experts, capacity, -1), (slot, keep, st, order)


def _combine(out_buf, dispatch_info, weights, t_tok: int):
    slot, keep, st, order = dispatch_info
    e, c, d = out_buf.shape
    rows = out_buf.reshape(e * c, d)
    vals = jnp.where(keep[:, None],
                     jnp.take(rows, jnp.minimum(slot, e * c - 1), axis=0), 0)
    w_sorted = weights.reshape(-1)[order]
    out = jnp.zeros((t_tok, d), out_buf.dtype)
    return out.at[st].add(vals * w_sorted[:, None].astype(out_buf.dtype))


def _expert_ffn(buf, w_gate, w_up, w_down, cfg: ModelConfig):
    gate = jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(buf.dtype))
    up = jnp.einsum("ecd,edf->ecf", buf, w_up.astype(buf.dtype))
    h = activate(gate, up, cfg.act if cfg.act != "gelu" else "swiglu")
    return jnp.einsum("ecf,efd->ecd", h, w_down.astype(buf.dtype))


def _capacity(t_tok: int, k: int, e: int, cf: float) -> int:
    return max(1, int(math.ceil(t_tok * k / e * cf)))


def apply_moe(params, x, cfg: ModelConfig):
    """x: (B, S, D) -> (B, S, D)."""
    ctx = sharding.current()
    b, s, d = x.shape
    if ctx is None or ctx.tp_axis is None:
        return _apply_local(params, x, cfg)

    mesh = ctx.mesh
    tp = ctx.tp_axis
    m = mesh.shape[tp]
    dp = ctx.dp_axes
    e = cfg.n_experts
    assert e % m == 0, (e, m)
    e_loc = e // m
    fsdp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    # Sequence-shard dispatch over the model axis when S divides: every tp
    # rank routes a distinct S/m token slice (no duplicated expert flops).
    # Decode (S=1) falls back to tp-replicated dispatch: tiny and correct.
    seq_shard = s % m == 0 and s >= m
    x_spec = jax.sharding.PartitionSpec(fsdp_spec, tp if seq_shard else None,
                                        None)

    def local_fn(x_loc, router, w_gate, w_up, w_down):
        # x_loc (B_loc, S, D); w_* (E_loc, D/dp, F) -> FSDP all-gather
        if ctx.fsdp and dp:
            w_gate = jax.lax.all_gather(w_gate, dp, axis=1, tiled=True)
            w_up = jax.lax.all_gather(w_up, dp, axis=1, tiled=True)
            w_down = jax.lax.all_gather(w_down, dp, axis=2, tiled=True)
        bl, sl, dl = x_loc.shape
        t_tok = bl * sl
        xt = x_loc.reshape(t_tok, dl)
        weights, eids = _route(xt, router, cfg.top_k)
        cap = _capacity(t_tok, cfg.top_k, e, cfg.capacity_factor)
        buf, info = _dispatch(xt, eids, e, cap)             # (E, C, D)
        # ---- all_to_all over model axis: experts to their owners. ----
        buf = buf.reshape(m, e_loc, cap, dl)
        buf = jax.lax.all_to_all(buf, tp, split_axis=0, concat_axis=0,
                                 tiled=False)               # (m, e_loc, C, D)
        buf = buf.transpose(1, 0, 2, 3).reshape(e_loc, m * cap, dl)
        out_buf = _expert_ffn(buf, w_gate, w_up, w_down, cfg)
        out_buf = out_buf.reshape(e_loc, m, cap, dl).transpose(1, 0, 2, 3)
        out_buf = jax.lax.all_to_all(out_buf, tp, split_axis=0,
                                     concat_axis=0, tiled=False)
        out_buf = out_buf.reshape(e, cap, dl)
        out = _combine(out_buf, info, weights, t_tok)
        return out.reshape(bl, sl, dl)

    out = shard_map(
        local_fn,
        mesh,
        in_specs=(
            x_spec,
            jax.sharding.PartitionSpec(None, None),
            jax.sharding.PartitionSpec(tp, fsdp_spec if ctx.fsdp else None,
                                       None),
            jax.sharding.PartitionSpec(tp, fsdp_spec if ctx.fsdp else None,
                                       None),
            jax.sharding.PartitionSpec(tp, None,
                                       fsdp_spec if ctx.fsdp else None),
        ),
        out_specs=x_spec,
    )(x, params["router"], params["w_gate"], params["w_up"],
      params["w_down"])
    return out


def _apply_local(params, x, cfg: ModelConfig):
    b, s, d = x.shape
    t_tok = b * s
    xt = x.reshape(t_tok, d)
    weights, eids = _route(xt, params["router"], cfg.top_k)
    cap = _capacity(t_tok, cfg.top_k, cfg.n_experts, cfg.capacity_factor)
    buf, info = _dispatch(xt, eids, cfg.n_experts, cap)
    out_buf = _expert_ffn(buf, params["w_gate"], params["w_up"],
                          params["w_down"], cfg)
    out = _combine(out_buf, info, weights, t_tok)
    return out.reshape(b, s, d)
