"""Model assembly: block zoo + stage scans + train/decode entry points.

Layers are grouped into *stages* (cycles of a block pattern, see
``ModelConfig.stages``); each stage's params are stacked on a leading axis
and driven by one ``lax.scan`` (HLO size O(1) in depth). Block kinds:

  attn   pre-norm GQA attention + MLP (parallel_block: attn || mlp)
  local  sliding-window attention + MLP (griffin attention layers)
  moe    GQA attention + expert-parallel MoE FFN
  rec    RG-LRU recurrent block + MLP (griffin)
  rwkv   RWKV-6 time-mix + channel-mix
  enc    bidirectional attention + MLP (whisper encoder)
  dec    causal self-attn + cross-attn + MLP (whisper decoder)
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from ..sharding import shard
from .common import (ModelConfig, apply_norm, dense_init, init_norm,
                     opt_barrier)
from . import layers, moe, rglru, rwkv


# --------------------------------------------------------------------------
# Blocks
# --------------------------------------------------------------------------
def init_block(cfg: ModelConfig, kind: str, key) -> dict:
    ks = jax.random.split(key, 4)
    if kind == "rwkv":
        p = rwkv.init_rwkv_block(cfg, ks[0])
        p["ln1"] = init_norm(cfg)
        p["ln2"] = init_norm(cfg)
        return p
    if kind == "rec":
        return {"ln1": init_norm(cfg),
                "rec": rglru.init_rglru(cfg, ks[0]),
                "ln2": init_norm(cfg),
                "mlp": layers.init_mlp(cfg, ks[1])}
    if kind == "dec":
        return {"ln1": init_norm(cfg),
                "attn": layers.init_attention(cfg, ks[0]),
                "lnx": init_norm(cfg),
                "xattn": layers.init_attention(cfg, ks[1], cross=True),
                "ln2": init_norm(cfg),
                "mlp": layers.init_mlp(cfg, ks[2])}
    p = {"attn": layers.init_attention(cfg, ks[0])}
    if cfg.parallel_block:
        p["ln"] = init_norm(cfg)
    else:
        p["ln1"] = init_norm(cfg)
        p["ln2"] = init_norm(cfg)
    if kind == "moe":
        p["moe"] = moe.init_moe(cfg, ks[1])
    else:
        p["mlp"] = layers.init_mlp(cfg, ks[1])
    return p


def _attn_mask_kind(cfg: ModelConfig, kind: str) -> tuple[str, int]:
    if kind == "enc":
        return "bidir", 0
    if kind == "local":
        return "window", 0
    if cfg.kind == "vlm":
        return "prefix", cfg.n_img_tokens
    return "causal", 0


def apply_block(params, x, cfg: ModelConfig, kind: str,
                enc_out: Optional[jax.Array] = None):
    use_rope = cfg.rope_theta > 0
    if kind == "rwkv":
        x = x + rwkv.time_mix(params, apply_norm(params["ln1"], x, cfg), cfg)
        x = x + rwkv.channel_mix(params, apply_norm(params["ln2"], x, cfg),
                                 cfg)
        return x
    if kind == "rec":
        x = x + rglru.apply_rglru(params["rec"],
                                  apply_norm(params["ln1"], x, cfg), cfg)
        x = x + layers.apply_mlp(params["mlp"],
                                 apply_norm(params["ln2"], x, cfg), cfg)
        return x
    if kind == "dec":
        h = apply_norm(params["ln1"], x, cfg)
        x = x + layers.attention_full(params["attn"], h, cfg, mask="causal",
                                      use_rope=use_rope)
        h = apply_norm(params["lnx"], x, cfg)
        x = x + layers.attention_full(params["xattn"], h, cfg, mask="bidir",
                                      xkv=enc_out, use_rope=False)
        x = x + layers.apply_mlp(params["mlp"],
                                 apply_norm(params["ln2"], x, cfg), cfg)
        return x

    mask, prefix_len = _attn_mask_kind(cfg, kind)
    if cfg.parallel_block:  # command-r: shared-norm parallel attn + FFN
        h = apply_norm(params["ln"], x, cfg)
        return x + layers.attention_full(
            params["attn"], h, cfg, mask=mask, prefix_len=prefix_len,
            use_rope=use_rope) + layers.apply_mlp(params["mlp"], h, cfg)
    h = apply_norm(params["ln1"], x, cfg)
    x = x + layers.attention_full(params["attn"], h, cfg, mask=mask,
                                  prefix_len=prefix_len, use_rope=use_rope)
    h = apply_norm(params["ln2"], x, cfg)
    ffn = (moe.apply_moe(params["moe"], h, cfg) if kind == "moe"
           else layers.apply_mlp(params["mlp"], h, cfg))
    return x + ffn


def apply_block_decode(params, x, cache, cfg: ModelConfig, kind: str):
    use_rope = cfg.rope_theta > 0
    if kind == "rwkv":
        h = apply_norm(params["ln1"], x, cfg)
        o, tm_cache = rwkv.time_mix_decode(params, h, cache, cfg)
        x = x + o
        h2 = apply_norm(params["ln2"], x, cfg)
        x = x + rwkv.channel_mix(params, h2, cfg, last=cache["last_c"])
        return x, {**tm_cache, "last_c": h2}
    if kind == "rec":
        h = apply_norm(params["ln1"], x, cfg)
        o, rec_cache = rglru.apply_rglru_decode(params["rec"], h, cache, cfg)
        x = x + o
        x = x + layers.apply_mlp(params["mlp"],
                                 apply_norm(params["ln2"], x, cfg), cfg)
        return x, rec_cache
    if kind == "dec":
        h = apply_norm(params["ln1"], x, cfg)
        o, sc = layers.attention_decode(params["attn"], h, cache["self"],
                                        cfg, use_rope=use_rope)
        x = x + o
        h = apply_norm(params["lnx"], x, cfg)
        o, _ = layers.attention_decode(params["xattn"], h, cache["cross"],
                                       cfg, use_rope=False, cross=True)
        x = x + o
        x = x + layers.apply_mlp(params["mlp"],
                                 apply_norm(params["ln2"], x, cfg), cfg)
        return x, {**cache, "self": sc}

    mask = "window" if kind == "local" else "causal"
    if cfg.parallel_block:
        h = apply_norm(params["ln"], x, cfg)
        o, new_cache = layers.attention_decode(params["attn"], h, cache, cfg,
                                               mask=mask, use_rope=use_rope)
        return x + o + layers.apply_mlp(params["mlp"], h, cfg), new_cache
    h = apply_norm(params["ln1"], x, cfg)
    o, new_cache = layers.attention_decode(params["attn"], h, cache, cfg,
                                           mask=mask, use_rope=use_rope)
    x = x + o
    h = apply_norm(params["ln2"], x, cfg)
    ffn = (moe.apply_moe(params["moe"], h, cfg) if kind == "moe"
           else layers.apply_mlp(params["mlp"], h, cfg))
    return x + ffn, new_cache


def init_block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                     enc_len: int = 0) -> dict:
    if kind == "rwkv":
        return rwkv.make_rwkv_cache(cfg, batch)
    if kind == "rec":
        return rglru.make_rglru_cache(cfg, batch)
    if kind == "dec":
        return {"self": layers.make_attn_cache(cfg, batch, max_len),
                "cross": {**layers.make_attn_cache(cfg, batch, enc_len),
                          "kv_len": jnp.zeros((), jnp.int32)}}
    return layers.make_attn_cache(cfg, batch, max_len,
                                  windowed=(kind == "local"))


# --------------------------------------------------------------------------
# Stages (scan over stacked cycles)
# --------------------------------------------------------------------------
def init_stage(cfg: ModelConfig, pattern, rep: int, key) -> dict:
    def one_cycle(k):
        ks = jax.random.split(k, len(pattern))
        return {f"b{j}": init_block(cfg, kind, ks[j])
                for j, kind in enumerate(pattern)}
    keys = jax.random.split(key, rep)
    return jax.vmap(one_cycle)(keys)


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fn


def apply_stage(stage_params, x, cfg: ModelConfig, pattern,
                enc_out: Optional[jax.Array] = None):
    def cycle(carry, cyc_params):
        h = carry
        for j, kind in enumerate(pattern):
            h = apply_block(cyc_params[f"b{j}"], h, cfg, kind, enc_out)
        # saved scan carries are the dominant train-memory term; store them
        # sequence-sharded over `model` (Megatron-SP style). Costs one
        # gather per layer — disable for models whose carries are small
        # (§Perf iteration).
        if cfg.seq_shard_carry:
            h = shard(h, "dp", "tp", None)
        return h, None

    body = _remat(cycle, cfg)
    x, _ = jax.lax.scan(body, x, stage_params)
    return x


def apply_stage_decode(stage_params, stage_cache, x, cfg: ModelConfig,
                       pattern):
    def cycle(carry, pc):
        cyc_params, cyc_cache = pc
        h = carry
        new_cache = {}
        for j, kind in enumerate(pattern):
            h, new_cache[f"b{j}"] = apply_block_decode(
                cyc_params[f"b{j}"], h, cyc_cache[f"b{j}"], cfg, kind)
        return h, new_cache

    x, new_caches = jax.lax.scan(cycle, x, (stage_params, stage_cache))
    return x, new_caches


# --------------------------------------------------------------------------
# Whole model
# --------------------------------------------------------------------------
def sinusoidal_pos(seq: int, d: int, offset=0) -> jax.Array:
    pos = offset + jnp.arange(seq)[:, None].astype(jnp.float32)
    div = jnp.exp(jnp.arange(0, d, 2) * (-math.log(10000.0) / d))
    pe = jnp.zeros((seq, d))
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


def init_model(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    if cfg.cpd_embedding:  # the paper's technique as the embedding layer
        from ..tensorized import init_cpd_embedding

        params = {"embed_cpd": init_cpd_embedding(
            ks[0], cfg.vocab_padded, d, cfg.cpd_rank or 64,
            dtype=cfg.pdtype)}
    else:
        params = {"embed": dense_init(ks[0], (cfg.vocab_padded, d),
                                      cfg.pdtype, scale=0.02)}
    for i, (pat, rep) in enumerate(cfg.stages()):
        params[f"stage{i}"] = init_stage(cfg, pat, rep, ks[1 + i % 4])
    params["ln_f"] = init_norm(cfg)
    if not cfg.tie_embeddings and not cfg.cpd_embedding:
        params["head"] = dense_init(ks[5], (d, cfg.vocab_padded), cfg.pdtype)
    if cfg.n_enc_layers:
        params["enc"] = init_stage(cfg, ("enc",), cfg.n_enc_layers, ks[6])
        params["enc_ln_f"] = init_norm(cfg)
    return params


def embed_lookup(params, ids, cfg: ModelConfig):
    """Gather token embeddings in compute dtype.

    The optimization_barrier pins the bf16 cast *before* the gather — XLA
    otherwise swaps them and the gather + vocab-shard combine run on the
    f32 master table (2x HBM + 2x collective bytes).
    """
    if cfg.cpd_embedding:  # backward of this lookup IS spMTTKRP (§4)
        from ..tensorized import cpd_embed

        return cpd_embed(params["embed_cpd"], ids).astype(cfg.cdtype)
    table = opt_barrier(params["embed"].astype(cfg.cdtype))
    return jnp.take(table, ids, axis=0)


def _logits(params, x, cfg: ModelConfig):
    x = apply_norm(params["ln_f"], x, cfg)
    if cfg.cpd_embedding:  # tied CPD head, no dense table materialized
        from ..tensorized import cpd_logits

        return shard(cpd_logits(params["embed_cpd"], x), "dp", None, "tp")
    logits = x @ head_matrix(params, cfg)
    return shard(logits, "dp", None, "tp")


def encode(params, enc_embeds, cfg: ModelConfig):
    """Whisper encoder over precomputed (stub) frame embeddings."""
    x = enc_embeds.astype(cfg.cdtype)
    x = x + sinusoidal_pos(x.shape[1], cfg.d_model).astype(cfg.cdtype)
    x = apply_stage(params["enc"], x, cfg, ("enc",))
    return apply_norm(params["enc_ln_f"], x, cfg)


def forward(params, cfg: ModelConfig, tokens: Optional[jax.Array] = None,
            embeds: Optional[jax.Array] = None,
            enc_embeds: Optional[jax.Array] = None,
            return_hidden: bool = False) -> jax.Array:
    """Training / teacher-forced forward. Returns logits (B, S, Vp).

    vlm: ``embeds`` (B, P_img, D) stub patch embeddings are prepended.
    audio: ``enc_embeds`` (B, S_enc, D) stub frame embeddings feed the
    encoder; ``tokens`` are decoder inputs.
    """
    x = embed_lookup(params, tokens, cfg)
    if cfg.kind == "vlm" and embeds is not None:
        x = jnp.concatenate([embeds.astype(cfg.cdtype), x], axis=1)
    if cfg.rope_theta == 0:  # whisper: absolute sinusoidal positions
        x = x + sinusoidal_pos(x.shape[1], cfg.d_model).astype(cfg.cdtype)
    x = shard(x, "dp", None, None)
    enc_out = None
    if cfg.n_enc_layers:
        assert enc_embeds is not None
        enc_out = encode(params, enc_embeds, cfg)
    for i, (pat, rep) in enumerate(cfg.stages()):
        x = apply_stage(params[f"stage{i}"], x, cfg, pat, enc_out)
    if return_hidden:  # chunked-loss path: caller owns the head matmul
        return apply_norm(params["ln_f"], x, cfg)
    return _logits(params, x, cfg)


def head_matrix(params, cfg: ModelConfig):
    if cfg.cpd_embedding:
        from ..tensorized import dense_table

        return dense_table(params["embed_cpd"]).astype(cfg.cdtype).T
    if cfg.tie_embeddings:
        return params["embed"].astype(cfg.cdtype).T
    return params["head"].astype(cfg.cdtype)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               enc_len: int = 0) -> dict:
    caches = {}
    for i, (pat, rep) in enumerate(cfg.stages()):
        def one_cycle(_):
            return {f"b{j}": init_block_cache(cfg, kind, batch, max_len,
                                              enc_len)
                    for j, kind in enumerate(pat)}
        caches[f"stage{i}"] = jax.vmap(one_cycle)(jnp.arange(rep))
    return caches


def decode_step(params, cache, cfg: ModelConfig, token: jax.Array):
    """token: (B, 1) int32 -> (logits (B, 1, Vp), new cache)."""
    x = embed_lookup(params, token, cfg)
    if cfg.rope_theta == 0:
        pos = _first_cache_len(cache, cfg)
        x = x + sinusoidal_pos(1, cfg.d_model,
                               offset=pos).astype(cfg.cdtype)[None]
    new_cache = {}
    for i, (pat, rep) in enumerate(cfg.stages()):
        x, new_cache[f"stage{i}"] = apply_stage_decode(
            params[f"stage{i}"], cache[f"stage{i}"], x, cfg, pat)
    return _logits(params, x, cfg), new_cache


def build_cross_caches(params, cfg: ModelConfig, enc_embeds, cache):
    """Run the encoder once and fill every decoder block's cross-attn KV."""
    enc_out = encode(params, enc_embeds, cfg)
    dt = cfg.cdtype
    kv_len = jnp.asarray(enc_out.shape[1], jnp.int32)
    new_cache = dict(cache)
    for i, (pat, rep) in enumerate(cfg.stages()):
        if "dec" not in pat:
            continue

        def fill(cyc_params):
            out = {}
            for j, kind in enumerate(pat):
                if kind != "dec":
                    continue
                xp = cyc_params[f"b{j}"]["xattn"]
                k = jnp.einsum("bsd,dhk->bshk", enc_out,
                               xp["wk"].astype(dt))
                v = jnp.einsum("bsd,dhk->bshk", enc_out,
                               xp["wv"].astype(dt))
                if "bk" in xp:
                    k = k + xp["bk"].astype(dt)
                    v = v + xp["bv"].astype(dt)
                out[f"b{j}"] = {"k": k, "v": v}
            return out

        kvs = jax.vmap(fill)(params[f"stage{i}"])
        sc = dict(cache[f"stage{i}"])
        for j, kind in enumerate(pat):
            if kind != "dec":
                continue
            cross = dict(sc[f"b{j}"]["cross"])
            cross["k"] = kvs[f"b{j}"]["k"]
            cross["v"] = kvs[f"b{j}"]["v"]
            cross["kv_len"] = jnp.broadcast_to(kv_len, (rep,))
            sc[f"b{j}"] = {**sc[f"b{j}"], "cross": cross}
        new_cache[f"stage{i}"] = sc
    return new_cache


def _first_cache_len(cache, cfg: ModelConfig):
    if "stage0" not in cache:  # 0-layer cost variants
        return jnp.zeros((), jnp.int32)
    leaf = cache["stage0"]
    if "b0" in leaf and isinstance(leaf["b0"], dict):
        b0 = leaf["b0"]
        if "self" in b0:
            return b0["self"]["len"][0]
        if "len" in b0:
            return b0["len"][0]
    return jnp.zeros((), jnp.int32)
