"""Griffin / RecurrentGemma recurrent block (RG-LRU, arXiv:2402.19427).

Branches: gate = gelu(x W_gate); rec = RG-LRU(conv1d(x W_rec)); out =
(gate * rec) W_out. The RG-LRU recurrence

    r_t = sigmoid(u_t W_a + b_a);  i_t = sigmoid(u_t W_x + b_x)
    a_t = exp(-c * softplus(Lambda) * r_t)            (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

is evaluated with ``lax.associative_scan`` in training/prefill (log-depth,
no while loop -> exact HLO cost; DESIGN.md roofline methodology) and with a
single fused step in decode. The Pallas ``lru_scan`` kernel is the
TPU-kernel variant used by the serving engine.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..sharding import shard
from .common import ModelConfig, dense_init

_C = 8.0


def init_rglru(cfg: ModelConfig, key) -> dict:
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 7)
    return {
        "w_in_rec": dense_init(ks[0], (d, w), cfg.pdtype),
        "w_in_gate": dense_init(ks[1], (d, w), cfg.pdtype),
        "conv_w": dense_init(ks[2], (cfg.conv_width, w), cfg.pdtype),
        "conv_b": jnp.zeros((w,), cfg.pdtype),
        "w_a": dense_init(ks[3], (w, w), cfg.pdtype),
        "b_a": jnp.zeros((w,), cfg.pdtype),
        "w_x": dense_init(ks[4], (w, w), cfg.pdtype),
        "b_x": jnp.zeros((w,), cfg.pdtype),
        # Lambda parameterized so a in ~(0.9, 0.999) at init
        "lam": jnp.asarray(
            jax.random.uniform(ks[5], (w,), jnp.float32, 0.9, 0.999)),
        "w_out_rec": dense_init(ks[6], (w, d), cfg.pdtype),
    }


def _gates(params, u, cfg: ModelConfig):
    dt = cfg.cdtype
    r = jax.nn.sigmoid(u @ params["w_a"].astype(dt)
                       + params["b_a"].astype(dt)).astype(jnp.float32)
    i = jax.nn.sigmoid(u @ params["w_x"].astype(dt)
                       + params["b_x"].astype(dt)).astype(jnp.float32)
    log_lam = jnp.log(params["lam"].astype(jnp.float32))  # < 0
    log_a = _C * log_lam * r              # softplus folded into lam param
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * i * u.astype(jnp.float32)
    return a, b


def _conv1d(params, u, cfg: ModelConfig, state=None):
    """Causal depthwise conv along time; state: last (width-1) inputs."""
    wt = params["conv_w"].astype(u.dtype)
    width = wt.shape[0]
    if state is None:
        pads = jnp.zeros((u.shape[0], width - 1, u.shape[2]), u.dtype)
    else:
        pads = state.astype(u.dtype)
    xp = jnp.concatenate([pads, u], axis=1)
    out = sum(xp[:, i:i + u.shape[1], :] * wt[i] for i in range(width))
    new_state = xp[:, -(width - 1):, :]
    return out + params["conv_b"].astype(u.dtype), new_state


def apply_rglru(params, x, cfg: ModelConfig):
    """Full-sequence path; x: (B, S, D)."""
    dt = cfg.cdtype
    gate = jax.nn.gelu(x @ params["w_in_gate"].astype(dt))
    gate = shard(gate, "dp", None, "tp")
    u = x @ params["w_in_rec"].astype(dt)
    u = shard(u, "dp", None, "tp")
    u, _ = _conv1d(params, u, cfg)
    a, b = _gates(params, u, cfg)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = h.astype(dt)
    out = (h * gate) @ params["w_out_rec"].astype(dt)
    return shard(out, "dp", None, None)


def apply_rglru_decode(params, x, cache: dict, cfg: ModelConfig):
    """Single-token step; cache: {"h": (B, W), "conv": (B, width-1, W)}."""
    dt = cfg.cdtype
    gate = jax.nn.gelu(x @ params["w_in_gate"].astype(dt))  # (B, 1, W)
    u = x @ params["w_in_rec"].astype(dt)
    u, conv_state = _conv1d(params, u, cfg, state=cache["conv"])
    a, b = _gates(params, u, cfg)                  # (B, 1, W) f32
    h = a[:, 0] * cache["h"] + b[:, 0]
    out = (h[:, None, :].astype(dt) * gate) @ params["w_out_rec"].astype(dt)
    return out, {"h": h, "conv": conv_state}


def make_rglru_cache(cfg: ModelConfig, batch: int) -> dict:
    w = cfg.lru_width or cfg.d_model
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, w), cfg.cdtype)}
