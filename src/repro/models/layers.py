"""Attention (GQA/MQA, full/sliding/prefix/cross, train + decode), MLP, RoPE.

No ``while`` loops inside layer bodies (DESIGN.md roofline methodology): the
query-chunk loop of the flash-style attention is a *Python* loop (static
chunk count), so compiled HLO FLOPs/bytes are exact; the only scans in the
model are the per-stage layer scans, corrected by the roofline module.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..sharding import shard
from .common import ModelConfig, dense_init, rms_head_norm

_NEG = -1e30

# Cost-measurement mode (see DESIGN.md roofline methodology): chunk loops
# unroll so compiled HLO FLOPs/bytes are exact. Default (False) uses
# lax.scan so buffer assignment reuses one chunk's buffers (memory truth).
_COST_MODE = [False]


def set_cost_mode(flag: bool):
    _COST_MODE[0] = bool(flag)


def cost_mode() -> bool:
    return _COST_MODE[0]


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------
def apply_rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); pos: broadcastable to (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    ang = pos[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Attention
# --------------------------------------------------------------------------
def init_attention(cfg: ModelConfig, key, cross: bool = False) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h, hd), cfg.pdtype),
        "wk": dense_init(ks[1], (d, kv, hd), cfg.pdtype),
        "wv": dense_init(ks[2], (d, kv, hd), cfg.pdtype),
        "wo": dense_init(ks[3], (h, hd, d), cfg.pdtype,
                         scale=1.0 / math.sqrt(h * hd)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), cfg.pdtype)
        p["bk"] = jnp.zeros((kv, hd), cfg.pdtype)
        p["bv"] = jnp.zeros((kv, hd), cfg.pdtype)
    if cfg.qk_norm:
        p["q_scale"] = jnp.ones((cfg.hd,), cfg.pdtype)
        p["k_scale"] = jnp.ones((cfg.hd,), cfg.pdtype)
    return p


def _qkv(params, xq, xkv, cfg: ModelConfig, q_pos, kv_pos, use_rope: bool):
    dt = cfg.cdtype
    q = jnp.einsum("bsd,dhk->bshk", xq, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", xkv, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", xkv, params["wv"].astype(dt))
    if "bq" in params:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    if "q_scale" in params:
        q = rms_head_norm(q, params["q_scale"])
        k = rms_head_norm(k, params["k_scale"])
    if use_rope:
        q = apply_rope(q, q_pos, cfg.rope_theta)
        k = apply_rope(k, kv_pos, cfg.rope_theta)
    q = shard(q, "dp", None, "tp", None)
    k = shard(k, "dp", None, None, None)   # kv heads may be < tp: replicate
    v = shard(v, "dp", None, None, None)
    return q, k, v


def _mask(kind: str, q_pos, k_pos, window: int, prefix_len: int):
    """(Q, K) boolean mask from absolute positions."""
    qp = q_pos[:, None]
    kp = k_pos[None, :]
    if kind == "bidir":
        return jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    m = kp <= qp  # causal
    if kind == "window":
        m = m & (kp > qp - window)
    elif kind == "prefix":
        m = m | (kp < prefix_len)
    return m


def attention_full(params, xq, cfg: ModelConfig, *, mask: str = "causal",
                   xkv=None, q_offset: int = 0, prefix_len: int = 0,
                   use_rope: bool = True, q_chunk: int = 512) -> jax.Array:
    """Training/prefill attention; Python-loop chunked over queries.

    For ``mask="window"`` only the (window + chunk) KV band is touched per
    chunk, making 32k-token hybrid prefill O(S*W) instead of O(S^2).
    """
    b, sq, d = xq.shape
    xkv = xq if xkv is None else xkv
    skv = xkv.shape[1]
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    g = h // kvh
    q_pos_all = q_offset + jnp.arange(sq)
    kv_pos_all = jnp.arange(skv)
    q, k, v = _qkv(params, xq, xkv, cfg, q_pos_all, kv_pos_all, use_rope)
    # Expand grouped KV to full heads so attention score tensors shard on
    # the head dim over `model` (XLA keeps the broadcast virtual; GQA param
    # and KV-cache savings are untouched — decode keeps the grouped form).
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    k = shard(k, "dp", None, "tp", None)
    v = shard(v, "dp", None, "tp", None)
    scale = 1.0 / math.sqrt(hd)

    cq = min(q_chunk, sq)
    n_chunks = (sq + cq - 1) // cq
    banded = mask == "window" and skv > cfg.window + cq
    band = cfg.window + cq

    def chunk(i, lo):
        """One q-chunk; ``lo`` may be a traced scalar (scan mode)."""
        qc = jax.lax.dynamic_slice_in_dim(q, lo, cq, axis=1)
        if banded:
            start = jnp.clip(lo + q_offset - cfg.window, 0, skv - band)
            kc = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
            k_pos = start + jnp.arange(band)
        else:
            kc, vc = k, v
            k_pos = kv_pos_all
        logits = jnp.einsum("bqhk,bshk->bhqs", qc, kc).astype(jnp.float32)
        logits = shard(logits, "dp", "tp", None, None)
        logits = logits * scale
        m = _mask(mask, q_offset + lo + jnp.arange(cq), k_pos, cfg.window,
                  prefix_len)
        logits = jnp.where(m[None, None], logits, _NEG)
        probs = jax.nn.softmax(logits, axis=-1).astype(cfg.cdtype)
        return jnp.einsum("bhqs,bshk->bqhk", probs, vc)

    # flash-style recompute: probs are rebuilt in backward, never stored
    chunk_ckpt = jax.checkpoint(chunk, static_argnums=(0,))

    if n_chunks == 1 or cost_mode():
        # unrolled: exact HLO cost for the roofline variant compiles
        o = jnp.concatenate(
            [chunk_ckpt(i, i * cq) for i in range(n_chunks)], axis=1)
    else:
        # scanned: one chunk's buffers live at a time (memory truth)
        def body(_, i):
            return None, chunk_ckpt(0, i * cq)

        _, oc = jax.lax.scan(body, None, jnp.arange(n_chunks))
        o = jnp.moveaxis(oc, 0, 1).reshape(b, sq, h, hd)
    o = shard(o, "dp", None, "tp", None)
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(cfg.cdtype))


def attention_decode(params, xq, cache: dict, cfg: ModelConfig, *,
                     mask: str = "causal", use_rope: bool = True,
                     cross: bool = False):
    """Single-token decode. cache: {"k","v": (B, Smax, KV, hd), "len": ()}.

    Self-attn writes the new KV at position ``len`` (ring-buffer modulo for
    windowed layers); cross-attn reads a precomputed encoder cache.
    """
    b, _, d = xq.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    g = h // kvh
    dt = cfg.cdtype
    pos = cache["len"]
    smax = cache["k"].shape[1]

    q = jnp.einsum("bsd,dhk->bshk", xq, params["wq"].astype(dt))
    if "bq" in params:
        q = q + params["bq"].astype(dt)
    if "q_scale" in params:
        q = rms_head_norm(q, params["q_scale"])
    if use_rope:
        q = apply_rope(q, jnp.full((b, 1), pos), cfg.rope_theta)

    if cross:
        k, v = cache["k"], cache["v"]
        valid = jnp.arange(smax) < cache.get("kv_len", smax)
        new_cache = cache
    else:
        knew = jnp.einsum("bsd,dhk->bshk", xq, params["wk"].astype(dt))
        vnew = jnp.einsum("bsd,dhk->bshk", xq, params["wv"].astype(dt))
        if "bk" in params:
            knew = knew + params["bk"].astype(dt)
            vnew = vnew + params["bv"].astype(dt)
        if "k_scale" in params:
            knew = rms_head_norm(knew, params["k_scale"])
        if use_rope:
            knew = apply_rope(knew, jnp.full((b, 1), pos), cfg.rope_theta)
        slot = pos % smax if mask == "window" else pos
        if "k_scale" in cache:  # int8 KV cache
            kq, ks = _quantize_rows(knew)
            vq, vs = _quantize_rows(vnew)
            new_cache = {
                **cache,
                "k": jax.lax.dynamic_update_slice(cache["k"], kq,
                                                  (0, slot, 0, 0)),
                "v": jax.lax.dynamic_update_slice(cache["v"], vq,
                                                  (0, slot, 0, 0)),
                "k_scale": jax.lax.dynamic_update_slice(
                    cache["k_scale"], ks, (0, slot, 0, 0)),
                "v_scale": jax.lax.dynamic_update_slice(
                    cache["v_scale"], vs, (0, slot, 0, 0)),
                "len": pos + 1,
            }
            k = (new_cache["k"].astype(jnp.float32)
                 * new_cache["k_scale"]).astype(dt)
            v = (new_cache["v"].astype(jnp.float32)
                 * new_cache["v_scale"]).astype(dt)
        else:
            k = jax.lax.dynamic_update_slice(cache["k"], knew.astype(dt),
                                             (0, slot, 0, 0))
            v = jax.lax.dynamic_update_slice(cache["v"], vnew.astype(dt),
                                             (0, slot, 0, 0))
            new_cache = {**cache, "k": k, "v": v, "len": pos + 1}
        if mask == "window":  # ring buffer: all slots < len are valid
            valid = jnp.arange(smax) < jnp.minimum(pos + 1, smax)
        else:
            valid = jnp.arange(smax) <= pos

    qg = q.reshape(b, 1, kvh, g, hd)
    logits = jnp.einsum("bqngh,bsnh->bngqs", qg, k).astype(jnp.float32)
    logits = logits / math.sqrt(hd)
    logits = jnp.where(valid[None, None, None, None, :], logits, _NEG)
    probs = jax.nn.softmax(logits, axis=-1).astype(dt)
    o = jnp.einsum("bngqs,bsnh->bqngh", probs, v).reshape(b, 1, h, hd)
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(dt))
    return out, new_cache


def make_attn_cache(cfg: ModelConfig, batch: int, max_len: int,
                    windowed: bool = False) -> dict:
    size = min(max_len, cfg.window) if windowed and cfg.window else max_len
    shape = (batch, size, cfg.n_kv_heads, cfg.hd)
    if cfg.kv_quant:  # int8 rows + per-(pos, head) scales (§Perf lever)
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(shape[:3] + (1,), jnp.float32),
                "v_scale": jnp.zeros(shape[:3] + (1,), jnp.float32),
                "len": jnp.zeros((), jnp.int32)}
    return {"k": jnp.zeros(shape, cfg.cdtype),
            "v": jnp.zeros(shape, cfg.cdtype),
            "len": jnp.zeros((), jnp.int32)}


def _quantize_rows(x):
    """x (B, 1, KV, hd) -> int8 rows + f32 scales."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------
def init_mlp(cfg: ModelConfig, key, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act in ("swiglu", "geglu"):
        return {"w_gate": dense_init(ks[0], (d, f), cfg.pdtype),
                "w_up": dense_init(ks[1], (d, f), cfg.pdtype),
                "w_down": dense_init(ks[2], (f, d), cfg.pdtype)}
    return {"w_up": dense_init(ks[0], (d, f), cfg.pdtype),
            "w_down": dense_init(ks[1], (f, d), cfg.pdtype)}


def apply_mlp(params, x, cfg: ModelConfig):
    dt = cfg.cdtype
    up = x @ params["w_up"].astype(dt)
    up = shard(up, "dp", None, "tp")
    if "w_gate" in params:
        gate = x @ params["w_gate"].astype(dt)
        gate = shard(gate, "dp", None, "tp")
        h = (jax.nn.silu(gate) if cfg.act == "swiglu"
             else jax.nn.gelu(gate)) * up
    else:
        h = jax.nn.gelu(up)
    out = h @ params["w_down"].astype(dt)
    return shard(out, "dp", None, None)
