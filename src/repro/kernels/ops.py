"""Jit'd public wrappers for the Pallas kernels.

On a real TPU the kernels compile through Mosaic; on this CPU container we
default to ``interpret=True`` (the kernel body runs as traced JAX ops) so
correctness is validated end-to-end. Dry-run/roofline lowering uses the
XLA reference paths so ``cost_analysis()`` reports honest HLO (DESIGN.md §6).

Interpret resolution is policy, not plumbing: every wrapper accepts either
an explicit ``interpret=`` or an :class:`repro.engine.ExecutionConfig`
(``config=``) and defers to ``config.resolve_interpret()`` — the same
policy object that keys the engine's backend registry. The platform
default itself lives in ONE place,
:func:`repro.engine.config.platform_default_interpret`, which both the
config and these wrappers consult, so kernel and engine can never disagree
about execution mode.
"""
from __future__ import annotations

from repro.engine.config import platform_default_interpret

from . import ref
from .mttkrp_kernel import mttkrp_fused as _mttkrp_fused
from .mttkrp_kernel import mttkrp_fused_compact as _mttkrp_fused_compact
from .mttkrp_kernel import mttkrp_fused_gather as _mttkrp_fused_gather
from .mttkrp_kernel import (
    mttkrp_fused_gather_compact as _mttkrp_fused_gather_compact)
from .mttkrp_kernel import mttkrp_fused_remap as _mttkrp_fused_remap
from .mttkrp_kernel import (
    mttkrp_fused_remap_compact as _mttkrp_fused_remap_compact)
from .lru_scan import lru_scan as _lru_scan
from .wkv6 import wkv6 as _wkv6


def resolve_interpret(interpret: bool | None = None, config=None) -> bool:
    """One resolution rule for all kernels: explicit flag > config policy >
    platform default (interpret everywhere but TPU)."""
    if interpret is not None:
        return bool(interpret)
    if config is not None:
        return config.resolve_interpret()
    return platform_default_interpret()


def mttkrp_fused(gathered, val, lrow, *, kappa, rows_pp, blocks_pp, block_p,
                 interpret: bool | None = None, config=None):
    return _mttkrp_fused(gathered, val, lrow, kappa=kappa, rows_pp=rows_pp,
                         blocks_pp=blocks_pp, block_p=block_p,
                         interpret=resolve_interpret(interpret, config))


def mttkrp_fused_compact(gathered, val, lrow, bpart, *, kappa, rows_pp,
                         nblocks, block_p, interpret: bool | None = None,
                         config=None):
    """Descriptor-driven compact-schedule EC baseline (1-D block grid)."""
    return _mttkrp_fused_compact(
        gathered, val, lrow, bpart, kappa=kappa, rows_pp=rows_pp,
        nblocks=nblocks, block_p=block_p,
        interpret=resolve_interpret(interpret, config))


def mttkrp_fused_gather(val, lrow, lidx, factors, *, kappa, rows_pp,
                        blocks_pp, block_p, interpret: bool | None = None,
                        config=None):
    """Zero-HBM-intermediate EC: factor rows gathered inside the kernel."""
    return _mttkrp_fused_gather(
        val, lrow, lidx, tuple(factors), kappa=kappa, rows_pp=rows_pp,
        blocks_pp=blocks_pp, block_p=block_p,
        interpret=resolve_interpret(interpret, config))


def mttkrp_fused_gather_compact(val, lrow, upos, bpart, uidx, nuniq,
                                factors, *, kappa, rows_pp, nblocks,
                                block_p, interpret: bool | None = None,
                                config=None):
    """Compact fused gather with in-block factor-row dedup (U <= P DMAs)."""
    return _mttkrp_fused_gather_compact(
        val, lrow, upos, bpart, uidx, nuniq, tuple(factors), kappa=kappa,
        rows_pp=rows_pp, nblocks=nblocks, block_p=block_p,
        interpret=resolve_interpret(interpret, config))


def mttkrp_fused_remap(val, idx, alpha, lrow, lidx, factors, *, kappa,
                       rows_pp, blocks_pp, block_p, smax, next_mode,
                       interpret: bool | None = None, config=None):
    """Fused EC + Alg. 3 remap scatter (one Pallas pass, four outputs)."""
    return _mttkrp_fused_remap(
        val, idx, alpha, lrow, lidx, tuple(factors), kappa=kappa,
        rows_pp=rows_pp, blocks_pp=blocks_pp, block_p=block_p, smax=smax,
        next_mode=next_mode,
        interpret=resolve_interpret(interpret, config))


def mttkrp_fused_remap_compact(val, idx, alpha, lrow, upos, bpart, uidx,
                               nuniq, factors, *, kappa, rows_pp, nblocks,
                               block_p, smax, next_mode,
                               interpret: bool | None = None, config=None):
    """Compact fused EC + remap with in-block dedup (one pass, 4 outputs)."""
    return _mttkrp_fused_remap_compact(
        val, idx, alpha, lrow, upos, bpart, uidx, nuniq, tuple(factors),
        kappa=kappa, rows_pp=rows_pp, nblocks=nblocks, block_p=block_p,
        smax=smax, next_mode=next_mode,
        interpret=resolve_interpret(interpret, config))


def lru_scan(a, x, *, chunk: int = 32, interpret: bool | None = None,
             config=None):
    return _lru_scan(a, x, chunk=chunk,
                     interpret=resolve_interpret(interpret, config))


def wkv6(r, k, w, v, u, *, chunk: int = 16, interpret: bool | None = None,
         config=None):
    return _wkv6(r, k, w, v, u, chunk=chunk,
                 interpret=resolve_interpret(interpret, config))


__all__ = ["mttkrp_fused", "mttkrp_fused_compact", "mttkrp_fused_gather",
           "mttkrp_fused_gather_compact", "mttkrp_fused_remap",
           "mttkrp_fused_remap_compact", "lru_scan", "wkv6", "ref",
           "resolve_interpret"]
