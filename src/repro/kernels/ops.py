"""Jit'd public wrappers for the Pallas kernels.

On a real TPU the kernels compile through Mosaic; on this CPU container we
default to ``interpret=True`` (the kernel body runs as traced JAX ops) so
correctness is validated end-to-end. Dry-run/roofline lowering uses the
XLA reference paths so ``cost_analysis()`` reports honest HLO (DESIGN.md §6).
"""
from __future__ import annotations

import jax

from . import ref
from .mttkrp_kernel import mttkrp_fused as _mttkrp_fused
from .lru_scan import lru_scan as _lru_scan
from .wkv6 import wkv6 as _wkv6


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def mttkrp_fused(gathered, val, lrow, *, kappa, rows_pp, blocks_pp, block_p,
                 interpret: bool | None = None):
    if interpret is None:
        interpret = _default_interpret()
    return _mttkrp_fused(gathered, val, lrow, kappa=kappa, rows_pp=rows_pp,
                         blocks_pp=blocks_pp, block_p=block_p,
                         interpret=interpret)


def lru_scan(a, x, *, chunk: int = 32, interpret: bool | None = None):
    if interpret is None:
        interpret = _default_interpret()
    return _lru_scan(a, x, chunk=chunk, interpret=interpret)


def wkv6(r, k, w, v, u, *, chunk: int = 16, interpret: bool | None = None):
    if interpret is None:
        interpret = _default_interpret()
    return _wkv6(r, k, w, v, u, chunk=chunk, interpret=interpret)


__all__ = ["mttkrp_fused", "lru_scan", "wkv6", "ref"]
