"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mttkrp_fused_ref(gathered, val, lrow, *, kappa, rows_pp, blocks_pp,
                     block_p):
    """Oracle for kernels.mttkrp_kernel.mttkrp_fused (rect schedule)."""
    s = gathered.shape[0]
    part = jnp.arange(s, dtype=jnp.int32) // (blocks_pp * block_p)
    return _segment_reduce(gathered, val, lrow, part, kappa, rows_pp)


def mttkrp_fused_compact_ref(gathered, val, lrow, bpart, *, kappa, rows_pp,
                             block_p):
    """Oracle for the compact-schedule kernels: the owning partition comes
    from the block->partition descriptor instead of a fixed stride."""
    s = gathered.shape[0]
    slot = jnp.arange(s, dtype=jnp.int32)
    part = jnp.take(bpart, slot // block_p, axis=0)
    return _segment_reduce(gathered, val, lrow, part, kappa, rows_pp)


def _segment_reduce(gathered, val, lrow, part, kappa, rows_pp):
    ell = jnp.prod(gathered, axis=1) * val[:, None].astype(jnp.float32)
    gid = jnp.where(lrow < 0, 0, part * rows_pp + lrow)
    ell = jnp.where((lrow < 0)[:, None], 0.0, ell)
    return jax.ops.segment_sum(ell, gid, num_segments=kappa * rows_pp)


def lru_scan_ref(a, x):
    """Oracle for kernels.lru_scan.lru_scan: h_t = a_t h_{t-1} + x_t."""
    a = a.astype(jnp.float32)
    x = x.astype(jnp.float32)

    def step(h, inp):
        at, xt = inp
        h = at * h + xt
        return h, h

    h0 = jnp.zeros((x.shape[0], x.shape[2]), jnp.float32)
    _, hs = jax.lax.scan(step, h0, (a.swapaxes(0, 1), x.swapaxes(0, 1)))
    return hs.swapaxes(0, 1)


def wkv6_ref(r, k, w, v, u):
    """Oracle for kernels.wkv6.wkv6."""
    f32 = jnp.float32
    r, k, w, v, u = (t.astype(f32) for t in (r, k, w, v, u))

    def one_head(r, k, w, v, u):
        def step(s, inp):
            rt, kt, wt, vt = inp
            kv = kt[:, None] * vt[None, :]
            y = (rt * u) @ kv + rt @ s
            s = wt[:, None] * s + kv
            return s, y

        s0 = jnp.zeros((r.shape[-1], v.shape[-1]), f32)
        _, ys = jax.lax.scan(step, s0, (r, k, w, v))
        return ys

    return jax.vmap(one_head)(r, k, w, v, u)
