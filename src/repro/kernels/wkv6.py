"""RWKV-6 (Finch) WKV recurrence Pallas TPU kernel (arXiv:2404.05892).

Per head with key dim K and value dim V, data-dependent decay w_t:

    y_t = (r_t . u) (k_t v_t^T) + r_t^T S_{t-1}
    S_t = diag(w_t) S_{t-1} + k_t v_t^T

The (K, V) state matrix lives in VMEM scratch across time chunks; the grid is
(batch*heads, time-chunks) with time innermost so each (bh) row's state
survives its whole scan. Within a chunk the loop is statically unrolled; the
rank-1 update k v^T and the readout r^T S are MXU-shaped contractions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _SCRATCH = lambda shape: pltpu.VMEM(shape, jnp.float32)  # noqa: E731
except Exception:  # pragma: no cover
    _SCRATCH = lambda shape: pl.MemorySpace.ANY  # noqa: E731


def _wkv_kernel(r_ref, k_ref, w_ref, v_ref, u_ref, o_ref, s_ref, *,
                chunk: int):
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    s = s_ref[...]                     # (K, V) state
    u = u_ref[...][0]                  # (K,) per-head bonus
    for c in range(chunk):             # static unroll
        r = r_ref[...][0, c, :]        # (K,)
        k = k_ref[...][0, c, :]
        w = w_ref[...][0, c, :]
        v = v_ref[...][0, c, :]        # (V,)
        kv = k[:, None] * v[None, :]   # (K, V) rank-1 update
        y = jnp.dot((r * u)[None, :], kv,
                    preferred_element_type=jnp.float32) + jnp.dot(
            r[None, :], s, preferred_element_type=jnp.float32)
        o_ref[0, c, :] = y[0]
        s = w[:, None] * s + kv
    s_ref[...] = s


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r: jax.Array, k: jax.Array, w: jax.Array, v: jax.Array,
         u: jax.Array, *, chunk: int = 16,
         interpret: bool = False) -> jax.Array:
    """r,k,w: (BH, T, K); v: (BH, T, V); u: (BH, K) -> y: (BH, T, V)."""
    bh, t, kd = r.shape
    vd = v.shape[-1]
    assert t % chunk == 0, (t, chunk)
    f32 = jnp.float32
    return pl.pallas_call(
        functools.partial(_wkv_kernel, chunk=chunk),
        grid=(bh, t // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, kd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, chunk, kd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, chunk, kd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, chunk, vd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, kd), lambda b, i: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, vd), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t, vd), f32),
        scratch_shapes=[_SCRATCH((kd, vd))],
        interpret=interpret,
    )(r.astype(f32), k.astype(f32), w.astype(f32), v.astype(f32),
      u.astype(f32))
