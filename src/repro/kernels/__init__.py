"""Pallas TPU kernels for the perf-critical hot spots (+ jnp oracles).

  mttkrp_kernel  fused Hadamard + one-hot MXU segment reduction (the paper's
                 thread-block kernel, TPU-native; DESIGN.md §2)
  lru_scan       RG-LRU linear recurrence, VMEM-resident state
  wkv6           RWKV-6 data-dependent-decay recurrence

Validated on CPU with interpret=True against ref.py; compiled via Mosaic on
real TPUs. ops.py wraps each with backend-aware defaults.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
