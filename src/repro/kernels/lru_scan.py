"""RG-LRU gated linear recurrence Pallas TPU kernel (Griffin, arXiv:2402.19427).

Computes h_t = a_t * h_{t-1} + x_t over the time axis, with the recurrent
state resident in VMEM scratch across sequence chunks. The grid walks
(time-chunks,); within a chunk the loop is unrolled (static ``chunk``) so
every step is a fully vectorized (B, D) VPU op — the TPU analogue of the
recurrence being register-resident.

Used by the recurrentgemma-9b blocks and by long-context serving, where the
O(1)-state scan is what makes ``long_500k`` feasible (DESIGN.md §5).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU memory spaces; interpret mode works without a real TPU
    from jax.experimental.pallas import tpu as pltpu
    _SCRATCH = lambda shape: pltpu.VMEM(shape, jnp.float32)  # noqa: E731
except Exception:  # pragma: no cover
    _SCRATCH = lambda shape: pl.MemorySpace.ANY  # noqa: E731


def _lru_kernel(a_ref, x_ref, o_ref, h_ref, *, chunk: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    h = h_ref[...]                      # (B, D) carry
    a = a_ref[...]                      # (B, C, D)
    x = x_ref[...]
    for c in range(chunk):              # static unroll: VPU steps
        h = a[:, c, :] * h + x[:, c, :]
        o_ref[:, c, :] = h
    h_ref[...] = h


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def lru_scan(a: jax.Array, x: jax.Array, *, chunk: int = 32,
             interpret: bool = False) -> jax.Array:
    """h_t = a_t * h_{t-1} + x_t ;  a, x: (B, T, D) -> h: (B, T, D)."""
    b, t, d = x.shape
    assert a.shape == x.shape
    assert t % chunk == 0, (t, chunk)
    return pl.pallas_call(
        functools.partial(_lru_kernel, chunk=chunk),
        grid=(t // chunk,),
        in_specs=[
            pl.BlockSpec((b, chunk, d), lambda i: (0, i, 0)),
            pl.BlockSpec((b, chunk, d), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((b, chunk, d), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, t, d), jnp.float32),
        scratch_shapes=[_SCRATCH((b, d))],
        interpret=interpret,
    )(a.astype(jnp.float32), x.astype(jnp.float32))
