"""Fused spMTTKRP elementwise-computation Pallas TPU kernel.

This is the TPU adaptation of the paper's thread-block kernel (Alg. 2/4):

  * grid = (kappa, blocks_pp): partition j's nonzero blocks iterate with the
    *output row tile resident in VMEM* — the paper's "intermediate values
    never visit global memory" (its challenge (2)) becomes "the (P, R)
    Hadamard partials live in VREGs and the (rows_pp, R) accumulator lives in
    VMEM for the whole partition".
  * the scatter-add that GPUs do with intra-block atomics becomes a one-hot
    MXU contraction: out_tile += onehot(lrow)^T @ partials, a dense
    (rows_pp x P) @ (P x R) matmul — the TPU-idiomatic segment reduction.
  * ownership (paper Observation 2): partition j's elements touch only rows
    [j*rows_pp, (j+1)*rows_pp), so the output BlockSpec depends on j alone
    and no cross-block reduction exists.

Pad slots carry lrow = -1; the one-hot comparison yields an all-zero column
for them, so they contribute nothing (their val is 0 anyway).

Block shape knobs mirror the paper's R x P thread block (Fig. 4): P is the
number of nonzeros entering per step (paper picks P=32 for 1024-thread
blocks; we default P=128 = one sublane tile), R is the rank (lane dim).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _ec_kernel(gathered_ref, val_ref, lrow_ref, out_ref, *, rows_pp: int):
    """One (partition j, block t) grid step."""
    t = pl.program_id(1)

    g = gathered_ref[...]                      # (P, N-1, R) f32
    ell = g[:, 0, :]
    for w in range(1, g.shape[1]):             # Hadamard across input modes
        ell = ell * g[:, w, :]                 # (Alg. 2 lines 11-13)
    ell = ell * val_ref[...]                   # (P, 1) broadcast: * val_i

    lrow = lrow_ref[...][:, 0]                 # (P,) local output row ids
    p = lrow.shape[0]
    # Scatter-add as a one-hot MXU matmul (no atomics on TPU; DESIGN.md §2).
    onehot = (
        lax.broadcasted_iota(jnp.int32, (rows_pp, p), 0) == lrow[None, :]
    ).astype(jnp.float32)                      # (rows_pp, P); -1 rows vanish
    contrib = jnp.dot(onehot, ell, preferred_element_type=jnp.float32)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += contrib


@functools.partial(
    jax.jit,
    static_argnames=("kappa", "rows_pp", "blocks_pp", "block_p", "interpret"),
)
def mttkrp_fused(
    gathered: jax.Array,   # (S, N-1, R) gathered input-factor rows
    val: jax.Array,        # (S,) nonzero values (0 in pads)
    lrow: jax.Array,       # (S,) local output rows (-1 in pads)
    *,
    kappa: int,
    rows_pp: int,
    blocks_pp: int,
    block_p: int,
    interpret: bool = False,
) -> jax.Array:
    """Returns out_rel (kappa*rows_pp, R) in relabeled row space."""
    s, nm1, r = gathered.shape
    assert s == kappa * blocks_pp * block_p, (s, kappa, blocks_pp, block_p)
    val2 = val.reshape(s, 1).astype(jnp.float32)
    lrow2 = lrow.reshape(s, 1).astype(jnp.int32)

    def elem_map(j, t, bpp=blocks_pp):
        return (j * bpp + t, 0)

    def elem_map3(j, t, bpp=blocks_pp):
        return (j * bpp + t, 0, 0)

    return pl.pallas_call(
        functools.partial(_ec_kernel, rows_pp=rows_pp),
        grid=(kappa, blocks_pp),
        in_specs=[
            pl.BlockSpec((block_p, nm1, r), elem_map3),
            pl.BlockSpec((block_p, 1), elem_map),
            pl.BlockSpec((block_p, 1), elem_map),
        ],
        out_specs=pl.BlockSpec((rows_pp, r), lambda j, t: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((kappa * rows_pp, r), jnp.float32),
        interpret=interpret,
    )(gathered, val2, lrow2)
