"""Fused spMTTKRP elementwise-computation Pallas TPU kernels.

This is the TPU adaptation of the paper's thread-block kernel (Alg. 2/4):

  * the grid walks nonzero blocks with the *output row tile resident in
    VMEM* — the paper's "intermediate values never visit global memory"
    (its challenge (2)) becomes "the (P, R) Hadamard partials live in VREGs
    and the (rows_pp, R) accumulator lives in VMEM for the whole partition".
  * the scatter-add that GPUs do with intra-block atomics becomes a one-hot
    MXU contraction: out_tile += onehot(lrow)^T @ partials, a dense
    (rows_pp x P) @ (P x R) matmul — the TPU-idiomatic segment reduction.
  * ownership (paper Observation 2): partition j's elements touch only rows
    [j*rows_pp, (j+1)*rows_pp), so the output BlockSpec depends on j alone
    and no cross-block reduction exists.

Pad slots carry lrow = -1; the one-hot comparison yields an all-zero column
for them, so they contribute nothing even when a pad val is nonzero.

Grid schedules (paper challenge (3): balanced block workloads):

  *rect*      grid = (kappa, blocks_pp): every partition padded to the max
              partition's block count. Simple, but on skewed tensors most
              grid steps process pure padding — kept as the baseline.
  *compact*   grid = (nblocks,): a 1-D walk over only the real blocks. The
              host plan emits a ``(nblocks,)`` block->partition descriptor
              (``bpart``) which is *scalar-prefetched*; the output BlockSpec
              index map reads ``bpart[b]`` to pick the resident row tile and
              the accumulator init keys off "first block of my partition"
              (``bpart[b] != bpart[b-1]``).

Pipelines (x2 schedules):

  ``mttkrp_fused[_compact]``        take a pre-gathered ``(S, N-1, R)``
                                    operand that XLA materializes in HBM —
                                    the comparison baseline (engine backend
                                    ``pallas``).
  ``mttkrp_fused_gather[_compact]`` zero-HBM-intermediate pipeline (engine
                                    backend ``pallas_fused``): factor
                                    matrices stay in ``ANY``/HBM and each
                                    grid step DMAs the needed rows into a
                                    double-buffered VMEM stage (block b+1's
                                    gather in flight while block b
                                    computes). The compact variant adds
                                    *in-block factor-row dedup*: the plan
                                    pre-sorts each block's factor-row list
                                    into ``U <= P`` unique rows (``uidx`` /
                                    ``nuniq``, scalar-prefetched) so the
                                    kernel issues ``U`` row DMAs instead of
                                    ``P`` — Zipf-heavy tensors re-fetch hot
                                    rows many times per block otherwise —
                                    and the EC body gathers its Hadamard
                                    operands through the per-slot stage
                                    positions ``upos`` with a one-hot MXU
                                    select (no dynamic VMEM gather needed).
  ``mttkrp_fused_remap[_compact]``  same pass, plus the Alg. 3 dynamic
                                    remap: the kernel scatters each alive
                                    slot's (val, idx, alpha) row to its
                                    ``alpha[:, next]`` destination in
                                    VMEM-resident next-layout buffers,
                                    replacing three full-``S_max`` XLA
                                    scatters per scan step.

Block shape knobs mirror the paper's R x P thread block (Fig. 4): P is the
number of nonzeros entering per step (paper picks P=32 for 1024-thread
blocks; we default P=128 = one sublane tile), R is the rank (lane dim).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ec_compute(parts, val_ref, lrow_ref, out_ref, *, rows_pp: int, first):
    """Shared EC body of all pipelines: Hadamard the staged factor rows,
    scale by val, one-hot-MXU segment-reduce into the resident out tile.
    ``parts`` is the per-input-mode list of (P, R) row blocks (however they
    were staged — HBM operand or in-kernel DMA); ``first`` is true on the
    first grid step owning this output tile (accumulator init)."""
    ell = parts[0]
    for part in parts[1:]:                     # Hadamard across input modes
        ell = ell * part                       # (Alg. 2 lines 11-13)
    ell = ell * val_ref[...]                   # (P, 1) broadcast: * val_i

    lrow = lrow_ref[...][:, 0]                 # (P,) local output row ids
    p = lrow.shape[0]
    # Scatter-add as a one-hot MXU matmul (no atomics on TPU; DESIGN.md §2).
    onehot = (
        lax.broadcasted_iota(jnp.int32, (rows_pp, p), 0) == lrow[None, :]
    ).astype(jnp.float32)                      # (rows_pp, P); -1 rows vanish
    contrib = jnp.dot(onehot, ell, preferred_element_type=jnp.float32)

    @pl.when(first)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += contrib


def _compact_first(bpart_ref, b):
    """Accumulator-init predicate under the compact schedule: this block is
    the first of its partition (the descriptor is nondecreasing)."""
    part = bpart_ref[b]
    prev = bpart_ref[jnp.maximum(b - 1, 0)]
    return jnp.logical_or(b == 0, part != prev)


def _ec_kernel(gathered_ref, val_ref, lrow_ref, out_ref, *, rows_pp: int):
    """One (partition j, block t) rect grid step."""
    g = gathered_ref[...]                      # (P, N-1, R) f32
    _ec_compute([g[:, w, :] for w in range(g.shape[1])], val_ref, lrow_ref,
                out_ref, rows_pp=rows_pp, first=pl.program_id(1) == 0)


def _compact_ec_kernel(bpart_ref, gathered_ref, val_ref, lrow_ref, out_ref,
                       *, rows_pp: int):
    """One block of the descriptor-driven compact grid (pre-gathered)."""
    g = gathered_ref[...]
    _ec_compute([g[:, w, :] for w in range(g.shape[1])], val_ref, lrow_ref,
                out_ref, rows_pp=rows_pp,
                first=_compact_first(bpart_ref, pl.program_id(0)))


@functools.partial(
    jax.jit,
    static_argnames=("kappa", "rows_pp", "blocks_pp", "block_p", "interpret"),
)
def mttkrp_fused(
    gathered: jax.Array,   # (S, N-1, R) gathered input-factor rows
    val: jax.Array,        # (S,) nonzero values (0 in pads)
    lrow: jax.Array,       # (S,) local output rows (-1 in pads)
    *,
    kappa: int,
    rows_pp: int,
    blocks_pp: int,
    block_p: int,
    interpret: bool = False,
) -> jax.Array:
    """Returns out_rel (kappa*rows_pp, R) in relabeled row space."""
    s, nm1, r = gathered.shape
    assert s == kappa * blocks_pp * block_p, (s, kappa, blocks_pp, block_p)
    val2 = val.reshape(s, 1).astype(jnp.float32)
    lrow2 = lrow.reshape(s, 1).astype(jnp.int32)

    def elem_map(j, t, bpp=blocks_pp):
        return (j * bpp + t, 0)

    def elem_map3(j, t, bpp=blocks_pp):
        return (j * bpp + t, 0, 0)

    return pl.pallas_call(
        functools.partial(_ec_kernel, rows_pp=rows_pp),
        grid=(kappa, blocks_pp),
        in_specs=[
            pl.BlockSpec((block_p, nm1, r), elem_map3),
            pl.BlockSpec((block_p, 1), elem_map),
            pl.BlockSpec((block_p, 1), elem_map),
        ],
        out_specs=pl.BlockSpec((rows_pp, r), lambda j, t: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((kappa * rows_pp, r), jnp.float32),
        interpret=interpret,
    )(gathered, val2, lrow2)


@functools.partial(
    jax.jit,
    static_argnames=("kappa", "rows_pp", "nblocks", "block_p", "interpret"),
)
def mttkrp_fused_compact(
    gathered: jax.Array,   # (S, N-1, R) gathered input-factor rows
    val: jax.Array,        # (S,) nonzero values (0 in pads)
    lrow: jax.Array,       # (S,) local output rows (-1 in pads)
    bpart: jax.Array,      # (nblocks,) block -> partition descriptor
    *,
    kappa: int,
    rows_pp: int,
    nblocks: int,
    block_p: int,
    interpret: bool = False,
) -> jax.Array:
    """Compact-schedule EC baseline: a 1-D grid over real blocks only, the
    output tile picked by the scalar-prefetched descriptor."""
    s, nm1, r = gathered.shape
    assert s == nblocks * block_p, (s, nblocks, block_p)
    val2 = val.reshape(s, 1).astype(jnp.float32)
    lrow2 = lrow.reshape(s, 1).astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((block_p, nm1, r), lambda b, bp: (b, 0, 0)),
            pl.BlockSpec((block_p, 1), lambda b, bp: (b, 0)),
            pl.BlockSpec((block_p, 1), lambda b, bp: (b, 0)),
        ],
        out_specs=pl.BlockSpec((rows_pp, r), lambda b, bp: (bp[b], 0)),
        scratch_shapes=[],
    )
    return pl.pallas_call(
        functools.partial(_compact_ec_kernel, rows_pp=rows_pp),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((kappa * rows_pp, r), jnp.float32),
        interpret=interpret,
    )(bpart.astype(jnp.int32), gathered, val2, lrow2)


# --------------------------------------------------------------------------
# Zero-HBM-intermediate pipeline: in-kernel gather (+ optional remap).
# --------------------------------------------------------------------------
def _remap_init_and_scatter(b, val_ref, idx_ref, alpha_ref, nval_ref,
                            nidx_ref, nalpha_ref, *, block_p: int,
                            next_mode: int):
    """Alg. 3 in-kernel: initialize the resident next-layout buffers on the
    first grid step, then scatter every alive slot to its
    ``alpha[:, next_mode]`` destination (conflict-free by construction —
    destinations are a permutation of the alive slots; pads carry -1)."""

    @pl.when(b == 0)
    def _init_next_layout():
        nval_ref[...] = jnp.zeros_like(nval_ref)
        nidx_ref[...] = jnp.zeros_like(nidx_ref)
        nalpha_ref[...] = jnp.full_like(nalpha_ref, -1)

    def scatter(i, _):
        d = alpha_ref[i, next_mode]

        @pl.when(d >= 0)
        def _move():
            nval_ref[pl.ds(d, 1), :] = val_ref[pl.ds(i, 1), :]
            nidx_ref[pl.ds(d, 1), :] = idx_ref[pl.ds(i, 1), :]
            nalpha_ref[pl.ds(d, 1), :] = alpha_ref[pl.ds(i, 1), :]
        return 0

    lax.fori_loop(0, block_p, scatter, 0)


def _fused_gather_kernel(lidx_ref, *refs, nm1: int, rows_pp: int,
                         blocks_pp: int, block_p: int, nblocks: int,
                         next_mode: int | None):
    """One (partition j, block t) step of the rect fused pipeline.

    ``lidx_ref`` is the scalar-prefetched ``(N-1, S)`` factor-row index
    table (SMEM). The input factors live in ``ANY`` (HBM on TPU); their
    needed rows are DMA'd into the two-slot VMEM stage ``scratch`` so block
    ``b+1``'s gather overlaps block ``b``'s compute. With ``next_mode``
    set, the kernel additionally owns VMEM-resident next-layout buffers and
    scatters every alive slot to its ``alpha[:, next_mode]`` destination.
    """
    with_remap = next_mode is not None
    if with_remap:
        val_ref, lrow_ref, idx_ref, alpha_ref = refs[:4]
        facs = refs[4:4 + nm1]
        (out_ref, nval_ref, nidx_ref, nalpha_ref,
         scratch, sems) = refs[4 + nm1:]
    else:
        val_ref, lrow_ref = refs[:2]
        facs = refs[2:2 + nm1]
        out_ref, scratch, sems = refs[2 + nm1:]

    j = pl.program_id(0)
    t = pl.program_id(1)
    b = j * blocks_pp + t
    slot = b % 2

    def gather(block, sl, wait: bool):
        # One (1, R) row copy per (factor, slot); starts and waits pair up
        # through the per-buffer DMA semaphore ``sems[sl]``.
        for w, f in enumerate(facs):
            def body(i, _, w=w, f=f):
                row = lidx_ref[w, block * block_p + i]
                cp = pltpu.make_async_copy(
                    f.at[pl.ds(row, 1)],
                    scratch.at[sl, w, pl.ds(i, 1)],
                    sems.at[sl])
                (cp.wait if wait else cp.start)()
                return 0

            lax.fori_loop(0, block_p, body, 0)

    @pl.when(b == 0)
    def _prologue():                       # block 0 has nobody to hide under
        gather(0, 0, wait=False)

    @pl.when(b + 1 < nblocks)
    def _prefetch_next():                  # overlap: issue b+1, compute b
        gather(b + 1, (b + 1) % 2, wait=False)

    gather(b, slot, wait=True)

    g = scratch[pl.ds(slot, 1)][0]         # (N-1, P, R) staged factor rows
    _ec_compute([g[w] for w in range(nm1)], val_ref, lrow_ref, out_ref,
                rows_pp=rows_pp, first=t == 0)

    if with_remap:
        _remap_init_and_scatter(b, val_ref, idx_ref, alpha_ref, nval_ref,
                                nidx_ref, nalpha_ref, block_p=block_p,
                                next_mode=next_mode)


def _compact_gather_kernel(bpart_ref, uidx_ref, nuniq_ref, *refs, nm1: int,
                           rows_pp: int, block_p: int, nblocks: int,
                           next_mode: int | None):
    """One block of the compact fused pipeline with in-block row dedup.

    Scalar-prefetched tables: ``bpart (nblocks,)`` block->partition,
    ``uidx (N-1, S)`` per-block unique factor rows (front-compacted),
    ``nuniq (N-1, nblocks)`` per-block unique counts. Each grid step DMAs
    only the ``U = nuniq[w, b] <= P`` unique rows of every input factor
    into the double-buffered VMEM stage; the EC body routes each slot to
    its staged row through ``upos`` (a one-hot MXU select — no dynamic
    VMEM gather). With ``next_mode`` set the same pass owns the resident
    next-layout buffers and scatters the Alg. 3 remap.
    """
    with_remap = next_mode is not None
    if with_remap:
        val_ref, lrow_ref, upos_ref, idx_ref, alpha_ref = refs[:5]
        facs = refs[5:5 + nm1]
        (out_ref, nval_ref, nidx_ref, nalpha_ref,
         scratch, sems) = refs[5 + nm1:]
    else:
        val_ref, lrow_ref, upos_ref = refs[:3]
        facs = refs[3:3 + nm1]
        out_ref, scratch, sems = refs[3 + nm1:]

    b = pl.program_id(0)
    slot = b % 2

    # The one-hot stage-select below reads the WHOLE staged block (rows
    # >= U included, weighted 0); zero the stage once so step 0/1 never
    # multiplies uninitialized VMEM (0 * garbage need not be 0). Later
    # steps only ever see stale-but-finite factor rows.
    @pl.when(b == 0)
    def _zero_stage():
        scratch[...] = jnp.zeros_like(scratch)

    def gather(block, sl, wait: bool):
        # U row copies per factor instead of P: hot rows fetched once.
        for w, f in enumerate(facs):
            def body(u, _, w=w, f=f):
                row = uidx_ref[w, block * block_p + u]
                cp = pltpu.make_async_copy(
                    f.at[pl.ds(row, 1)],
                    scratch.at[sl, w, pl.ds(u, 1)],
                    sems.at[sl])
                (cp.wait if wait else cp.start)()
                return 0

            lax.fori_loop(0, nuniq_ref[w, block], body, 0)

    @pl.when(b == 0)
    def _prologue():                       # block 0 has nobody to hide under
        gather(0, 0, wait=False)

    @pl.when(b + 1 < nblocks)
    def _prefetch_next():                  # overlap: issue b+1, compute b
        gather(b + 1, (b + 1) % 2, wait=False)

    gather(b, slot, wait=True)

    g = scratch[pl.ds(slot, 1)][0]         # (N-1, P, R) staged unique rows
    pos = upos_ref[...]                    # (P, N-1) per-slot stage position
    parts = []
    for w in range(nm1):
        # slot i's operand row = staged[pos[i]]: a (P x P) one-hot select
        # matmul (MXU-friendly; dynamic VMEM gathers are not).
        sel = (
            pos[:, w][:, None]
            == lax.broadcasted_iota(jnp.int32, (block_p, block_p), 1)
        ).astype(jnp.float32)
        parts.append(jnp.dot(sel, g[w], preferred_element_type=jnp.float32))

    _ec_compute(parts, val_ref, lrow_ref, out_ref, rows_pp=rows_pp,
                first=_compact_first(bpart_ref, b))

    if with_remap:
        _remap_init_and_scatter(b, val_ref, idx_ref, alpha_ref, nval_ref,
                                nidx_ref, nalpha_ref, block_p=block_p,
                                next_mode=next_mode)


def _fused_specs(nm1: int, r: int, block_p: int, blocks_pp: int,
                 rows_pp: int):
    """Shared in/out specs of the rect fused pipelines (scalar-prefetch
    aware: index maps take the prefetch ref as trailing argument)."""
    def eblk(j, t, lidx, bpp=blocks_pp):
        return (j * bpp + t, 0)

    elem = pl.BlockSpec((block_p, 1), eblk)
    fac = pl.BlockSpec(memory_space=pltpu.ANY)
    out = pl.BlockSpec((rows_pp, r), lambda j, t, lidx: (j, 0))
    scratch = [pltpu.VMEM((2, nm1, block_p, r), jnp.float32),
               pltpu.SemaphoreType.DMA((2,))]
    return elem, fac, out, scratch


def _compact_fused_specs(nm1: int, r: int, block_p: int, rows_pp: int):
    """Shared in/out specs of the compact fused pipelines. Index maps take
    the three prefetch refs (bpart, uidx, nuniq) as trailing arguments; the
    output tile is the descriptor lookup."""
    def eblk(b, bp, ui, nu):
        return (b, 0)

    elem = pl.BlockSpec((block_p, 1), eblk)
    posb = pl.BlockSpec((block_p, nm1), eblk)
    fac = pl.BlockSpec(memory_space=pltpu.ANY)
    out = pl.BlockSpec((rows_pp, r), lambda b, bp, ui, nu: (bp[b], 0))
    scratch = [pltpu.VMEM((2, nm1, block_p, r), jnp.float32),
               pltpu.SemaphoreType.DMA((2,))]
    return elem, posb, fac, out, scratch


@functools.partial(
    jax.jit,
    static_argnames=("kappa", "rows_pp", "blocks_pp", "block_p", "interpret"),
)
def mttkrp_fused_gather(
    val: jax.Array,        # (S,) nonzero values (0 in pads)
    lrow: jax.Array,       # (S,) local output rows (-1 in pads)
    lidx: jax.Array,       # (N-1, S) input-factor row per slot (prefetched)
    factors: tuple,        # N-1 arrays (I_w, R), kept in ANY/HBM
    *,
    kappa: int,
    rows_pp: int,
    blocks_pp: int,
    block_p: int,
    interpret: bool = False,
) -> jax.Array:
    """EC with the factor gather fused into the kernel grid; returns
    out_rel (kappa*rows_pp, R) without materializing (S, N-1, R) in HBM."""
    s = val.shape[0]
    nm1 = len(factors)
    r = factors[0].shape[1]
    nblocks = kappa * blocks_pp
    assert s == nblocks * block_p, (s, kappa, blocks_pp, block_p)
    assert lidx.shape == (nm1, s), (lidx.shape, nm1, s)
    val2 = val.reshape(s, 1).astype(jnp.float32)
    lrow2 = lrow.reshape(s, 1).astype(jnp.int32)

    elem, fac, out, scratch = _fused_specs(nm1, r, block_p, blocks_pp,
                                           rows_pp)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(kappa, blocks_pp),
        in_specs=[elem, elem] + [fac] * nm1,
        out_specs=out,
        scratch_shapes=scratch,
    )
    return pl.pallas_call(
        functools.partial(_fused_gather_kernel, nm1=nm1, rows_pp=rows_pp,
                          blocks_pp=blocks_pp, block_p=block_p,
                          nblocks=nblocks, next_mode=None),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((kappa * rows_pp, r), jnp.float32),
        interpret=interpret,
    )(lidx.astype(jnp.int32), val2, lrow2, *factors)


@functools.partial(
    jax.jit,
    static_argnames=("kappa", "rows_pp", "nblocks", "block_p", "interpret"),
)
def mttkrp_fused_gather_compact(
    val: jax.Array,        # (S,) nonzero values (0 in pads)
    lrow: jax.Array,       # (S,) local output rows (-1 in pads)
    upos: jax.Array,       # (S, N-1) per-slot stage position (0 in pads)
    bpart: jax.Array,      # (nblocks,) block -> partition (prefetched)
    uidx: jax.Array,       # (N-1, S) per-block unique rows (prefetched)
    nuniq: jax.Array,      # (N-1, nblocks) unique counts (prefetched)
    factors: tuple,        # N-1 arrays (I_w, R), kept in ANY/HBM
    *,
    kappa: int,
    rows_pp: int,
    nblocks: int,
    block_p: int,
    interpret: bool = False,
) -> jax.Array:
    """Compact-schedule fused gather with in-block row dedup; returns
    out_rel (kappa*rows_pp, R)."""
    s = val.shape[0]
    nm1 = len(factors)
    r = factors[0].shape[1]
    assert s == nblocks * block_p, (s, nblocks, block_p)
    assert uidx.shape == (nm1, s) and upos.shape == (s, nm1)
    assert nuniq.shape == (nm1, nblocks)
    val2 = val.reshape(s, 1).astype(jnp.float32)
    lrow2 = lrow.reshape(s, 1).astype(jnp.int32)

    elem, posb, fac, out, scratch = _compact_fused_specs(nm1, r, block_p,
                                                         rows_pp)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(nblocks,),
        in_specs=[elem, elem, posb] + [fac] * nm1,
        out_specs=out,
        scratch_shapes=scratch,
    )
    return pl.pallas_call(
        functools.partial(_compact_gather_kernel, nm1=nm1, rows_pp=rows_pp,
                          block_p=block_p, nblocks=nblocks, next_mode=None),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((kappa * rows_pp, r), jnp.float32),
        interpret=interpret,
    )(bpart.astype(jnp.int32), uidx.astype(jnp.int32),
      nuniq.astype(jnp.int32), val2, lrow2, upos.astype(jnp.int32),
      *factors)


@functools.partial(
    jax.jit,
    static_argnames=("kappa", "rows_pp", "blocks_pp", "block_p", "smax",
                     "next_mode", "interpret"),
)
def mttkrp_fused_remap(
    val: jax.Array,        # (S,) nonzero values (0 in pads)
    idx: jax.Array,        # (S, N) original indices
    alpha: jax.Array,      # (S, N) per-mode slot table (-1 in pads)
    lrow: jax.Array,       # (S,) local output rows (-1 in pads)
    lidx: jax.Array,       # (N-1, S) input-factor row per slot (prefetched)
    factors: tuple,        # N-1 arrays (I_w, R), kept in ANY/HBM
    *,
    kappa: int,
    rows_pp: int,
    blocks_pp: int,
    block_p: int,
    smax: int,
    next_mode: int,
    interpret: bool = False,
):
    """Fused EC + Alg. 3 remap: one Pallas pass returning
    ``(out_rel, nval, nidx, nalpha)`` with the next layout scattered
    in-kernel to the ``alpha[:, next_mode]`` destinations (no separate
    full-``S_max`` XLA scatters, no separate destination stream)."""
    s = val.shape[0]
    n = idx.shape[1]
    nm1 = len(factors)
    r = factors[0].shape[1]
    nblocks = kappa * blocks_pp
    assert s == nblocks * block_p, (s, kappa, blocks_pp, block_p)
    assert s <= smax and lidx.shape == (nm1, s)
    assert 0 <= next_mode < n
    val2 = val.reshape(s, 1).astype(jnp.float32)
    lrow2 = lrow.reshape(s, 1).astype(jnp.int32)

    elem, fac, out, scratch = _fused_specs(nm1, r, block_p, blocks_pp,
                                           rows_pp)
    eblk_n = pl.BlockSpec((block_p, n),
                          lambda j, t, lidx, bpp=blocks_pp: (j * bpp + t, 0))
    resident1 = pl.BlockSpec((smax, 1), lambda j, t, lidx: (0, 0))
    resident_n = pl.BlockSpec((smax, n), lambda j, t, lidx: (0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(kappa, blocks_pp),
        in_specs=[elem, elem, eblk_n, eblk_n] + [fac] * nm1,
        out_specs=[out, resident1, resident_n, resident_n],
        scratch_shapes=scratch,
    )
    out_rel, nval, nidx, nalpha = pl.pallas_call(
        functools.partial(_fused_gather_kernel, nm1=nm1, rows_pp=rows_pp,
                          blocks_pp=blocks_pp, block_p=block_p,
                          nblocks=nblocks, next_mode=next_mode),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((kappa * rows_pp, r), jnp.float32),
            jax.ShapeDtypeStruct((smax, 1), jnp.float32),
            jax.ShapeDtypeStruct((smax, n), jnp.int32),
            jax.ShapeDtypeStruct((smax, n), jnp.int32),
        ],
        interpret=interpret,
    )(lidx.astype(jnp.int32), val2, lrow2, idx.astype(jnp.int32),
      alpha.astype(jnp.int32), *factors)
    return out_rel, nval[:, 0], nidx, nalpha


@functools.partial(
    jax.jit,
    static_argnames=("kappa", "rows_pp", "nblocks", "block_p", "smax",
                     "next_mode", "interpret"),
)
def mttkrp_fused_remap_compact(
    val: jax.Array,        # (S,) nonzero values (0 in pads)
    idx: jax.Array,        # (S, N) original indices
    alpha: jax.Array,      # (S, N) per-mode slot table (-1 in pads)
    lrow: jax.Array,       # (S,) local output rows (-1 in pads)
    upos: jax.Array,       # (S, N-1) per-slot stage position (0 in pads)
    bpart: jax.Array,      # (nblocks,) block -> partition (prefetched)
    uidx: jax.Array,       # (N-1, S) per-block unique rows (prefetched)
    nuniq: jax.Array,      # (N-1, nblocks) unique counts (prefetched)
    factors: tuple,        # N-1 arrays (I_w, R), kept in ANY/HBM
    *,
    kappa: int,
    rows_pp: int,
    nblocks: int,
    block_p: int,
    smax: int,
    next_mode: int,
    interpret: bool = False,
):
    """Compact-schedule fused EC + Alg. 3 remap with in-block row dedup;
    one Pallas pass returning ``(out_rel, nval, nidx, nalpha)``."""
    s = val.shape[0]
    n = idx.shape[1]
    nm1 = len(factors)
    r = factors[0].shape[1]
    assert s == nblocks * block_p, (s, nblocks, block_p)
    assert s <= smax and uidx.shape == (nm1, s) and upos.shape == (s, nm1)
    assert nuniq.shape == (nm1, nblocks)
    assert 0 <= next_mode < n
    val2 = val.reshape(s, 1).astype(jnp.float32)
    lrow2 = lrow.reshape(s, 1).astype(jnp.int32)

    elem, posb, fac, out, scratch = _compact_fused_specs(nm1, r, block_p,
                                                         rows_pp)
    eblk_n = pl.BlockSpec((block_p, n), lambda b, bp, ui, nu: (b, 0))
    resident1 = pl.BlockSpec((smax, 1), lambda b, bp, ui, nu: (0, 0))
    resident_n = pl.BlockSpec((smax, n), lambda b, bp, ui, nu: (0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(nblocks,),
        in_specs=[elem, elem, posb, eblk_n, eblk_n] + [fac] * nm1,
        out_specs=[out, resident1, resident_n, resident_n],
        scratch_shapes=scratch,
    )
    out_rel, nval, nidx, nalpha = pl.pallas_call(
        functools.partial(_compact_gather_kernel, nm1=nm1, rows_pp=rows_pp,
                          block_p=block_p, nblocks=nblocks,
                          next_mode=next_mode),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((kappa * rows_pp, r), jnp.float32),
            jax.ShapeDtypeStruct((smax, 1), jnp.float32),
            jax.ShapeDtypeStruct((smax, n), jnp.int32),
            jax.ShapeDtypeStruct((smax, n), jnp.int32),
        ],
        interpret=interpret,
    )(bpart.astype(jnp.int32), uidx.astype(jnp.int32),
      nuniq.astype(jnp.int32), val2, lrow2, upos.astype(jnp.int32),
      idx.astype(jnp.int32), alpha.astype(jnp.int32), *factors)
    return out_rel, nval[:, 0], nidx, nalpha
