"""Serving: batched prefill/decode engine over the unified cache."""
from .engine import Engine, ServeConfig

__all__ = ["Engine", "ServeConfig"]
