"""Batched serving engine: prefill + decode over the unified cache.

The decode step is a single jit (the artifact the decode_* dry-run cells
lower); prefill teacher-forces the prompt through the same step so every
cache layout (KV ring buffers, recurrent states, cross-attention memories)
is exercised by one code path. Whisper requests first build the encoder
memory via ``build_cross_caches``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..models import decode_step, init_cache
from ..models import transformer
from ..models.common import ModelConfig


@dataclasses.dataclass
class ServeConfig:
    batch: int
    max_len: int
    temperature: float = 0.0    # 0 => greedy
    eos_id: int = -1            # -1 => never stop early


class Engine:
    def __init__(self, params, cfg: ModelConfig, scfg: ServeConfig,
                 enc_embeds: Optional[jax.Array] = None):
        self.params = params
        self.cfg = cfg
        self.scfg = scfg
        self.cache = init_cache(
            cfg, scfg.batch, scfg.max_len,
            enc_len=enc_embeds.shape[1] if enc_embeds is not None else 0)
        if cfg.n_enc_layers:
            assert enc_embeds is not None, "audio arch needs encoder input"
            self.cache = transformer.build_cross_caches(
                params, cfg, enc_embeds, self.cache)
        self._step = jax.jit(
            lambda p, c, t: decode_step(p, c, self.cfg, t))

    def prefill(self, prompt: jax.Array) -> jax.Array:
        """prompt: (B, P) int32. Returns logits of the last position."""
        logits = None
        for t in range(prompt.shape[1]):
            logits, self.cache = self._step(self.params, self.cache,
                                            prompt[:, t:t + 1])
        return logits

    def _sample(self, logits, key):
        lf = logits[:, -1, :self.cfg.vocab].astype(jnp.float32)
        if self.scfg.temperature <= 0.0:
            return jnp.argmax(lf, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, lf / self.scfg.temperature, axis=-1).astype(jnp.int32)

    def generate(self, prompt: jax.Array, max_new: int,
                 key: Optional[jax.Array] = None) -> jax.Array:
        """Greedy/temperature decode; returns (B, max_new) tokens."""
        key = key if key is not None else jax.random.PRNGKey(0)
        logits = self.prefill(prompt)
        outs = []
        done = jnp.zeros((prompt.shape[0],), bool)
        for i in range(max_new):
            key, sub = jax.random.split(key)
            nxt = self._sample(logits, sub)
            nxt = jnp.where(done, 0, nxt)
            outs.append(nxt)
            done = done | (nxt == self.scfg.eos_id)
            logits, self.cache = self._step(self.params, self.cache,
                                            nxt[:, None])
        return jnp.stack(outs, axis=1)
