"""Run summaries over collected spans + metrics.

:func:`render_report` turns a tracer + registry into a human-readable
text/markdown summary: the per-phase wall-time tree (aggregated over
span paths, with self-time), the plan-cache hit taxonomy, and the
streamed transfer-vs-compute split.

This module also owns the **span-derived overlap efficiency** — the
profiler-timeline cross-check of ``StreamStats.overlap_efficiency``
(which counts prefetched uploads).  A ``stream.upload`` span counts as
*overlapped* exactly when some ``stream.compute`` span of an **earlier**
chunk in the same mode pass starts after the upload starts: the upload
was issued ahead of the compute frontier, i.e. it ran while earlier
chunks were still in flight.  The rule needs only span timestamps and
``chunk`` attrs, so it applies equally to live :class:`SpanRecord`s
(:func:`stream_overlap_from_spans`) and to an exported Chrome trace
(:func:`stream_overlap_from_chrome` — what the CI gate uses).
"""
from __future__ import annotations

from .metrics import REGISTRY, MetricsRegistry
from .trace import Tracer, get_tracer

__all__ = ["time_tree", "render_report", "stream_overlap_from_spans",
           "stream_overlap_from_chrome", "resilience_report"]


# --------------------------------------------------------------------------
# Per-phase time tree.
# --------------------------------------------------------------------------
class _Node:
    __slots__ = ("name", "count", "total_ns", "child_ns", "children")

    def __init__(self, name: str):
        self.name = name
        self.count = 0
        self.total_ns = 0
        self.child_ns = 0
        self.children: dict[str, _Node] = {}

    @property
    def self_ns(self) -> int:
        return max(self.total_ns - self.child_ns, 0)


def time_tree(spans) -> dict[str, _Node]:
    """Aggregate spans into a tree keyed by span *path* (the stack of
    names from a root span down), merging repeats: each node carries its
    invocation count, total wall time, and self time (total minus the
    time attributed to child spans)."""
    by_id = {s.span_id: s for s in spans}
    roots: dict[str, _Node] = {}

    def path_of(s):
        names = [s.name]
        seen = {s.span_id}
        while s.parent_id is not None:
            s = by_id.get(s.parent_id)
            if s is None or s.span_id in seen:  # cross-thread / partial
                break
            seen.add(s.span_id)
            names.append(s.name)
        return tuple(reversed(names))

    for s in spans:
        path = path_of(s)
        level = roots
        node = None
        for name in path:
            node = level.get(name)
            if node is None:
                node = level[name] = _Node(name)
            level = node.children
        node.count += 1
        node.total_ns += s.duration_ns
        if s.parent_id is not None:
            parent = by_id.get(s.parent_id)
            if parent is not None:
                # attribute child time to the parent node
                pnode = roots
                target = None
                for name in path[:-1]:
                    target = pnode.get(name)
                    if target is None:
                        break
                    pnode = target.children
                if target is not None:
                    target.child_ns += s.duration_ns
    return roots


def _render_tree(roots: dict[str, _Node], indent: str = "  ") -> list[str]:
    lines: list[str] = []

    def fmt_ms(ns: int) -> str:
        return f"{ns / 1e6:10.3f}ms"

    def walk(nodes: dict[str, _Node], depth: int):
        for node in sorted(nodes.values(), key=lambda n: -n.total_ns):
            lines.append(
                f"{indent * depth}{node.name:<{max(34 - depth * 2, 8)}}"
                f" x{node.count:<5d} total {fmt_ms(node.total_ns)}"
                f"  self {fmt_ms(node.self_ns)}")
            walk(node.children, depth + 1)

    walk(roots, 0)
    return lines


# --------------------------------------------------------------------------
# Span-derived overlap efficiency (the profiler-timeline cross-check).
# --------------------------------------------------------------------------
def _overlap_from_events(events) -> float | None:
    """``events``: iterables of ``(name, parent_id, start, chunk)``.
    Applies the module-docstring rule; returns ``None`` with no uploads."""
    uploads: dict[object, list] = {}
    computes: dict[object, list] = {}
    for name, parent, start, chunk in events:
        if chunk is None:
            continue
        if name == "stream.upload":
            uploads.setdefault(parent, []).append((start, chunk))
        elif name == "stream.compute":
            computes.setdefault(parent, []).append((start, chunk))
    total = overlapped = 0
    for parent, ups in uploads.items():
        comps = computes.get(parent, [])
        for u_start, u_chunk in ups:
            total += 1
            if any(c_start > u_start and c_chunk < u_chunk
                   for c_start, c_chunk in comps):
                overlapped += 1
    if total == 0:
        return None
    return overlapped / total


def stream_overlap_from_spans(spans) -> float | None:
    """Span-derived ``overlap_efficiency`` over live span records (see
    module docstring for the rule); ``None`` when no ``stream.upload``
    spans were recorded."""
    return _overlap_from_events(
        (s.name, s.parent_id, s.start_ns, s.attrs.get("chunk"))
        for s in spans)


def stream_overlap_from_chrome(trace: dict) -> float | None:
    """Span-derived ``overlap_efficiency`` recomputed from an exported
    Chrome trace (the CI ``obs-smoke`` gate's input)."""
    events = []
    for e in trace.get("traceEvents", ()):
        if e.get("ph") != "X":
            continue
        args = e.get("args", {})
        events.append((e.get("name"), args.get("parent_id"), e.get("ts"),
                       args.get("chunk")))
    return _overlap_from_events(events)


# --------------------------------------------------------------------------
# Resilience pairing: every injected fault must leave an answering event.
# --------------------------------------------------------------------------
def resilience_report(registry: MetricsRegistry | None = None) -> dict:
    """Pair each ``chaos_injections`` site with the resilience event that
    should have answered it — the machine-checkable form of the *no
    silent degradation* invariant (the CI ``chaos-smoke`` gate asserts
    ``unanswered == []``).

    The pairing table (see :mod:`repro.resilience.chaos` for the fault
    model): ``upload_fail`` -> an upload retry; ``oom_chunk`` -> a
    chunk-budget degradation; ``oom_resident`` -> the ``full->stream``
    residency rung; ``compile_fail`` -> a backend rung; ``nan_burst`` ->
    a NaN rollback recovery; ``corrupt_blob`` -> a quarantined
    plan-cache blob; ``kill_sweep`` -> a snapshot load (only observable
    in the *resumed* process — the injection itself dies with the killed
    one). Distributed sites: ``exchange_fail`` -> the ``permute ->
    all_gather`` exchange rung; ``device_lost`` -> a mesh-shrink
    degradation; ``dist_transient`` -> a ``dist.dispatch`` retry.
    """
    registry = registry or REGISTRY
    metrics = {m["name"]: m.get("values", {}) for m in registry.collect()}
    degr = metrics.get("resilience_degradations", {})
    retries = metrics.get("resilience_retries", {})
    recov = metrics.get("resilience_recoveries", {})
    cache = metrics.get("plan_cache_outcomes", {})
    snap = metrics.get("snapshot_events", {})
    injections = dict(metrics.get("chaos_injections", {}))

    def answered(site: str) -> bool:
        if site == "upload_fail":
            return retries.get("stream.upload", 0) > 0
        if site == "oom_chunk":
            return any(k.startswith("oom:") and k != "oom:full->stream"
                       for k in degr)
        if site == "oom_resident":
            return degr.get("oom:full->stream", 0) > 0
        if site == "compile_fail":
            return any(k.startswith("compile:") for k in degr)
        if site == "nan_burst":
            return recov.get("nan_rollback", 0) > 0
        if site == "corrupt_blob":
            return cache.get("disk_corrupt", 0) > 0
        if site == "kill_sweep":
            return snap.get("load", 0) > 0
        if site == "exchange_fail":
            return any(k.startswith("exchange:") for k in degr)
        if site == "device_lost":
            return any(k.startswith("device_lost:") for k in degr)
        if site == "dist_transient":
            return retries.get("dist.dispatch", 0) > 0
        return False

    return {
        "injections": injections,
        "answered": sorted(s for s in injections if answered(s)),
        "unanswered": sorted(s for s in injections if not answered(s)),
        "degradations": dict(degr),
        "retries": dict(retries),
        "recoveries": dict(recov),
        "snapshot_events": dict(snap),
        "cache_quarantines": cache.get("disk_corrupt", 0),
    }


# --------------------------------------------------------------------------
# The report.
# --------------------------------------------------------------------------
def render_report(tracer: Tracer | None = None,
                  registry: MetricsRegistry | None = None,
                  fmt: str = "text") -> str:
    """Text/markdown run summary: phase time tree, cache hit taxonomy,
    transfer vs compute, and the raw metrics dump."""
    if fmt not in ("text", "markdown"):
        raise ValueError(f"fmt must be 'text' or 'markdown', got {fmt!r}")
    tracer = tracer or get_tracer()
    registry = registry or REGISTRY
    spans = tracer.spans() if tracer else ()
    md = fmt == "markdown"

    def header(title: str) -> list[str]:
        return [f"## {title}", ""] if md else [title, "-" * len(title)]

    lines: list[str] = []
    lines += ["# repro run report", ""] if md else \
        ["repro run report", "=" * 16]

    lines += header(f"Phase time tree ({len(spans)} spans)")
    tree_lines = _render_tree(time_tree(spans)) or ["(no spans recorded — "
                                                    "set REPRO_TRACE=1)"]
    lines += ["```", *tree_lines, "```", ""] if md else tree_lines + [""]

    metrics = {m["name"]: m for m in registry.collect()}

    cache = metrics.get("plan_cache_outcomes", {}).get("values", {})
    if cache:
        lines += header("Plan cache taxonomy")
        total = sum(cache.values())
        for outcome, n in sorted(cache.items()):
            lines.append(f"  {outcome:<12} {n:>8}  "
                         f"({100.0 * n / max(total, 1):.1f}%)")
        lines.append("")

    stream = metrics.get("stream_bytes", {}).get("values", {})
    if stream:
        lines += header("Streaming transfer vs compute")
        h2d = stream.get("h2d", 0)
        frag = stream.get("fragment", 0)
        compute_ns = sum(s.duration_ns for s in spans
                         if s.name == "stream.compute")
        upload_ns = sum(s.duration_ns for s in spans
                        if s.name == "stream.upload")
        lines.append(f"  h2d bytes      {h2d:>14,}")
        lines.append(f"  fragment bytes {frag:>14,}")
        lines.append(f"  upload wall    {upload_ns / 1e6:>12.3f}ms")
        lines.append(f"  compute wall   {compute_ns / 1e6:>12.3f}ms "
                     "(dispatch; device time overlaps uploads)")
        span_eff = stream_overlap_from_spans(spans)
        if span_eff is not None:
            lines.append(f"  overlap (span-derived) {span_eff:>7.3f}")
        counts = metrics.get("stream_counts", {}).get("values", {})
        ups = counts.get("uploads", 0)
        if ups:
            lines.append(f"  overlap (count-derived)"
                         f" {counts.get('overlapped_uploads', 0) / ups:>7.3f}")
        lines.append("")

    lines += header("Metrics")
    if not metrics:
        lines.append("  (none recorded)")
    for name, m in sorted(metrics.items()):
        lines.append(f"  {name} ({m['kind']})")
        for key, value in sorted(m["values"].items()):
            if isinstance(value, dict):  # histogram summary
                mean = value["sum"] / max(value["count"], 1)
                value = (f"count={value['count']} mean={mean:.6g} "
                         f"min={value['min']:.6g} max={value['max']:.6g}")
            lines.append(f"    {key:<28} {value}")
    return "\n".join(lines) + "\n"
