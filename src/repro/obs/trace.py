"""Hierarchical wall-clock tracing: the span layer of ``repro.obs``.

Every phase of the engine — plan, autotune, stream, dist, ALS sweep,
backend dispatch — wraps itself in a :func:`span`.  A span records a
monotonic ``perf_counter_ns`` interval, its thread, its parent (spans
nest per-thread), and a small dict of attributes (mode, chunk, cache
outcome, ...).  The collected records export to a Perfetto-loadable
Chrome trace (:mod:`repro.obs.export`) and aggregate into the run report
(:mod:`repro.obs.report`).

Design constraints, in priority order:

* **Zero overhead when off.**  Tracing is disabled by default; the
  module-level :func:`span` is a two-instruction fast path (one global
  load, one ``is None`` test) returning a shared no-op context manager.
  Instrumented hot loops (per-chunk streaming, per-dispatch engine
  calls) pay nanoseconds, CI-gated at < 5% of any traced entry point.
* **Process-global but instantiable.**  Library code talks to the one
  global tracer (enabled via :func:`enable` or the ``REPRO_TRACE``
  environment variable); tests build private :class:`Tracer` instances
  and install them with ``enable(tracer)`` / ``disable()``.
* **Thread-safe.**  The record list is lock-protected and the span
  stack is thread-local, so host-side prefetch threads and the main
  dispatch loop can trace concurrently.
* **XLA-visible.**  When tracing is on, each span optionally enters a
  ``jax.profiler.TraceAnnotation`` of the same name, so our phases line
  up inside real XLA profiler timelines (TensorBoard / Perfetto) next
  to the compiled computations they drive.

Enable from the environment::

    REPRO_TRACE=1 python ...            # collect spans (export manually)
    REPRO_TRACE=out/trace.json python … # collect + write a Chrome trace
                                        # (atexit)
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
import os
import threading
import time

__all__ = ["SpanRecord", "Tracer", "span", "traced", "enable", "disable",
           "is_enabled", "get_tracer", "ENV_VAR"]

ENV_VAR = "REPRO_TRACE"


@dataclasses.dataclass(frozen=True)
class SpanRecord:
    """One completed span (immutable once recorded)."""

    name: str
    span_id: int
    parent_id: int | None
    thread_id: int
    thread_name: str
    start_ns: int
    end_ns: int
    attrs: dict

    @property
    def duration_ns(self) -> int:
        return self.end_ns - self.start_ns


class _NullSpan:
    """Shared reentrant no-op span: the disabled fast path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, key, value):  # matches _Span.set
        return None


NULL_SPAN = _NullSpan()


class _Span:
    """Live span context manager (one per ``with span(...)`` entry)."""

    __slots__ = ("_tracer", "name", "attrs", "_span_id", "_parent_id",
                 "_start_ns", "_ann")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs
        self._ann = None

    def set(self, key, value) -> None:
        """Attach/overwrite an attribute while the span is open (e.g. a
        cache outcome known only at the end of the phase)."""
        self.attrs[key] = value

    def __enter__(self):
        t = self._tracer
        stack = t._stack()
        self._parent_id = stack[-1] if stack else None
        self._span_id = next(t._ids)
        stack.append(self._span_id)
        if t.xla_annotations:
            ann = _trace_annotation(self.name)
            if ann is not None:
                self._ann = ann
                ann.__enter__()
        self._start_ns = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        end_ns = time.perf_counter_ns()
        if self._ann is not None:
            self._ann.__exit__(*exc)
        t = self._tracer
        stack = t._stack()
        if stack and stack[-1] == self._span_id:
            stack.pop()
        cur = threading.current_thread()
        t._record(SpanRecord(
            name=self.name, span_id=self._span_id,
            parent_id=self._parent_id, thread_id=cur.ident or 0,
            thread_name=cur.name, start_ns=self._start_ns, end_ns=end_ns,
            attrs=self.attrs))
        return False


def _trace_annotation(name: str):
    """A ``jax.profiler.TraceAnnotation`` for ``name``, or ``None`` when
    jax (or its profiler) is unavailable — obs itself stays dependency-
    free."""
    try:
        from jax.profiler import TraceAnnotation
    except Exception:  # pragma: no cover - jax is a repo-wide dep
        return None
    return TraceAnnotation(name)


class Tracer:
    """Collects :class:`SpanRecord`s; thread-safe, instantiable for tests.

    ``xla_annotations=True`` additionally wraps every span in a
    ``jax.profiler.TraceAnnotation`` so engine phases appear inside XLA
    profiler timelines (harmless no-op when no profile is being taken).
    """

    def __init__(self, *, xla_annotations: bool = True):
        self.xla_annotations = xla_annotations
        self._records: list[SpanRecord] = []
        self._lock = threading.Lock()
        self._tls = threading.local()
        self._ids = itertools.count(1)
        self.epoch_ns = time.perf_counter_ns()

    # ------------------------------------------------------------- recording
    def span(self, name: str, **attrs) -> _Span:
        return _Span(self, name, attrs)

    def _stack(self) -> list:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def _record(self, rec: SpanRecord) -> None:
        with self._lock:
            self._records.append(rec)

    # -------------------------------------------------------------- querying
    def spans(self) -> tuple[SpanRecord, ...]:
        """All completed spans, in start order."""
        with self._lock:
            records = list(self._records)
        return tuple(sorted(records, key=lambda r: (r.start_ns, r.span_id)))

    def clear(self) -> None:
        with self._lock:
            self._records.clear()
        self.epoch_ns = time.perf_counter_ns()

    def __len__(self) -> int:
        with self._lock:
            return len(self._records)


# --------------------------------------------------------------------------
# The process-global tracer + module-level fast path.
# --------------------------------------------------------------------------
_ACTIVE: Tracer | None = None


def span(name: str, **attrs):
    """Open a span on the global tracer; hard no-op while disabled.

    Usage::

        with span("plan.mode", mode=d):
            ...
        with span("plan.cache_lookup") as sp:
            ...
            sp.set("outcome", outcome)
    """
    t = _ACTIVE
    if t is None:
        return NULL_SPAN
    return t.span(name, **attrs)


def traced(name: str | None = None, **attrs):
    """Decorator form of :func:`span` (span named after the function)."""

    def deco(fn):
        label = name or fn.__qualname__

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            t = _ACTIVE
            if t is None:
                return fn(*args, **kwargs)
            with t.span(label, **attrs):
                return fn(*args, **kwargs)

        return wrapper

    return deco


def enable(tracer: Tracer | None = None) -> Tracer:
    """Install ``tracer`` (or a fresh one) as the global tracer."""
    global _ACTIVE
    _ACTIVE = tracer if tracer is not None else Tracer()
    return _ACTIVE


def disable() -> Tracer | None:
    """Remove the global tracer (spans become no-ops); returns it."""
    global _ACTIVE
    prev, _ACTIVE = _ACTIVE, None
    return prev


def is_enabled() -> bool:
    return _ACTIVE is not None


def get_tracer() -> Tracer | None:
    """The global tracer, or ``None`` while tracing is disabled."""
    return _ACTIVE


def _init_from_env() -> None:
    """``REPRO_TRACE`` opt-in: any non-empty value other than ``0/false``
    enables tracing at import; a path-looking value additionally dumps a
    Chrome trace there at interpreter exit."""
    val = os.environ.get(ENV_VAR, "").strip()
    if not val or val.lower() in ("0", "false", "off"):
        return
    enable()
    if val.lower() in ("1", "true", "on"):
        return
    import atexit

    def _dump(path=val):
        from .export import write_chrome_trace

        if _ACTIVE is not None and len(_ACTIVE):
            write_chrome_trace(path)

    atexit.register(_dump)


_init_from_env()
