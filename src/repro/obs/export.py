"""Trace/metrics exporters: Chrome-trace JSON, JSONL event log, manifest.

The Chrome trace (``chrome_trace`` / ``write_chrome_trace``) follows the
Trace Event Format's "JSON object" flavor — a ``traceEvents`` list of
complete (``"ph": "X"``) duration events plus thread-name metadata and
one ``"C"`` counter sample per counter metric — and loads directly into
Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``.  Extra
top-level keys carry the run manifest and a metrics snapshot, which the
CI ``obs-smoke`` gate reads back (span-derived vs count-derived overlap
agreement) without re-running anything.

``write_jsonl`` is the greppable flat log (one JSON object per span);
``run_manifest`` records what produced the trace (jax version, backend,
devices, PlanSpec knobs, dataset signature).
"""
from __future__ import annotations

import json
import os
import time

from .metrics import REGISTRY, MetricsRegistry
from .trace import SpanRecord, Tracer, get_tracer

__all__ = ["chrome_trace", "write_chrome_trace", "write_jsonl",
           "run_manifest", "validate_chrome_trace"]


def _jsonable(value):
    if value is None or isinstance(value, (bool, int, float, str)):
        return value
    if isinstance(value, (list, tuple)):
        return [_jsonable(v) for v in value]
    if isinstance(value, dict):
        return {str(k): _jsonable(v) for k, v in value.items()}
    # numpy scalars and friends
    item = getattr(value, "item", None)
    if callable(item):
        try:
            return _jsonable(item())
        except Exception:
            pass
    return repr(value)


def run_manifest(spec=None, dataset_signature=None, extra=None) -> dict:
    """What produced this trace: runtime versions, backend + devices,
    the PlanSpec/ExecutionConfig knobs, and the dataset's sparsity
    signature (all optional and degraded gracefully — obs itself has no
    hard deps)."""
    import platform
    import sys

    manifest: dict = {
        "unix_time": time.time(),
        "python": sys.version.split()[0],
        "platform": platform.platform(),
        "argv": list(sys.argv),
        "pid": os.getpid(),
    }
    try:
        import jax

        manifest["jax_version"] = jax.__version__
        manifest["jax_backend"] = jax.default_backend()
        manifest["devices"] = [str(d) for d in jax.local_devices()]
    except Exception:  # pragma: no cover - jax is a repo-wide dep
        pass
    if spec is not None:
        import dataclasses

        manifest["plan_spec"] = (
            dataclasses.asdict(spec) if dataclasses.is_dataclass(spec)
            else _jsonable(spec))
    if dataset_signature is not None:
        manifest["dataset_signature"] = _jsonable(dataset_signature)
    if extra:
        manifest.update({str(k): _jsonable(v) for k, v in extra.items()})
    return manifest


def chrome_trace(tracer: Tracer | None = None,
                 registry: MetricsRegistry | None = None,
                 manifest: dict | None = None) -> dict:
    """Render spans (+ a metrics snapshot) as a Chrome-trace JSON object.

    Timestamps are microseconds relative to the tracer's epoch; span
    attrs, ids, and parent ids ride in each event's ``args`` so the
    trace is self-contained (the overlap-validation gate reconstructs
    span relationships from the file alone).
    """
    tracer = tracer or get_tracer()
    registry = registry or REGISTRY
    spans: tuple[SpanRecord, ...] = tracer.spans() if tracer else ()
    epoch = min((s.start_ns for s in spans), default=0)

    events: list[dict] = [{
        "name": "process_name", "ph": "M", "pid": 0, "tid": 0,
        "args": {"name": "repro"},
    }]
    tids: dict[int, int] = {}
    for s in spans:
        tid = tids.get(s.thread_id)
        if tid is None:
            tid = tids[s.thread_id] = len(tids)
            events.append({"name": "thread_name", "ph": "M", "pid": 0,
                           "tid": tid, "args": {"name": s.thread_name}})
        events.append({
            "name": s.name,
            "cat": s.name.split(".", 1)[0],
            "ph": "X",
            "pid": 0,
            "tid": tid,
            "ts": (s.start_ns - epoch) / 1e3,
            "dur": s.duration_ns / 1e3,
            "args": {"span_id": s.span_id, "parent_id": s.parent_id,
                     **{str(k): _jsonable(v) for k, v in s.attrs.items()}},
        })
    end_ts = max(((s.end_ns - epoch) / 1e3 for s in spans), default=0.0)
    metrics = registry.collect()
    for m in metrics:
        if m["kind"] != "counter" or not m["values"]:
            continue
        events.append({
            "name": m["name"], "ph": "C", "pid": 0, "tid": 0, "ts": end_ts,
            "args": {k: v for k, v in m["values"].items()
                     if isinstance(v, (int, float))},
        })
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "metadata": {
            "manifest": manifest if manifest is not None else run_manifest(),
            "metrics": metrics,
            "span_count": len(spans),
        },
    }


def validate_chrome_trace(trace: dict) -> list[str]:
    """Schema check for the traces we emit (and that Perfetto loads):
    returns a list of problems, empty when the trace is well-formed."""
    errors: list[str] = []
    if not isinstance(trace, dict):
        return [f"trace must be a JSON object, got {type(trace).__name__}"]
    events = trace.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    for i, e in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(e, dict):
            errors.append(f"{where}: not an object")
            continue
        for field in ("name", "ph", "pid", "tid"):
            if field not in e:
                errors.append(f"{where}: missing {field!r}")
        ph = e.get("ph")
        if ph not in ("X", "M", "C", "B", "E", "i"):
            errors.append(f"{where}: unknown phase {ph!r}")
        if ph == "X":
            ts, dur = e.get("ts"), e.get("dur")
            if not isinstance(ts, (int, float)) or ts < 0:
                errors.append(f"{where}: bad ts {ts!r}")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"{where}: bad dur {dur!r}")
            args = e.get("args", {})
            if "span_id" not in args:
                errors.append(f"{where}: X event missing args.span_id")
    try:
        json.dumps(trace)
    except (TypeError, ValueError) as err:
        errors.append(f"not JSON-serializable: {err}")
    return errors


def write_chrome_trace(path: str, tracer: Tracer | None = None,
                       registry: MetricsRegistry | None = None,
                       manifest: dict | None = None) -> dict:
    """Validate + atomically write the Chrome trace; returns the object."""
    trace = chrome_trace(tracer, registry, manifest)
    errors = validate_chrome_trace(trace)
    if errors:  # our own exporter must never emit an invalid trace
        raise ValueError(f"invalid chrome trace: {errors[:5]}")
    parent = os.path.dirname(os.path.abspath(path))
    os.makedirs(parent, exist_ok=True)
    tmp = os.path.join(parent, f".tmp-trace-{os.getpid()}")
    with open(tmp, "w") as f:
        json.dump(trace, f)
        f.write("\n")
    os.replace(tmp, path)
    return trace


def write_jsonl(path: str, tracer: Tracer | None = None) -> int:
    """Flat span log: one JSON object per span, start-ordered. Returns
    the number of spans written."""
    tracer = tracer or get_tracer()
    spans = tracer.spans() if tracer else ()
    parent = os.path.dirname(os.path.abspath(path))
    os.makedirs(parent, exist_ok=True)
    tmp = os.path.join(parent, f".tmp-jsonl-{os.getpid()}")
    with open(tmp, "w") as f:
        for s in spans:
            f.write(json.dumps({
                "name": s.name, "span_id": s.span_id,
                "parent_id": s.parent_id, "thread": s.thread_name,
                "start_ns": s.start_ns, "dur_ns": s.duration_ns,
                "attrs": {str(k): _jsonable(v) for k, v in s.attrs.items()},
            }))
            f.write("\n")
    os.replace(tmp, path)
    return len(spans)
