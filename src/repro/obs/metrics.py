"""Labeled counter/gauge/histogram registry: the metrics layer of
``repro.obs``.

Before this module each subsystem invented its own tally — the engine's
``collections.Counter`` trace/dispatch counts, ``StreamStats``' transfer
fields, ``PlanCache``'s hit counters.  The registry absorbs them behind
one uniform surface so the exporter (:mod:`repro.obs.export`) and the
run report (:mod:`repro.obs.report`) see every subsystem the same way:

* :class:`Counter` — monotonically increasing tallies, keyed by a label
  (``DISPATCHES.inc("all_modes")``).  Counters double as dict-like
  tallies (``c["all_modes"] += 1``, ``c.clear()``, ``dict(c)``) so the
  engine's legacy ``TRACE_COUNTS`` / ``DISPATCH_COUNTS`` module globals
  migrate onto the registry without breaking a single callsite.
* :class:`Gauge` — last-value-wins samples (``fit`` per ALS sweep, peak
  ring bytes).
* :class:`Histogram` — streaming summaries (count/sum/min/max) for
  timings and sizes where the full distribution is not worth keeping.

Everything is process-global by default (:data:`REGISTRY`) but
instantiable (:class:`MetricsRegistry`) for tests; all mutation is
lock-protected.
"""
from __future__ import annotations

import threading

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY",
           "counter", "gauge", "histogram"]


class _Metric:
    """Shared keyed-value plumbing; ``key`` is any hashable label (the
    common case is a short string)."""

    kind = "metric"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._values: dict = {}
        self._lock = threading.Lock()

    # ------------------------------------------------------- mapping surface
    def __getitem__(self, key):
        with self._lock:
            return self._values.get(key, 0)

    def __contains__(self, key):
        with self._lock:
            return key in self._values

    def __iter__(self):
        with self._lock:
            return iter(list(self._values))

    def __len__(self):
        with self._lock:
            return len(self._values)

    def keys(self):
        with self._lock:
            return list(self._values.keys())

    def items(self):
        with self._lock:
            return list(self._values.items())

    def get(self, key, default=0):
        with self._lock:
            return self._values.get(key, default)

    def clear(self) -> None:
        with self._lock:
            self._values.clear()

    def as_dict(self) -> dict:
        with self._lock:
            return dict(self._values)

    def __repr__(self):
        return f"{type(self).__name__}({self.name!r}, {self.as_dict()!r})"


class Counter(_Metric):
    """Monotonic tally per label; dict-style mutation kept for back-compat
    with the engine's legacy ``collections.Counter`` globals."""

    kind = "counter"

    def inc(self, key, amount=1):
        with self._lock:
            value = self._values.get(key, 0) + amount
            self._values[key] = value
            return value

    def __setitem__(self, key, value):
        # legacy `c[k] += 1` path (getitem + setitem); also absolute sets
        with self._lock:
            self._values[key] = value

    def total(self):
        with self._lock:
            return sum(self._values.values())


class Gauge(_Metric):
    """Last-value-wins sample per label."""

    kind = "gauge"

    def set(self, key, value):
        with self._lock:
            self._values[key] = value

    def __setitem__(self, key, value):
        self.set(key, value)

    def max(self, key, value):
        """Keep the running maximum (peak trackers)."""
        with self._lock:
            cur = self._values.get(key)
            if cur is None or value > cur:
                self._values[key] = value


class Histogram(_Metric):
    """Streaming summary per label: count / sum / min / max (and the
    derived mean).  Full distributions stay with the caller when they
    matter (``benchmarks.common.time_fn`` records p10/p90 itself)."""

    kind = "histogram"

    def observe(self, key, value):
        value = float(value)
        with self._lock:
            cur = self._values.get(key)
            if cur is None:
                self._values[key] = {"count": 1, "sum": value,
                                     "min": value, "max": value}
            else:
                cur["count"] += 1
                cur["sum"] += value
                if value < cur["min"]:
                    cur["min"] = value
                if value > cur["max"]:
                    cur["max"] = value

    def summary(self, key) -> dict | None:
        with self._lock:
            cur = self._values.get(key)
            if cur is None:
                return None
            out = dict(cur)
        out["mean"] = out["sum"] / max(out["count"], 1)
        return out


class MetricsRegistry:
    """Name -> metric registry; ``counter/gauge/histogram`` get-or-create
    (re-registration with a different kind is an error)."""

    _KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}

    def __init__(self):
        self._metrics: dict[str, _Metric] = {}
        self._lock = threading.Lock()

    def _get_or_create(self, kind: str, name: str, help: str) -> _Metric:
        cls = self._KINDS[kind]
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, help)
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as {m.kind}, "
                    f"requested {kind}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create("counter", name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create("gauge", name, help)

    def histogram(self, name: str, help: str = "") -> Histogram:
        return self._get_or_create("histogram", name, help)

    def metrics(self) -> dict[str, _Metric]:
        with self._lock:
            return dict(self._metrics)

    def collect(self) -> list[dict]:
        """Snapshot every metric as plain JSON-able records (the export
        and report layers' input)."""
        out = []
        for name, m in sorted(self.metrics().items()):
            out.append({"name": name, "kind": m.kind, "help": m.help,
                        "values": {_label(k): v
                                   for k, v in m.as_dict().items()}})
        return out

    def reset(self) -> None:
        """Clear every metric's values (registrations survive)."""
        for m in self.metrics().values():
            m.clear()


def _label(key) -> str:
    return key if isinstance(key, str) else repr(key)


#: Process-wide default registry — library instrumentation lands here.
REGISTRY = MetricsRegistry()


def counter(name: str, help: str = "") -> Counter:
    """Get-or-create a counter on the default registry."""
    return REGISTRY.counter(name, help)


def gauge(name: str, help: str = "") -> Gauge:
    """Get-or-create a gauge on the default registry."""
    return REGISTRY.gauge(name, help)


def histogram(name: str, help: str = "") -> Histogram:
    """Get-or-create a histogram on the default registry."""
    return REGISTRY.histogram(name, help)
