"""repro.obs — unified tracing + metrics for the FLYCOO engine.

One observability surface across every layer: hierarchical wall-clock
spans (:mod:`~repro.obs.trace`) over plan → autotune → stream → dist →
ALS sweep → backend dispatch, a labeled counter/gauge/histogram registry
(:mod:`~repro.obs.metrics`), Chrome-trace / JSONL / manifest exporters
(:mod:`~repro.obs.export`), run summaries plus the span-derived overlap
cross-check (:mod:`~repro.obs.report`), and peak-memory probes
(:mod:`~repro.obs.probe`).

Quick start::

    from repro import obs

    obs.enable()                      # or: REPRO_TRACE=1 / =trace.json
    result = cp_als(tensor, rank=8)
    obs.write_chrome_trace("trace.json")   # load in ui.perfetto.dev
    print(obs.render_report())

Everything is zero-dependency and free when disabled: the module-level
:func:`span` is a single ``is None`` test returning a shared no-op when
no tracer is installed (CI gates traced entry points at < 5% overhead
with tracing off).
"""
from .export import (chrome_trace, run_manifest, validate_chrome_trace,
                     write_chrome_trace, write_jsonl)
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry, REGISTRY,
                      counter, gauge, histogram)
from .probe import device_peak_bytes, memory_probe
from .report import (render_report, resilience_report,
                     stream_overlap_from_chrome, stream_overlap_from_spans,
                     time_tree)
from .trace import (ENV_VAR, NULL_SPAN, SpanRecord, Tracer, disable, enable,
                    get_tracer, is_enabled, span, traced)

__all__ = [
    # trace
    "span", "traced", "Tracer", "SpanRecord", "NULL_SPAN", "enable",
    "disable", "is_enabled", "get_tracer", "ENV_VAR",
    # metrics
    "REGISTRY", "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "counter", "gauge", "histogram",
    # export
    "chrome_trace", "write_chrome_trace", "write_jsonl", "run_manifest",
    "validate_chrome_trace",
    # report
    "render_report", "resilience_report", "time_tree",
    "stream_overlap_from_spans", "stream_overlap_from_chrome",
    # probe
    "memory_probe", "device_peak_bytes",
]
