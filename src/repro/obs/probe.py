"""Peak-memory probes (host RSS + device allocator high-water mark).

Lives in ``repro.obs`` so library code — ``StreamStats.as_row()``, the
run manifest, the report — can record residency without importing bench
helpers; ``benchmarks.common`` re-exports :func:`memory_probe` for the
existing figure scripts.
"""
from __future__ import annotations

__all__ = ["memory_probe", "device_peak_bytes"]


def memory_probe() -> dict:
    """Peak-memory observability hook for the out-of-core tier.

    Returns ``host_peak_rss_bytes`` (the process high-water mark — on
    Linux ``ru_maxrss`` is KiB) and ``device_peak_bytes`` (the first
    device's allocator high-water mark, ``None`` where the platform
    doesn't report one, e.g. CPU jax). fig11's oversubscription rows and
    the CI stream gate record both next to the modeled ring bytes, so a
    residency regression shows up as measured numbers, not just model
    drift.
    """
    probe: dict = {"host_peak_rss_bytes": None,
                   "device_peak_bytes": device_peak_bytes()}
    try:
        import resource
        import sys

        peak = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
        scale = 1024 if sys.platform.startswith("linux") else 1
        probe["host_peak_rss_bytes"] = int(peak) * scale
    except (ImportError, ValueError, OSError):
        pass
    return probe


def device_peak_bytes() -> int | None:
    """First device's allocator high-water mark (``None`` when the
    platform reports no memory stats — e.g. CPU jax)."""
    try:
        import jax

        stats = jax.local_devices()[0].memory_stats() or {}
        return stats.get("peak_bytes_in_use", stats.get("bytes_in_use"))
    except Exception:  # memory_stats unsupported on this backend
        return None
