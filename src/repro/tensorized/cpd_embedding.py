"""CPD-factorized embedding layer — the paper's kernel as an LM feature.

The (V, D) table is represented as a rank-R CPD of its (V1 x V2 x D)
reshaping:  E[v1*V2 + v2, :] = C @ (A[v1] * B[v2])^T, with
A (V1, R), B (V2, R), C (D, R). Storage drops from V*D to (V1+V2+D)*R.

The factor gradients for a token batch are *exactly* an spMTTKRP where the
batch plays the sparse tensor (DESIGN.md §4): viewing the batch as the
3-mode sparse tensor X in R^{V1 x V2 x T} with nonzeros (v1_t, v2_t, t),

    dA = X_(0) (B  (.) GC)      (mode-0 spMTTKRP, GC = cotangent @ C)
    dB = X_(1) (A  (.) GC)
    dC = G^T (A[v1] * B[v2])    (dense)

implemented below with the same gather-Hadamard-segment-sum elementwise
computation as core.mttkrp (Alg. 2). Token indices are dynamic, so the
runtime path uses the segment-sum form; the host-side FLYCOO partitioner
applies when batches are statically sorted (serving).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp


def split_dims(vocab: int) -> tuple[int, int]:
    v1 = int(math.ceil(math.sqrt(vocab)))
    v2 = int(math.ceil(vocab / v1))
    return v1, v2


def init_cpd_embedding(key, vocab: int, d_model: int, rank: int,
                       dtype=jnp.float32) -> dict:
    v1, v2 = split_dims(vocab)
    ka, kb, kc = jax.random.split(key, 3)
    s = (1.0 / rank) ** 0.5
    return {
        "A": (jax.random.normal(ka, (v1, rank)) * s).astype(dtype),
        "B": (jax.random.normal(kb, (v2, rank)) * s).astype(dtype),
        "C": (jax.random.normal(kc, (d_model, rank)) * s).astype(dtype),
    }


@partial(jax.custom_vjp, nondiff_argnums=())
def cpd_embed(params, tokens):
    """tokens (B, S) -> embeddings (B, S, D)."""
    out, _ = _fwd(params, tokens)
    return out


def _lookup(params, tokens):
    v2 = params["B"].shape[0]
    i1 = tokens // v2
    i2 = tokens % v2
    a = jnp.take(params["A"], i1, axis=0)   # (B, S, R)
    b = jnp.take(params["B"], i2, axis=0)
    return (a * b) @ params["C"].T, (i1, i2, a, b)


def _fwd(params, tokens):
    out, res = _lookup(params, tokens)
    return out, (params, tokens, res)


def _bwd(resids, g):
    params, tokens, (i1, i2, a, b) = resids
    bsz, seq, d = g.shape
    t = bsz * seq
    gf = g.reshape(t, d).astype(jnp.float32)
    gc = gf @ params["C"]                       # (T, R): mode-T "factor"
    af = a.reshape(t, -1).astype(jnp.float32)
    bf = b.reshape(t, -1).astype(jnp.float32)
    # --- spMTTKRP elementwise computation (Alg. 2): gather-Hadamard done,
    # segment-sum = the ownership-partitioned accumulation. ---
    dA = jax.ops.segment_sum(bf * gc, i1.reshape(t),
                             num_segments=params["A"].shape[0])
    dB = jax.ops.segment_sum(af * gc, i2.reshape(t),
                             num_segments=params["B"].shape[0])
    dC = gf.T @ (af * bf)
    dparams = {"A": dA.astype(params["A"].dtype),
               "B": dB.astype(params["B"].dtype),
               "C": dC.astype(params["C"].dtype)}
    return dparams, None


cpd_embed.defvjp(_fwd, _bwd)


def cpd_logits(params, x):
    """Tied-head logits without materializing the dense table:
    logits[t, v] = sum_r (x_t . C[:, r]) A[v1, r] B[v2, r]."""
    v1 = params["A"].shape[0]
    v2 = params["B"].shape[0]
    vocab = v1 * v2
    xc = x @ params["C"].astype(x.dtype)         # (B, S, R)
    ids = jnp.arange(vocab)
    krp = (jnp.take(params["A"], ids // v2, axis=0)
           * jnp.take(params["B"], ids % v2, axis=0))
    return xc @ krp.T.astype(x.dtype)


def dense_table(params) -> jax.Array:
    """Materialize E (tests / comparison only)."""
    v1, r = params["A"].shape
    v2 = params["B"].shape[0]
    krp = (params["A"][:, None, :] * params["B"][None, :, :]).reshape(
        v1 * v2, r)
    return krp @ params["C"].T
