"""Paper technique as LM features: CPD embeddings, low-rank grad compression."""
from .cpd_embedding import (cpd_embed, cpd_logits, dense_table,
                            init_cpd_embedding, split_dims)

__all__ = ["cpd_embed", "cpd_logits", "dense_table", "init_cpd_embedding",
           "split_dims"]
