"""FLYCOO-TPU: Sparse MTTKRP for Tensor Decomposition (CF'24) as a
production multi-pod JAX framework. See DESIGN.md / EXPERIMENTS.md."""

__version__ = "1.0.0"
