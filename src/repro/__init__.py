"""FLYCOO-TPU: Sparse MTTKRP for Tensor Decomposition (CF'24) as a
production multi-pod JAX framework. See DESIGN.md / EXPERIMENTS.md.

``repro.engine`` is the functional spMTTKRP execution engine (pytree
``EngineState`` + ``ExecutionConfig``); ``repro.core`` holds the FLYCOO
format, preprocessing, and CPD-ALS built on top of it."""

__version__ = "1.1.0"
