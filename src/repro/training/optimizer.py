"""Optimizers (AdamW, Adafactor), global-norm clipping, LR schedules.

Own implementation (optax is not vendored here). State dtypes are
configurable: the >=100B configs can run bf16 moments to fit HBM
(reported by the dry-run's memory_analysis either way).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"            # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    state_dtype: str = "float32"   # moment dtype (bf16 for the giants)


def schedule(cfg: OptimizerConfig, step):
    """Linear warmup -> cosine decay to 10%."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.1 + 0.45 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * cos


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float):
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (g + 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale
                                   ).astype(x.dtype), grads), g


# ------------------------------------------------------------------- adamw
def adamw_init(params, cfg: OptimizerConfig):
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)  # noqa: E731
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(grads, opt_state, params, cfg: OptimizerConfig):
    step = opt_state["step"] + 1
    lr = schedule(cfg, step)
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        mf = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        vf = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * gf * gf
        mhat = mf / bc1
        vhat = vf / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # no decay on norms/biases/1-d tables
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return (newp.astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype))

    flat = jax.tree.map(upd, grads, opt_state["m"], opt_state["v"], params)
    new_params = jax.tree.map(lambda x: x[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda x: x[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda x: x[2], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, lr


# ---------------------------------------------------------------- adafactor
def adafactor_init(params, cfg: OptimizerConfig):
    def rows_cols(p):
        if p.ndim >= 2:
            return {"r": jnp.zeros(p.shape[:-1], jnp.float32),
                    "c": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"f": jax.tree.map(rows_cols, params,
                              is_leaf=lambda x: not isinstance(x, dict)),
            "step": jnp.zeros((), jnp.int32)}


def adafactor_update(grads, opt_state, params, cfg: OptimizerConfig):
    step = opt_state["step"] + 1
    lr = schedule(cfg, step)
    beta = 1.0 - (step.astype(jnp.float32) + 1.0) ** -0.8

    def upd(g, f, p):
        gf = g.astype(jnp.float32)
        g2 = gf * gf + 1e-30
        if p.ndim >= 2:
            r = beta * f["r"] + (1 - beta) * jnp.mean(g2, axis=-1)
            c = beta * f["c"] + (1 - beta) * jnp.mean(g2, axis=-2)
            denom = jnp.sqrt(
                r[..., None] * c[..., None, :]
                / (jnp.mean(r, axis=-1, keepdims=True)[..., None] + 1e-30))
            newf = {"r": r, "c": c}
        else:
            v = beta * f["v"] + (1 - beta) * g2
            denom = jnp.sqrt(v)
            newf = {"v": v}
        delta = gf / (denom + 1e-30)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), newf

    is_state = lambda x: isinstance(x, dict) and ("r" in x or "v" in x)  # noqa
    flat = jax.tree.map(upd, grads, opt_state["f"], params, is_leaf=None)
    new_params = jax.tree.map(lambda x: x[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_f = jax.tree.map(lambda x: x[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"f": new_f, "step": step}, lr


def init(params, cfg: OptimizerConfig):
    if cfg.name == "adafactor":
        return adafactor_init(params, cfg)
    return adamw_init(params, cfg)


def update(grads, opt_state, params, cfg: OptimizerConfig):
    if cfg.name == "adafactor":
        return adafactor_update(grads, opt_state, params, cfg)
    return adamw_update(grads, opt_state, params, cfg)
