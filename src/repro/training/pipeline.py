"""GPipe-style pipeline parallelism via shard_map + collective_permute.

Stages live on a dedicated mesh axis; microbatches stream through the
classic (n_micro + n_stages - 1)-tick schedule with activations handed to
the next stage by ``ppermute`` each tick (bubbles included — this is honest
GPipe, not an idealized overlap model).

Not used by the production dry-run meshes (DESIGN.md §6 explains why DP x
TP x EP + SP is the right regime for the assigned archs at 512 chips); it
exists so the framework has a tested PP primitive for deeper-than-HBM
models, and is exercised by tests/test_distributed.py on a 4-stage mesh.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:
    from jax import shard_map as _shard_map

    def _smap(f, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def _smap(f, mesh, in_specs, out_specs):
        return _shard_map_old(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=False)


def pipeline_apply(stage_fn, stage_params, x, *, mesh, axis: str = "pp",
                   n_micro: int):
    """Run ``y = stage_{S-1}(...stage_0(x))`` on a pipeline mesh axis.

    Args:
      stage_fn: (params_one_stage, h) -> h, the per-stage computation.
      stage_params: pytree stacked on a leading n_stages axis (sharded on
        ``axis``).
      x: (batch, ...) global input; batch must divide n_micro.
      mesh: mesh containing ``axis`` of size n_stages.
      n_micro: number of microbatches streamed through the pipe.

    Returns y with x's shape.
    """
    n_stages = mesh.shape[axis]
    b = x.shape[0]
    assert b % n_micro == 0, (b, n_micro)
    mb = b // n_micro
    ticks = n_micro + n_stages - 1
    fwd_perm = [(i, i + 1) for i in range(n_stages - 1)]

    def local(params_st, x_loc):
        # params_st: this stage's params (leading axis 1); x_loc: the full
        # batch (replicated along the pp axis — inputs enter at stage 0).
        params_one = jax.tree.map(lambda p: p[0], params_st)
        stage_id = jax.lax.axis_index(axis)
        mbs = x_loc.reshape(n_micro, mb, *x_loc.shape[1:])
        carry = jnp.zeros_like(mbs[0])
        outs = jnp.zeros_like(mbs)
        for t in range(ticks):  # static schedule: exact HLO
            # stage 0 injects microbatch t (if any); others use the carry
            feed_idx = min(t, n_micro - 1)
            inject = mbs[feed_idx]
            h_in = jnp.where(stage_id == 0, inject, carry)
            h_out = stage_fn(params_one, h_in)
            # last stage retires microbatch t - (n_stages - 1)
            out_idx = t - (n_stages - 1)  # static
            if 0 <= out_idx < n_micro:
                keep = jnp.where(stage_id == n_stages - 1, h_out,
                                 jnp.zeros_like(h_out))
                outs = outs.at[out_idx].add(keep)
            # hand activations to the next stage
            carry = jax.lax.ppermute(h_out, axis, fwd_perm)
        # non-last stages hold zeros; psum materializes the pipe's output
        outs = jax.lax.psum(outs, axis)
        return outs.reshape(b, *x_loc.shape[1:])

    return _smap(
        local, mesh,
        in_specs=(P(axis), P()),       # stage params sharded; x replicated
        out_specs=P(),
    )(stage_params, x)
