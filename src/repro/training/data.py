"""Deterministic synthetic LM data pipeline (sharded, resumable).

Every batch is a pure function of (seed, step): restart-safe by
construction, and each dp shard can generate only its slice on a real
cluster. ``get_state``/``set_state`` plug into the checkpoint manager.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from ..models.common import ModelConfig


class SyntheticLM:
    """Zipf-ish token stream with next-token targets."""

    def __init__(self, cfg: ModelConfig, batch: int, seq: int,
                 seed: int = 0):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.seed = seed
        self.step = 0

    def _tokens(self, rng, shape):
        v = self.cfg.vocab
        raw = rng.zipf(1.3, size=shape)
        return (raw % v).astype(np.int32)

    def next(self) -> dict:
        rng = np.random.default_rng((self.seed, self.step))
        self.step += 1
        cfg = self.cfg
        if cfg.kind == "vlm":
            n_txt = self.seq - cfg.n_img_tokens
            toks = self._tokens(rng, (self.batch, n_txt + 1))
            return {
                "tokens": jnp.asarray(toks[:, :-1]),
                "targets": jnp.asarray(toks[:, 1:]),
                "embeds": jnp.asarray(
                    rng.standard_normal(
                        (self.batch, cfg.n_img_tokens, cfg.d_model)
                    ).astype(np.float32), dtype=cfg.cdtype),
            }
        if cfg.kind == "audio":
            toks = self._tokens(rng, (self.batch, self.seq + 1))
            return {
                "tokens": jnp.asarray(toks[:, :-1]),
                "targets": jnp.asarray(toks[:, 1:]),
                "enc_embeds": jnp.asarray(
                    rng.standard_normal(
                        (self.batch, self.seq, cfg.d_model)
                    ).astype(np.float32), dtype=cfg.cdtype),
            }
        toks = self._tokens(rng, (self.batch, self.seq + 1))
        return {"tokens": jnp.asarray(toks[:, :-1]),
                "targets": jnp.asarray(toks[:, 1:])}

    def get_state(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def set_state(self, state: dict):
        self.step = int(state.get("step", 0))
        self.seed = int(state.get("seed", self.seed))
