"""Loss, train_step factory, and the fault-tolerant training controller."""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .. import sharding
from ..models import forward
from ..models.common import ModelConfig, opt_barrier
from . import optimizer as opt_lib
from .optimizer import OptimizerConfig

log = logging.getLogger("repro.train")


def softmax_xent(logits, targets, vocab: int):
    """fp32 cross-entropy; positions with target < 0 are masked; padded
    vocab rows (>= vocab) are excluded from the partition function.

    The picked-logit term is a one-hot contraction (not take_along_axis) so
    the vocab dim can stay model-sharded — no logits all-gather.
    """
    lf = logits.astype(jnp.float32)
    vp = lf.shape[-1]
    if vp > vocab:
        pad_mask = jnp.arange(vp) >= vocab
        lf = jnp.where(pad_mask, -1e30, lf)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    tgt = jnp.maximum(targets, 0)
    onehot = jax.nn.one_hot(tgt, vp, dtype=lf.dtype)
    onehot = sharding.shard(onehot, "dp", None, "tp")
    picked = jnp.einsum("bsv,bsv->bs", lf, onehot)
    nll = lse - picked
    mask = (targets >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def chunked_xent(x, head, targets, vocab: int, cfg, chunk: int = 512):
    """Cross-entropy with the head matmul fused into a sequence-chunk loop:
    full (B, S, V) logits are never materialized (the dominant 0-layer
    memory term at 256k vocab). Chunk bodies are rematerialized in backward.
    """
    from ..models import layers as _layers

    b, s, d = x.shape
    cs = min(chunk, s)
    n_chunks = (s + cs - 1) // cs
    hd = head.astype(x.dtype)
    # gather the seq-sharded hidden ONCE; otherwise every chunk's slice
    # (and its remat twin) re-all-gathers x — was the dominant collective
    x = sharding.shard(x, "dp", None, None)

    def body(lo):
        xc = jax.lax.dynamic_slice_in_dim(x, lo, cs, axis=1)
        tc = jax.lax.dynamic_slice_in_dim(targets, lo, cs, axis=1)
        logits = xc @ hd
        logits = sharding.shard(logits, "dp", None, "tp")
        lf = logits.astype(jnp.float32)
        vp = lf.shape[-1]
        if vp > vocab:
            lf = jnp.where(jnp.arange(vp) >= vocab, -1e30, lf)
        lse = jax.scipy.special.logsumexp(lf, axis=-1)
        onehot = jax.nn.one_hot(jnp.maximum(tc, 0), vp, dtype=logits.dtype)
        onehot = sharding.shard(onehot, "dp", None, "tp")
        picked = jnp.einsum("bsv,bsv->bs", logits, onehot,
                            preferred_element_type=jnp.float32)
        mask = (tc >= 0).astype(jnp.float32)
        return jnp.sum((lse - picked) * mask), jnp.sum(mask)

    body = jax.checkpoint(body)
    if n_chunks == 1 or _layers.cost_mode():
        parts = [body(i * cs) for i in range(n_chunks)]
        nll = sum(p[0] for p in parts)
        cnt = sum(p[1] for p in parts)
    else:
        def scan_body(carry, i):
            nll, cnt = body(i * cs)
            return (carry[0] + nll, carry[1] + cnt), None

        (nll, cnt), _ = jax.lax.scan(scan_body, (0.0, 0.0),
                                     jnp.arange(n_chunks))
    return nll / jnp.maximum(cnt, 1.0)


def make_loss_fn(cfg: ModelConfig) -> Callable:
    from ..models.transformer import head_matrix

    def loss_fn(params, batch):
        kwargs = {}
        if cfg.kind == "vlm":
            kwargs["embeds"] = batch["embeds"]
        if cfg.kind == "audio":
            kwargs["enc_embeds"] = batch["enc_embeds"]
        targets = batch["targets"]
        if cfg.cpd_embedding:
            # CPD head: logits come factored (never a dense (V, D) table)
            logits = forward(params, cfg, tokens=batch["tokens"], **kwargs)
            if cfg.kind == "vlm":
                logits = logits[:, cfg.n_img_tokens:]
            return softmax_xent(logits, targets, cfg.vocab)
        x = forward(params, cfg, tokens=batch["tokens"], return_hidden=True,
                    **kwargs)
        if cfg.kind == "vlm":  # image prefix positions carry no loss
            x = x[:, cfg.n_img_tokens:]
        return chunked_xent(x, head_matrix(params, cfg), targets, cfg.vocab,
                            cfg)
    return loss_fn


def make_train_step(cfg: ModelConfig, ocfg: OptimizerConfig,
                    grad_accum: int = 1, param_shardings=None,
                    cast_params_once: bool = False) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    ``grad_accum`` > 1 splits the batch into microbatches on the leading
    axis (Python loop: exact HLO cost, overlappable by XLA).
    ``param_shardings`` (optional pytree) constrains gradients to the FSDP
    param layout so XLA emits reduce-scatter instead of full all-reduce.
    ``cast_params_once`` makes one bf16 working copy of the >=2D params at
    step entry (sharded like the masters, pinned with optimization_barrier)
    so FSDP all-gathers move bf16, not the f32 masters — halves fwd/bwd
    param collective bytes (§Perf iteration).
    """
    loss_fn = make_loss_fn(cfg)

    def train_step(state, batch):
        params = state["params"]
        if cast_params_once:
            def cast(p, s=None):
                if p.ndim < 2 or not jnp.issubdtype(p.dtype, jnp.floating):
                    return p
                c = p.astype(cfg.cdtype)
                if s is not None:
                    c = jax.lax.with_sharding_constraint(c, s)
                return opt_barrier(c)

            if param_shardings is not None:
                fwd_params = jax.tree.map(cast, params, param_shardings)
            else:
                fwd_params = jax.tree.map(cast, params)
        else:
            fwd_params = params

        def one(mb):
            loss, g = jax.value_and_grad(loss_fn)(fwd_params, mb)
            return loss, g

        if grad_accum == 1:
            loss, grads = one(batch)
        else:
            from ..models import layers as _layers

            mbs = jax.tree.map(
                lambda x: x.reshape(grad_accum, -1, *x.shape[1:]), batch)
            if _layers.cost_mode():  # unrolled: exact HLO cost
                losses, grads = [], None
                for i in range(grad_accum):
                    li, gi = one(jax.tree.map(lambda x: x[i], mbs))
                    losses.append(li)
                    grads = gi if grads is None else jax.tree.map(
                        jnp.add, grads, gi)
                loss = sum(losses)
            else:                    # scanned: one microbatch live at a time
                def mb_body(carry, mb):
                    li, gi = one(mb)
                    acc_l, acc_g = carry
                    return (acc_l + li,
                            jax.tree.map(jnp.add, acc_g, gi)), None

                zero_g = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), fwd_params)
                (loss, grads), _ = jax.lax.scan(mb_body, (0.0, zero_g), mbs)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss = loss / grad_accum

        if cast_params_once:  # grads back to master dtype for the update
            grads = jax.tree.map(lambda g, p: g.astype(jnp.float32)
                                 if g.dtype != p.dtype and p.ndim >= 2
                                 else g, grads, params)
        if param_shardings is not None:  # grads land sharded like params
            grads = jax.tree.map(jax.lax.with_sharding_constraint, grads,
                                 param_shardings)
        grads, gnorm = opt_lib.clip_by_global_norm(grads, ocfg.grad_clip)
        new_params, new_opt, lr = opt_lib.update(grads, state["opt"],
                                                 params, ocfg)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return {"params": new_params, "opt": new_opt,
                "step": state["step"] + 1}, metrics

    return train_step


def init_state(cfg: ModelConfig, ocfg: OptimizerConfig, key):
    from ..models import init_model

    params = init_model(cfg, key)
    return {"params": params, "opt": opt_lib.init(params, ocfg),
            "step": jnp.zeros((), jnp.int32)}


# --------------------------------------------------------------------------
# Fault-tolerant controller (checkpoint/auto-resume/straggler watchdog)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class ControllerConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep: int = 3
    async_save: bool = True
    straggler_factor: float = 3.0   # step slower than factor*median -> flag
    max_failures: int = 3


class TrainController:
    """Runs the training loop with checkpoint/restart fault tolerance.

    - atomically checkpoints (params, opt, step, data cursor) every N steps;
    - auto-resumes from the newest checkpoint on (re)start — preemption
      recovery is "rerun the binary";
    - reshard-on-load: restore works onto a *different* mesh/device count
      than the checkpoint was written from (elastic shrink/grow);
    - straggler watchdog: flags steps slower than ``factor x`` running
      median (on multi-host this feeds the scheduler's quarantine list).
    """

    def __init__(self, cfg: ModelConfig, ocfg: OptimizerConfig,
                 ctrl: ControllerConfig, data_iter, train_step=None,
                 state=None, key=None):
        from .checkpoint import CheckpointManager

        self.cfg, self.ocfg, self.ctrl = cfg, ocfg, ctrl
        self.data = data_iter
        self.step_fn = train_step or jax.jit(make_train_step(cfg, ocfg))
        self.mgr = CheckpointManager(ctrl.ckpt_dir, keep=ctrl.keep,
                                     async_save=ctrl.async_save)
        self.state = state
        if self.state is None:
            self.state = init_state(cfg, ocfg, key or jax.random.PRNGKey(0))
            restored = self.mgr.restore_latest(like=self.state)
            if restored is not None:
                self.state, data_state = restored
                self.data.set_state(data_state)
                log.info("auto-resumed at step %s", int(self.state["step"]))
        self.durations: list[float] = []
        self.straggler_steps: list[int] = []

    def run(self, num_steps: int, fail_at: Optional[int] = None):
        """Train; ``fail_at`` injects a simulated preemption (tests)."""
        metrics = None
        while int(self.state["step"]) < num_steps:
            step = int(self.state["step"])
            if fail_at is not None and step == fail_at:
                raise InterruptedError(f"simulated preemption at {step}")
            t0 = time.monotonic()
            batch = self.data.next()
            self.state, metrics = self.step_fn(self.state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.monotonic() - t0
            self._watch(step, dt)
            if (step + 1) % self.ctrl.ckpt_every == 0:
                self.mgr.save(self.state, self.data.get_state())
        self.mgr.save(self.state, self.data.get_state())
        self.mgr.wait()
        return self.state, metrics

    def _watch(self, step: int, dt: float):
        self.durations.append(dt)
        hist = sorted(self.durations[-50:])
        med = hist[len(hist) // 2]
        if len(self.durations) > 5 and dt > self.ctrl.straggler_factor * med:
            self.straggler_steps.append(step)
            log.warning("straggler step %d: %.3fs (median %.3fs)",
                        step, dt, med)
