"""Training substrate: optimizer, loop, checkpointing, data, compression."""
from .optimizer import OptimizerConfig
from .train_loop import (ControllerConfig, TrainController, init_state,
                         make_loss_fn, make_train_step, softmax_xent)
from .checkpoint import CheckpointManager
from .data import SyntheticLM

__all__ = ["OptimizerConfig", "ControllerConfig", "TrainController",
           "init_state", "make_loss_fn", "make_train_step", "softmax_xent",
           "CheckpointManager", "SyntheticLM"]
