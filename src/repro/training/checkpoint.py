"""Sharded checkpointing: atomic, checksummed, retained, reshard-on-load.

A thin adapter over the :mod:`repro.resilience.snapshot` blob format:
each step is ONE flat ``.npz`` (flattened leaves + JSON meta with the
data-pipeline cursor) whose :func:`~repro.resilience.snapshot.
payload_digest` is part of the *filename* —
``step_<NNNNNNNN>-<digest12>.npz``. Writes go to a tmp file and are
published with ``os.replace`` (atomic on POSIX), so a preempted save can
never corrupt the latest checkpoint; restores recompute the digest, and
:meth:`CheckpointManager.restore_latest` quarantines a torn or
bit-rotten blob (renamed ``*.corrupt``) and falls back to the next-older
step instead of resuming from garbage. Restore ``device_put``s leaves
with whatever sharding the *current* mesh prescribes, so restarts may
change device count (elastic shrink/grow).
"""
from __future__ import annotations

import concurrent.futures
import json
import os
import re

import jax
import numpy as np

from repro.obs.metrics import counter as _counter
from repro.obs.trace import span as _span
from repro.resilience.snapshot import payload_digest

_NAME_RE = re.compile(r"step_(?P<step>\d{8})-(?P<digest>[0-9a-f]{12})\.npz")


def _events():
    return _counter("checkpoint_events",
                    "train checkpoint saves/loads/corruptions")


def _payload(host_leaves, meta_bytes) -> dict:
    """Canonical digest/save order: leaves, then meta."""
    arrays = {f"leaf{i:05d}": a for i, a in enumerate(host_leaves)}
    arrays["meta"] = meta_bytes
    return arrays


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 async_save: bool = True):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pool = (concurrent.futures.ThreadPoolExecutor(max_workers=1)
                      if async_save else None)
        self._pending = None

    # ------------------------------------------------------------------ save
    def save(self, state, data_state: dict | None = None):
        step = int(state["step"])
        # snapshot to host synchronously (cheap vs. train step), write async
        leaves, _ = jax.tree_util.tree_flatten(state)
        host = [np.asarray(x) for x in leaves]
        meta = {
            "step": step,
            "n_leaves": len(host),
            "data_state": data_state or {},
        }
        if self._pool is not None:
            self.wait()
            self._pending = self._pool.submit(self._write, step, host, meta)
        else:
            self._write(step, host, meta)

    def _write(self, step: int, host_leaves, meta):
        with _span("checkpoint.save", step=step) as sp:
            arrays = _payload(host_leaves, np.frombuffer(
                json.dumps(meta).encode(), dtype=np.uint8))
            digest = payload_digest(arrays)
            final = os.path.join(
                self.dir, f"step_{step:08d}-{digest[:12]}.npz")
            tmp = os.path.join(self.dir, f".tmp-{os.getpid()}-{step}")
            with open(tmp, "wb") as f:
                np.savez(f, **arrays)
            os.replace(tmp, final)                # atomic publish
            sp.set("path", os.path.basename(final))
        _events().inc("save")
        self._gc()

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _gc(self):
        blobs = self._blobs()
        for _, name in blobs[:-self.keep] if self.keep else []:
            try:
                os.remove(os.path.join(self.dir, name))
            except OSError:
                pass

    # --------------------------------------------------------------- restore
    def _blobs(self) -> list[tuple[int, str]]:
        """(step, filename) of every checkpoint blob, step-ascending."""
        out = []
        for name in os.listdir(self.dir):
            m = _NAME_RE.fullmatch(name)
            if m:
                out.append((int(m.group("step")), name))
        return sorted(out)

    def all_steps(self) -> list[int]:
        return [s for s, _ in self._blobs()]

    def _load(self, name: str):
        """Load + checksum-verify one blob; ValueError on corruption."""
        path = os.path.join(self.dir, name)
        m = _NAME_RE.fullmatch(name)
        with np.load(path) as blob:
            arrays = {k: blob[k] for k in blob.files}
        meta = json.loads(bytes(arrays["meta"]).decode())
        host = [arrays[f"leaf{i:05d}"] for i in range(meta["n_leaves"])]
        digest = payload_digest(_payload(host, arrays["meta"]))
        if digest[:12] != m.group("digest"):
            raise ValueError(f"checkpoint payload digest mismatch: {path}")
        return host, meta

    def _quarantine(self, name: str) -> None:
        _events().inc("corrupt")
        with _span("checkpoint.quarantine", path=name):
            try:
                os.replace(os.path.join(self.dir, name),
                           os.path.join(self.dir, name + ".corrupt"))
            except OSError:
                pass

    def _unflatten(self, host, meta, like, shardings):
        if like is None:
            raise ValueError("restore requires `like` pytree for structure")
        _, treedef = jax.tree_util.tree_flatten(like)
        state = jax.tree_util.tree_unflatten(treedef, host)
        if shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s), state, shardings)
        _events().inc("load")
        return state, meta["data_state"]

    def restore(self, step: int, like=None, shardings=None):
        """Load one step. ``like`` (a pytree of the same structure, e.g.
        from init or eval_shape) provides the treedef; ``shardings`` (same
        structure, optional) reshards onto the current mesh. Raises on a
        corrupt blob — use :meth:`restore_latest` for quarantine-and-
        fall-back semantics."""
        for s, name in self._blobs():
            if s == step:
                host, meta = self._load(name)
                return self._unflatten(host, meta, like, shardings)
        raise FileNotFoundError(f"no checkpoint for step {step} in "
                                f"{self.dir}")

    def restore_latest(self, like=None, shardings=None):
        """Newest *intact* checkpoint, or ``None`` with an empty dir.
        Corrupt blobs met on the way down are quarantined and skipped."""
        if like is None:
            return None
        for _, name in reversed(self._blobs()):
            try:
                host, meta = self._load(name)
            except Exception:
                self._quarantine(name)
                continue
            return self._unflatten(host, meta, like, shardings)
        return None
