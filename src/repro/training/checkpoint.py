"""Sharded checkpointing: atomic, retained, async, reshard-on-load.

Layout:  <dir>/step_<N>/  with one ``.npy`` per flattened leaf plus
``meta.json`` (tree structure, data-pipeline cursor, step). Writes go to
``step_<N>.tmp`` and are renamed (atomic on POSIX) — a preempted save can
never corrupt the latest checkpoint. Restore ``device_put``s leaves with
whatever sharding the *current* mesh prescribes, so restarts may change
device count (elastic shrink/grow).
"""
from __future__ import annotations

import concurrent.futures
import json
import os
import re
import shutil

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 async_save: bool = True):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pool = (concurrent.futures.ThreadPoolExecutor(max_workers=1)
                      if async_save else None)
        self._pending = None

    # ------------------------------------------------------------------ save
    def save(self, state, data_state: dict | None = None):
        step = int(state["step"])
        # snapshot to host synchronously (cheap vs. train step), write async
        leaves, treedef = _flatten(state)
        host = [np.asarray(x) for x in leaves]
        meta = {
            "step": step,
            "treedef": jax.tree_util.tree_structure(state).serialize_using_proto().hex()
            if hasattr(treedef, "serialize_using_proto") else None,
            "n_leaves": len(host),
            "data_state": data_state or {},
        }
        if self._pool is not None:
            self.wait()
            self._pending = self._pool.submit(self._write, step, host, meta)
        else:
            self._write(step, host, meta)

    def _write(self, step: int, host_leaves, meta):
        tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
        final = os.path.join(self.dir, f"step_{step:08d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for i, arr in enumerate(host_leaves):
            np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                     # atomic publish
        self._gc()

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name,
                                                 "meta.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def restore(self, step: int, like=None, shardings=None):
        """Load a checkpoint. ``like`` (a pytree of the same structure, e.g.
        from init or eval_shape) provides the treedef; ``shardings`` (same
        structure, optional) reshards onto the current mesh."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        host = [np.load(os.path.join(path, f"leaf_{i:05d}.npy"))
                for i in range(meta["n_leaves"])]
        if like is None:
            raise ValueError("restore requires `like` pytree for structure")
        _, treedef = _flatten(like)
        state = jax.tree_util.tree_unflatten(treedef, host)
        if shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s), state, shardings)
        return state, meta["data_state"]

    def restore_latest(self, like=None, shardings=None):
        steps = self.all_steps()
        if not steps:
            return None
        if like is None:
            return None
        return self.restore(steps[-1], like=like, shardings=shardings)
