"""Low-rank cross-pod gradient compression (PowerSGD-style, arXiv:1905.13727).

The same low-rank machinery as the paper's CPD factors, applied to the
distributed-optimization layer (DESIGN.md §4): instead of all-reducing a
full (A, B) gradient across the slow inter-pod links, exchange rank-r
factors P (A, r) and Q (B, r):

    P = G Q0;  P = psum_mean(P); P = orth(P);  Q = G^T P; Q = psum_mean(Q)
    G_hat = P Q^T

Error feedback keeps the residual locally and re-adds it next step, so the
compression bias vanishes over time. Used inside a ``shard_map`` over the
"pod" axis by the explicit-DP train step (opt-in; tests cover 4 fake pods).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _orthonormalize(p):
    q, _ = jnp.linalg.qr(p.astype(jnp.float32))
    return q


def compress_allreduce(g, key, rank: int, axis_name: str):
    """All-reduce a >=2D gradient across ``axis_name`` via rank-r factors.

    Returns the synchronized low-rank approximation of mean(g). 1-D leaves
    should be psum'd directly (they are small).
    """
    shape = g.shape
    a = shape[0]
    b = 1
    for s in shape[1:]:
        b *= s
    g2 = g.reshape(a, b).astype(jnp.float32)
    r = min(rank, a, b)
    q0 = jax.random.normal(key, (b, r), jnp.float32)
    p = g2 @ q0
    p = jax.lax.pmean(p, axis_name)
    p = _orthonormalize(p)
    q = g2.T @ p
    q = jax.lax.pmean(q, axis_name)
    return (p @ q.T).reshape(shape).astype(g.dtype)


def compressed_grad_sync(grads, key, rank: int, axis_name: str,
                         error: dict | None = None):
    """Tree-wide sync: 2D+ leaves compressed (with error feedback), small
    leaves psum'd exactly. Returns (synced_grads, new_error)."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    err_leaves = (jax.tree_util.tree_flatten(error)[0] if error is not None
                  else [jnp.zeros_like(x) for x in leaves])
    keys = jax.random.split(key, len(leaves))
    out, new_err = [], []
    for x, e, k in zip(leaves, err_leaves, keys):
        if x.ndim >= 2 and x.size >= 4096:
            corrected = x + e.astype(x.dtype)
            approx = compress_allreduce(corrected, k, rank, axis_name)
            out.append(approx)
            new_err.append((corrected - approx).astype(e.dtype))
        else:
            out.append(jax.lax.pmean(x, axis_name))
            new_err.append(jnp.zeros_like(e))
    return (jax.tree_util.tree_unflatten(treedef, out),
            jax.tree_util.tree_unflatten(treedef, new_err))
