"""Cost-model-guided plan autotuner over a :class:`~repro.engine.factory.
PlanSpace`.

fig8 shows the P-sweep is non-monotonic and fig10 that planning itself is
expensive, so the tuner is staged to spend host time where it matters
(the load-balanced MTTKRP line of work — arxiv 1904.03329 — motivates the
histogram-driven model):

1. **Analytic stage** (:func:`analytic_cost`): a closed-form cost over the
   per-mode nnz-per-slice (degree) histograms only — no plans are built.
   It simulates Alg. 1's cyclic deal from the sorted degrees (partition
   loads are column sums of the rank-major deal), prices pad slots from
   the block schedule, models in-block factor-row DMA copies with a
   collision model (``E[uniques/block] = sum_r 1-(1-p_r)^P``), and adds
   the imbalance surplus over the ``OPT >= max(mean, d_max)`` bound. The
   full space is ranked and pruned to ``top_k`` candidates.
2. **Exact stage** (:func:`modeled_cost`): candidates are actually planned
   (through the plan cache, so shared structure is priced once) and scored
   on the *real* pad slots + DMA row copies
   (:meth:`FlycooTensor.dma_row_model`). The hand-set default spec is
   always evaluated here, so the tuned pick is never worse than the
   default on modeled cost.
3. **Measured stage** (optional, :func:`hill_climb`): a greedy
   hypothesis->change->measure loop over single-knob neighbors, using the
   ``experiments/hillclimb.py`` harness as the measurement backend.
   Tie-breaks are seeded; the whole pipeline is reproducible under a
   fixed seed.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

import numpy as np

from repro.obs.trace import span

from .factory import SPACE_DIMS, PlanSpace, PlanSpec


def _needs_dedup_tables(spec: PlanSpec) -> bool:
    from .backends import get_backend

    return (spec.schedule == "compact"
            and getattr(get_backend(spec.backend), "needs_dedup", False))


def _mode_degrees(indices: np.ndarray, dims: Sequence[int]) -> list:
    idx_t = np.ascontiguousarray(np.asarray(indices, dtype=np.int32).T)
    return [np.bincount(idx_t[d], minlength=int(dims[d]))
            for d in range(len(dims))]


# --------------------------------------------------------------------------
# Streaming transfer term (chunk H2D + remap fragment D2H per hop).
#
# Costs are in *slot units* (one f32 element move); transfer bytes divide
# by 4 to land in the same unit, plus ``block_p`` slots of launch/ring-
# turnaround overhead per chunk so the tuner never picks pathologically
# tiny chunks (chunk padding alone would not punish a chunk of exactly one
# partition).
# --------------------------------------------------------------------------
def _analytic_stream_cost(spec: PlanSpec, config, dims, nnz: int,
                          mode_nblocks: Sequence[int]) -> float:
    """Histogram-stage streaming transfer cost; mirrors
    :func:`repro.engine.stream.stream_transfer_model` with chunk counts
    approximated from the modeled block totals (no plans built)."""
    from repro.engine.stream import (bytes_per_slot, resolve_chunk_slots,
                                     row_bytes)

    n = len(dims)
    tables = _needs_dedup_tables(spec) and spec.dedup
    target = resolve_chunk_slots(config, dims, tables=tables)
    target_blocks = max(1, target // spec.block_p)
    total = 0.0
    for nblocks in mode_nblocks:
        nchunks = max(1, -(-int(nblocks) // target_blocks))
        upload_slots = int(nblocks) * spec.block_p
        total += upload_slots * bytes_per_slot(n, tables) / 4.0
        total += nnz * row_bytes(n) / 4.0          # remap fragment per hop
        total += nchunks * spec.block_p            # per-chunk overhead
    return total


def _analytic_streams(spec: PlanSpec, config, dims, nnz: int,
                      mode_nblocks: Sequence[int]) -> bool:
    """Whether this spec runs the streaming tier, with ``"auto"`` resolved
    against a histogram-stage estimate of the resident footprint."""
    if spec.residency == "stream":
        return True
    if spec.residency != "auto" or config.device_budget_bytes is None:
        return False
    n = len(dims)
    smax = max(int(b) for b in mode_nblocks) * spec.block_p
    resident = smax * 4 * (1 + 2 * n)
    tables = _needs_dedup_tables(spec) and spec.dedup
    for nblocks in mode_nblocks:
        s_d = int(nblocks) * spec.block_p
        resident += int(nblocks) * 4
        if tables:
            resident += s_d * 8 * (n - 1) + int(nblocks) * 4 * (n - 1)
    resident += sum(int(d) for d in dims) * 4 * (1 + spec.rank_hint)
    resident += max(int(d) for d in dims) * spec.rank_hint * 4
    return resident > config.device_budget_bytes


def _spec_streams(spec: PlanSpec, tensor) -> bool:
    """Exact-stage residency resolution — the same rule
    ``factory.make_engine`` applies (``resident_bytes`` vs budget)."""
    from repro.engine.stream import resident_bytes

    if spec.residency == "stream":
        return True
    config = spec.to_config()
    return (spec.residency == "auto"
            and config.device_budget_bytes is not None
            and resident_bytes(tensor, config) > config.device_budget_bytes)


# --------------------------------------------------------------------------
# Stage 1: analytic cost from degree histograms only.
# --------------------------------------------------------------------------
def analytic_cost(degrees: Sequence[np.ndarray], dims: Sequence[int],
                  nnz: int, spec: PlanSpec) -> float:
    """Histogram-only plan cost (slot units): pad slots + modeled DMA row
    copies + imbalance surplus over the OPT lower bound, plus the modeled
    transfer traffic (chunk H2D + remap fragments) when the spec resolves
    to the streaming tier. No plans built.
    """
    spec = spec.canonical()
    config = spec.to_config()
    n = len(dims)
    p_blk = spec.block_p
    total = 0.0
    mode_nblocks = []
    # per-factor expected unique rows per block (collision model) — spec-
    # independent except for P, computed once per input mode
    uniq_per_block = []
    for w in range(n):
        p = degrees[w].astype(np.float64) / max(nnz, 1)
        uniq_per_block.append(float((1.0 - (1.0 - p) ** p_blk).sum()))
    for d in range(n):
        dim = int(dims[d])
        kappa = config.kappa_for(dim)
        deg = np.sort(degrees[d].astype(np.int64))[::-1]
        pad = (-dim) % kappa
        if pad:
            deg = np.concatenate([deg, np.zeros(pad, dtype=deg.dtype)])
        part_nnz = deg.reshape(-1, kappa).sum(axis=0)
        blocks = np.maximum(1, -(-part_nnz // p_blk))
        if spec.schedule == "rect":
            nblocks = kappa * int(blocks.max())
        else:
            nblocks = int(blocks.sum())
        mode_nblocks.append(nblocks)
        pad_slots = nblocks * p_blk - nnz
        # imbalance surplus of the achieved max load over the OPT bound
        opt_lb = max(float(part_nnz.mean()), float(deg[0]))
        surplus = float(part_nnz.max()) - opt_lb
        if _needs_dedup_tables(spec) and spec.dedup:
            dma = sum(min(uniq_per_block[w], p_blk) * nblocks
                      for w in range(n) if w != d)
        else:
            dma = (n - 1) * nblocks * p_blk
        total += pad_slots + dma + surplus
    if _analytic_streams(spec, config, dims, nnz, mode_nblocks):
        total += _analytic_stream_cost(spec, config, dims, nnz,
                                       mode_nblocks)
    return float(total)


# --------------------------------------------------------------------------
# Stage 2: exact modeled cost from built plans.
# --------------------------------------------------------------------------
def modeled_cost(tensor, spec: PlanSpec) -> float:
    """Exact modeled cost of ``tensor``'s built plans under ``spec``:
    pad slots + factor-row DMA copies (dedup tables when the spec uses
    them, per-slot copies otherwise), plus the exact streamed transfer
    traffic (:func:`repro.engine.stream.stream_transfer_model`) when the
    spec resolves to the streaming tier — so tuned chunk sizes are chosen
    against real chunk padding, not guessed."""
    spec = spec.canonical()
    total = 0.0
    for d in range(tensor.nmodes):
        plan = tensor.plans[d]
        total += plan.padded_nnz - tensor.nnz
        if _needs_dedup_tables(spec) and spec.dedup:
            total += tensor.dma_row_model(d)["dedup_rows"]
        else:
            total += (tensor.nmodes - 1) * plan.padded_nnz
    if _spec_streams(spec, tensor):
        from repro.engine.stream import stream_transfer_model

        model = stream_transfer_model(tensor, spec.to_config())
        total += (model["h2d_bytes"] + model["fragment_bytes"]) / 4.0
        total += model["total_chunks"] * spec.block_p
    return float(total)


def _build_for(spec: PlanSpec, indices, values, dims, cache):
    from repro.core.flycoo import build_flycoo

    config = spec.to_config()
    kw = dict(kappa=config.kappa if config.kappa_policy == "fixed" else None,
              rows_pp=config.resolve_rows_pp(), block_p=config.block_p,
              schedule=config.schedule)
    if cache is not None:
        return cache.get_tensor(indices, values, dims, **kw)
    return build_flycoo(indices, values, dims, **kw)


# --------------------------------------------------------------------------
# Stage 3: measured greedy hill-climb (hypothesis -> change -> measure).
# --------------------------------------------------------------------------
def hill_climb(start: PlanSpec, candidates: Sequence[PlanSpec],
               measure: Callable[[PlanSpec], float], *,
               seed: int = 0, max_steps: int = 8):
    """Greedy single-knob descent over ``candidates``.

    From ``start``, measure every candidate differing in exactly one
    searchable knob, move to the best strict improvement, repeat. Each
    spec is measured once (memoized); equal measurements tie-break by
    seeded draw, so a fixed seed reproduces the trajectory exactly.
    Returns ``(best_spec, trace)`` where ``trace`` records every
    hypothesis->change->measure step.
    """
    rng = np.random.default_rng(seed)
    cand = list(dict.fromkeys(c.canonical() for c in candidates))
    seen: dict[PlanSpec, float] = {}

    def timed(spec: PlanSpec) -> float:
        if spec not in seen:
            seen[spec] = float(measure(spec))
        return seen[spec]

    current = start.canonical()
    cur_t = timed(current)
    trace = [{"step": 0, "spec": current, "time": cur_t, "move": "start"}]
    for step in range(1, max_steps + 1):
        neighbors = [
            c for c in cand if c != current
            and sum(getattr(c, f) != getattr(current, f)
                    for f in SPACE_DIMS) == 1
        ]
        if not neighbors:
            break
        best, best_t = None, cur_t
        for c in neighbors:
            t = timed(c)
            # strict improvement moves; exact ties resolved by seeded coin
            if t < best_t or (t == best_t and best is not None
                              and rng.integers(2) == 1):
                best, best_t = c, t
        if best is None:
            break
        trace.append({"step": step, "spec": best, "time": best_t,
                      "move": _diff(current, best)})
        current, cur_t = best, best_t
    return current, trace


def _diff(a: PlanSpec, b: PlanSpec) -> str:
    parts = [f"{f}: {getattr(a, f)!r} -> {getattr(b, f)!r}"
             for f in SPACE_DIMS if getattr(a, f) != getattr(b, f)]
    return "; ".join(parts) or "none"


# --------------------------------------------------------------------------
# The tuner.
# --------------------------------------------------------------------------
@dataclasses.dataclass
class AutotuneResult:
    best: PlanSpec                       # winner (modeled or measured)
    default: PlanSpec                    # the hand-set baseline point
    analytic: dict                       # spec -> stage-1 cost (full space)
    modeled: dict                        # spec -> stage-2 cost (candidates)
    measured: dict                       # spec -> seconds (measured stage)
    trace: list                          # hill-climb trajectory
    seed: int

    def summary(self) -> dict:
        return {
            "best": dataclasses.asdict(self.best),
            "modeled_best": min(self.modeled.values()),
            "modeled_default": self.modeled[self.default],
            "n_analytic": len(self.analytic),
            "n_exact": len(self.modeled),
            "n_measured": len(self.measured),
            "seed": self.seed,
        }


def autotune(indices, values, dims,
             space: PlanSpace | None = None, *,
             top_k: int = 4,
             measure: Callable[[PlanSpec], float] | None = None,
             seed: int = 0,
             cache=None,
             max_steps: int = 8) -> AutotuneResult:
    """Pick a plan spec for a COO tensor; see module docstring for stages.

    ``measure`` (optional) maps a spec to a wall-time sample — when given,
    a seeded greedy hill-climb over the analytic top-``top_k`` runs after
    the exact stage; otherwise the exact modeled cost decides. The
    hand-set default (``space.base``) is always scored in the exact stage,
    so the returned spec is never worse than it on modeled cost.
    Deterministic for a fixed ``seed``.
    """
    from repro.core.plancache import PlanCache

    space = space or PlanSpace()
    if cache is None:
        cache = PlanCache()
    indices = np.ascontiguousarray(np.asarray(indices, dtype=np.int32))
    nnz = int(indices.shape[0])
    with span("autotune", nnz=nnz, top_k=top_k,
              measured=measure is not None) as tune_sp:
        degrees = _mode_degrees(indices, dims)

        # stage 1: rank the whole space analytically
        specs = space.specs()
        with span("autotune.analytic", space_size=len(specs)):
            analytic = {s: analytic_cost(degrees, dims, nnz, s)
                        for s in specs}
        ranked = sorted(specs, key=lambda s: (analytic[s], specs.index(s)))
        default = space.base.canonical()
        candidates = list(dict.fromkeys(
            [default] + ranked[:max(1, top_k)]))

        # stage 2: exact modeled cost on built plans (through the cache)
        modeled = {}
        with span("autotune.exact", candidates=len(candidates)):
            for s in candidates:
                t = _build_for(s, indices, values, dims, cache)
                modeled[s] = modeled_cost(t, s)
        best = min(candidates,
                   key=lambda s: (modeled[s], candidates.index(s)))

        # stage 3 (optional): measured hill-climb from the modeled winner
        measured: dict = {}
        trace: list = []
        if measure is not None:
            def memo_measure(spec: PlanSpec) -> float:
                with span("autotune.measure", backend=spec.backend,
                          schedule=spec.schedule, block_p=spec.block_p):
                    t = float(measure(spec))
                measured[spec] = t
                return t

            with span("autotune.hill_climb", max_steps=max_steps):
                best, trace = hill_climb(best, candidates, memo_measure,
                                         seed=seed, max_steps=max_steps)
        tune_sp.set("n_measured", len(measured))

        return AutotuneResult(best=best, default=default, analytic=analytic,
                              modeled=modeled, measured=measured,
                              trace=trace, seed=seed)


__all__ = ["analytic_cost", "modeled_cost", "hill_climb", "autotune",
           "AutotuneResult"]
