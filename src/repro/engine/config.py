"""Execution configuration for the functional spMTTKRP engine.

``ExecutionConfig`` is a *frozen* (hashable) dataclass: it rides in the
static aux_data of :class:`repro.engine.state.EngineState`, so two states
with different configs hash to different jit cache entries and nothing
about execution policy is smuggled through mutable attributes.
"""
from __future__ import annotations

import dataclasses
import math

import jax

# Kappa policies understood by ``engine.init`` when it has to *build* the
# FLYCOO plans itself (raw COO input). "vmem" sizes partitions so a row
# tile fits VMEM (the DESIGN.md default); "fixed" uses ``kappa`` verbatim.
KAPPA_POLICIES = ("vmem", "fixed")

# Block schedules (see ``repro.core.partition``): "compact" emits only real
# blocks + a block->partition descriptor; "rect" pads every partition to
# the max partition's block count (the comparison baseline).
SCHEDULES = ("compact", "rect")

# Residency tiers: "full" keeps the whole FLYCOO layout device-resident
# (the classic engine); "stream" keeps only a double-buffered ring of
# partition-aligned chunks resident (the out-of-core tier,
# ``repro.engine.stream``); "auto" lets ``factory.make_engine`` pick —
# stream exactly when the resident layout would exceed
# ``device_budget_bytes``.
RESIDENCIES = ("auto", "full", "stream")

# Degradation-ladder backend ordering (consumed by ``repro.resilience``):
# on a compile/lowering failure each backend falls back to the next entry
# — strictly more portable, bitwise-identical output (the parity property
# every backend already CI-gates). ``residency`` has its own rung
# (full -> stream, in ``factory.make_engine``) and the streaming tier
# halves its chunk budget on OOM; see ``repro.resilience.ladder``.
BACKEND_LADDER = ("pallas_fused", "pallas", "xla", "ref")

# One budget, two tiers: when only the device (HBM) budget is given, the
# VMEM share the "vmem" kappa policy sizes row tiles against is derived
# from it — a fixed fraction capped at a typical per-core VMEM — so
# residency, rows_pp, and chunking can never contradict each other.
DEFAULT_VMEM_BYTES = 16 * 1024 * 1024
VMEM_FRACTION_OF_DEVICE = 8


def derive_vmem_budget(device_budget_bytes: int) -> int:
    """VMEM share of a device (HBM) budget: ``device/8`` capped at 16 MiB.
    The single derivation rule ``PlanSpec.canonical()`` and
    ``ExecutionConfig.resolve_rows_pp`` both use, so the row-tile sizing
    and the chunk sizing always answer to the same budget."""
    return max(1, min(DEFAULT_VMEM_BYTES,
                      device_budget_bytes // VMEM_FRACTION_OF_DEVICE))


def platform_default_interpret() -> bool:
    """Single source of the Pallas interpret-mode platform default: run the
    kernels through Mosaic only on a real TPU, interpret everywhere else.
    Both ``ExecutionConfig.resolve_interpret`` and ``repro.kernels.ops``
    defer here, so engine and kernels can never disagree."""
    return jax.default_backend() != "tpu"


@dataclasses.dataclass(frozen=True)
class ExecutionConfig:
    """Static execution policy for the engine (hashable, jit-cache safe).

    Attributes:
      backend: name in the backend registry (``xla`` | ``pallas`` | ``ref``).
      interpret: Pallas interpret mode. ``None`` = auto (interpret everywhere
        except on a real TPU), mirroring ``kernels.ops``.
      block_p: nonzeros per kernel block when the engine builds plans itself
        (paper's P; one sublane tile by default).
      kappa_policy: how ``engine.init`` picks the partition count for raw
        COO input — ``"vmem"`` (derive from rows_pp) or ``"fixed"``.
      kappa: partition count used when ``kappa_policy == "fixed"``.
      rows_pp: rows per partition for the ``"vmem"`` policy (``None`` =
        library default).
      precision: accumulation dtype name for the Hadamard partials
        (``"float32"`` unless a later mixed-precision PR widens this).
      donate: donate the layout buffers into the jitted scan (the paper's
        T_in/T_out swap without a second live copy). ``None`` = auto:
        donate only where XLA supports it (TPU/GPU).
      fuse_remap: let a fusing backend (one exposing ``fused_remap``, e.g.
        ``pallas_fused``) emit the Alg. 3 remap scatter inside its kernel
        pass instead of the three full-``S_max`` XLA scatters in the scan
        step. ``False`` forces the XLA scatter path for any backend (the
        comparison baseline).
      dedup: build the in-block factor-row dedup tables for backends that
        consume them (``needs_dedup``). ``False`` installs the trivial
        tables (one row DMA per slot) — same kernels, no host-side
        per-block sort; a plan-space point that trades preprocessing time
        against kernel DMA traffic.
      vmem_budget_bytes: VMEM budget the ``"vmem"`` kappa policy sizes row
        tiles against when ``rows_pp`` is not given explicitly. ``None`` =
        library default tile (``partition.DEFAULT_ROWS_PER_PARTITION``).
      rank_hint: rank R used to convert the VMEM budget into rows (the
        paper's default R=32); only consulted when ``vmem_budget_bytes``
        is set.
      schedule: block schedule used when ``engine.init`` builds plans from
        raw COO input — ``"compact"`` (load-balanced grid of real blocks,
        the default) or ``"rect"`` (rectangular comparison baseline). A
        prebuilt ``FlycooTensor``'s plans carry their own schedule and
        take precedence.
      residency: memory tier — ``"full"`` (whole layout device-resident),
        ``"stream"`` (out-of-core chunk ring, ``repro.engine.stream``), or
        ``"auto"`` (factory picks by comparing the resident footprint to
        ``device_budget_bytes``).
      chunk_nnz: target nonzeros per streamed chunk (partition-aligned;
        the planner rounds to whole partitions). ``None`` = derive from
        ``device_budget_bytes`` / the library default.
      device_budget_bytes: device (HBM) budget the streaming tier sizes
        its resident chunk ring against, and the threshold ``"auto"``
        residency compares the full layout to. Also the root of the
        derived VMEM budget (``derive_vmem_budget``) when
        ``vmem_budget_bytes`` is not set.
      stream_ring: number of resident chunk buffers in the streaming ring
        (2 = classic double buffering: chunk k computes while k+1 uploads).
    """

    backend: str = "xla"
    interpret: bool | None = None
    block_p: int = 128
    kappa_policy: str = "vmem"
    kappa: int | None = None
    rows_pp: int | None = None
    precision: str = "float32"
    donate: bool | None = None
    fuse_remap: bool = True
    dedup: bool = True
    vmem_budget_bytes: int | None = None
    rank_hint: int = 32
    schedule: str = "compact"
    residency: str = "auto"
    chunk_nnz: int | None = None
    device_budget_bytes: int | None = None
    stream_ring: int = 2

    def __post_init__(self):
        if self.kappa_policy not in KAPPA_POLICIES:
            raise ValueError(
                f"kappa_policy {self.kappa_policy!r} not in {KAPPA_POLICIES}")
        if self.schedule not in SCHEDULES:
            raise ValueError(
                f"schedule {self.schedule!r} not in {SCHEDULES}")
        if self.residency not in RESIDENCIES:
            raise ValueError(
                f"residency {self.residency!r} not in {RESIDENCIES}")
        if self.kappa_policy == "fixed" and self.kappa is None:
            raise ValueError("kappa_policy='fixed' requires kappa")
        if self.vmem_budget_bytes is not None and self.vmem_budget_bytes < 1:
            raise ValueError("vmem_budget_bytes must be positive")
        if self.chunk_nnz is not None and self.chunk_nnz < 1:
            raise ValueError("chunk_nnz must be positive")
        if (self.device_budget_bytes is not None
                and self.device_budget_bytes < 1):
            raise ValueError("device_budget_bytes must be positive")
        if self.stream_ring < 1:
            raise ValueError("stream_ring must be >= 1")
        if (self.vmem_budget_bytes is not None
                and self.device_budget_bytes is not None
                and self.vmem_budget_bytes > self.device_budget_bytes):
            raise ValueError(
                "contradictory budgets: vmem_budget_bytes "
                f"({self.vmem_budget_bytes}) exceeds device_budget_bytes "
                f"({self.device_budget_bytes})")

    # ------------------------------------------------------------ resolution
    def resolve_interpret(self) -> bool:
        if self.interpret is None:
            return platform_default_interpret()
        return bool(self.interpret)

    def resolve_donate(self) -> bool:
        if self.donate is None:
            # CPU XLA ignores donation and warns; keep auto mode quiet there.
            return jax.default_backend() in ("tpu", "gpu")
        return bool(self.donate)

    def accum_dtype(self):
        import jax.numpy as jnp

        return jnp.dtype(self.precision)

    def resolve_rows_pp(self) -> int | None:
        """Rows per partition for the ``"vmem"`` kappa policy.

        Explicit ``rows_pp`` wins. Otherwise, with a ``vmem_budget_bytes``
        the tile is sized so the fused kernel's resident f32 output tile
        (``rows_pp * rank_hint * 4`` bytes) uses at most half the budget —
        the other half is reserved for the double-buffered factor-row
        staging and the one-hot operand. ``None`` means the library default
        tile (``partition.DEFAULT_ROWS_PER_PARTITION``).
        """
        if self.rows_pp is not None:
            return self.rows_pp
        vmem = self.resolve_vmem_budget()
        if vmem is None:
            return None
        return max(8, vmem // (2 * 4 * self.rank_hint))

    def resolve_vmem_budget(self) -> int | None:
        """The one VMEM budget everything answers to: explicit
        ``vmem_budget_bytes`` wins; otherwise it is derived from
        ``device_budget_bytes`` (``derive_vmem_budget``); ``None`` when
        neither budget is set."""
        if self.vmem_budget_bytes is not None:
            return self.vmem_budget_bytes
        if self.device_budget_bytes is not None:
            return derive_vmem_budget(self.device_budget_bytes)
        return None

    def kappa_for(self, dim: int, n_dev: int = 1) -> int:
        """Partition count for a mode of size ``dim`` under this config's
        kappa policy, rounded so each of ``n_dev`` devices owns an equal,
        contiguous run of partitions (``kappa % n_dev == 0`` and
        ``kappa <= dim``, so ``plan_mode`` never clamps it).

        This is the single source of the per-device rounding rule — the
        engine, ``core.distributed.build_sharded_flycoo``, and benchmarks
        all derive their sharded partition counts from it.
        """
        if self.kappa_policy == "fixed":
            base = self.kappa
        else:
            from repro.core.partition import choose_kappa

            rows_pp = self.resolve_rows_pp()
            base = choose_kappa(dim, rows_pp) if rows_pp else choose_kappa(dim)
        if n_dev <= 1:
            return min(base, dim)
        if dim < n_dev:
            raise ValueError(
                f"mode of size {dim} cannot shard over {n_dev} devices "
                "(fewer rows than devices)")
        kappa = max(n_dev, math.ceil(base / n_dev) * n_dev)
        return min(kappa, (dim // n_dev) * n_dev)


__all__ = ["ExecutionConfig", "KAPPA_POLICIES", "SCHEDULES", "RESIDENCIES",
           "BACKEND_LADDER", "derive_vmem_budget",
           "platform_default_interpret"]
