"""Pytree engine state for the functional spMTTKRP engine.

``EngineState`` is the device-resident half of a
:class:`~repro.core.flycoo.FlycooTensor`: the *current* FLYCOO layout
(val/idx/alpha), padded to the uniform slot count ``S_max = max_d S_d`` so
the same pytree shape serves every mode — which is exactly what makes the
mode loop a ``lax.scan`` carry and the T_in/T_out swap a buffer donation
instead of a host round-trip.

Array leaves (pytree children):
  val      (S_max,)     f32   nonzero values, 0 in pads
  idx      (S_max, N)   i32   beta — original per-mode indices, 0 in pads
  alpha    (S_max, N)   i32   alpha — slot of the element in every mode
                              layout (-1 in pads)
  relabel  N x (I_d,)   i32   old row id -> relabeled row id, per mode
  sched    N x ModeSched      per-mode block-schedule tables: the block ->
                              partition descriptor and (compact schedule)
                              the in-block factor-row dedup tables. Unlike
                              the layout triple these never remap — they
                              describe the mode-d slot space itself.

Static aux_data (hashable, part of the jit cache key):
  mode     int                 which mode's layout is resident
  dims     tuple[int, ...]
  statics  tuple[ModeStatic]   per-mode plan constants (kappa, rows_pp, ...)
  config   ExecutionConfig
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax

from .config import ExecutionConfig


class ModeStatic(NamedTuple):
    """Hashable subset of ``partition.ModePlan`` the kernels need."""

    kappa: int
    rows_pp: int
    blocks_pp: int
    block_p: int
    dim: int
    nblocks: int = -1        # total kernel blocks; -1 = rect default
    schedule: str = "rect"   # "compact" | "rect" block schedule

    @property
    def padded_nnz(self) -> int:
        if self.schedule == "compact":
            return self.nblocks * self.block_p
        return self.kappa * self.blocks_pp * self.block_p

    @property
    def relabeled_rows(self) -> int:
        return self.kappa * self.rows_pp


class ModeSched(NamedTuple):
    """Per-mode device-resident schedule tables (pytree of array leaves).

    ``bpart`` is the ``(nblocks,)`` block -> partition descriptor (present
    for both schedules). The dedup tables (see ``FlycooTensor.
    dedup_tables``) are built for the ``compact`` schedule only and are
    ``None`` under ``rect``:

      uidx   (N-1, S_d)      per-block unique factor rows, front-compacted
      upos   (S_d, N-1)      per-slot stage position among the uniques
      nuniq  (N-1, nblocks)  per-block unique-row counts
    """

    bpart: jax.Array
    uidx: Optional[jax.Array] = None
    upos: Optional[jax.Array] = None
    nuniq: Optional[jax.Array] = None


def mode_static_from_plan(plan) -> ModeStatic:
    return ModeStatic(kappa=plan.kappa, rows_pp=plan.rows_pp,
                      blocks_pp=plan.blocks_pp, block_p=plan.block_p,
                      dim=plan.dim, nblocks=plan.nblocks,
                      schedule=plan.schedule)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class EngineState:
    """Immutable, pytree-registered engine state (see module docstring)."""

    val: jax.Array
    idx: jax.Array
    alpha: jax.Array
    relabel: tuple[jax.Array, ...]
    sched: tuple[ModeSched, ...]
    mode: int
    dims: tuple[int, ...]
    statics: tuple[ModeStatic, ...]
    config: ExecutionConfig

    # ------------------------------------------------------------ derived
    @property
    def nmodes(self) -> int:
        return len(self.dims)

    @property
    def smax(self) -> int:
        """Uniform physical slot count (max over per-mode padded sizes)."""
        return max(s.padded_nnz for s in self.statics)

    @property
    def rmax(self) -> int:
        """Max relabeled-row count over modes (scan output row padding)."""
        return max(s.relabeled_rows for s in self.statics)

    @property
    def imax(self) -> int:
        return max(self.dims)

    def aux_key(self):
        """Hashable key identifying every static property of this state."""
        return (self.mode, self.dims, self.statics, self.config)

    def replace(self, **kw) -> "EngineState":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------- pytree
    def tree_flatten(self):
        children = (self.val, self.idx, self.alpha, self.relabel,
                    self.sched)
        aux = (self.mode, self.dims, self.statics, self.config)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        val, idx, alpha, relabel, sched = children
        mode, dims, statics, config = aux
        return cls(val=val, idx=idx, alpha=alpha, relabel=tuple(relabel),
                   sched=tuple(sched), mode=mode, dims=dims,
                   statics=statics, config=config)


__all__ = ["EngineState", "ModeStatic", "ModeSched", "mode_static_from_plan"]
