"""Declarative plan/backend factory: ``PlanSpec`` / ``PlanSpace`` /
``make_engine``.

Before this layer, every callsite hand-assembled an ``ExecutionConfig``,
chose a ``DistConfig`` exchange, decided whether to pre-build a
``FlycooTensor`` (and with which kappa rounding for sharding), and plumbed
the knobs through ``engine.init`` / ``dist.shard_state`` separately. The
factory collapses that into one declarative object:

``PlanSpec``
    One *point* in the plan space — every searchable knob (block size P,
    block schedule, kappa policy, VMEM budget, dedup, fused remap, backend,
    distributed exchange) in a single frozen dataclass. ``to_config()`` /
    ``to_dist_config()`` derive the engine- and distribution-layer configs.

``PlanSpace``
    A *set* of candidate values per searchable dimension (the autotuner's
    search domain). ``specs()`` enumerates the cartesian product as
    ``PlanSpec`` points; skewed-irrelevant combinations (e.g. dedup under
    the ``rect`` schedule, where no dedup tables exist) are canonicalized
    away so the space has no duplicate semantics.

``make_engine``
    The single entry point: COO triple or prebuilt tensor + spec ->
    device-resident state, going through the sparsity-signature plan cache
    (:mod:`repro.core.plancache`) so streaming re-inits skip ``plan_mode``,
    and through ``dist.shard_state`` when a mesh is given (per-mode kappa
    rounded to the device count via ``ExecutionConfig.kappa_for``).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Sequence

from repro.resilience import chaos as _chaos
from repro.resilience.ladder import (classify, record_degradation,
                                     resolve_policy)

from .config import SCHEDULES, ExecutionConfig
from .dist import EXCHANGES, DistConfig, shard_state

# Searchable spec fields, in enumeration order (PlanSpace dimensions).
SPACE_DIMS = ("backend", "schedule", "block_p", "rows_pp",
              "vmem_budget_bytes", "dedup", "fuse_remap", "exchange",
              "residency", "chunk_nnz")


@dataclasses.dataclass(frozen=True)
class PlanSpec:
    """One point in the plan space (frozen — usable as a dict/jit key).

    Engine knobs mirror :class:`~repro.engine.config.ExecutionConfig`;
    ``exchange`` is the distributed remap exchange schedule (consumed only
    when :func:`make_engine` is given a mesh).
    """

    backend: str = "xla"
    schedule: str = "compact"
    block_p: int = 128
    kappa_policy: str = "vmem"
    kappa: int | None = None
    rows_pp: int | None = None
    vmem_budget_bytes: int | None = None
    rank_hint: int = 32
    dedup: bool = True
    fuse_remap: bool = True
    interpret: bool | None = None
    exchange: str = "permute"
    residency: str = "auto"
    chunk_nnz: int | None = None
    device_budget_bytes: int | None = None
    stream_ring: int = 2
    #: degradation-ladder default for engines built from this spec:
    #: ``None`` defers to ``make_engine(ladder=...)`` and the ambient
    #: ``REPRO_LADDER`` policy; ``True``/``False`` force it per spec.
    ladder: bool | None = None

    def __post_init__(self):
        if self.exchange not in EXCHANGES:
            raise ValueError(
                f"exchange {self.exchange!r} not in {EXCHANGES}")
        # delegate the remaining validation to ExecutionConfig
        self.to_config()

    def to_config(self) -> ExecutionConfig:
        return ExecutionConfig(
            backend=self.backend, interpret=self.interpret,
            block_p=self.block_p, kappa_policy=self.kappa_policy,
            kappa=self.kappa, rows_pp=self.rows_pp,
            fuse_remap=self.fuse_remap, dedup=self.dedup,
            vmem_budget_bytes=self.vmem_budget_bytes,
            rank_hint=self.rank_hint, schedule=self.schedule,
            residency=self.residency, chunk_nnz=self.chunk_nnz,
            device_budget_bytes=self.device_budget_bytes,
            stream_ring=self.stream_ring)

    def to_dist_config(self, data_axis: str = "data") -> DistConfig:
        return DistConfig(data_axis=data_axis, exchange=self.exchange)

    def canonical(self) -> "PlanSpec":
        """Collapse knob settings with identical semantics to one point:
        dedup only exists for needs_dedup backends under ``compact``;
        fused remap only for backends exposing ``fused_remap``; streaming
        knobs only for the streaming tier; and the VMEM budget is made
        explicit from ``device_budget_bytes`` (``derive_vmem_budget``)
        when only the device budget is given — ONE budget source of truth,
        so residency, ``rows_pp``, and chunking can never silently
        contradict each other."""
        from .backends import get_backend
        from .config import derive_vmem_budget

        backend = get_backend(self.backend)
        spec = self
        if self.schedule != "compact" or \
                not getattr(backend, "needs_dedup", False):
            spec = dataclasses.replace(spec, dedup=True)
        if getattr(backend, "fused_remap", None) is None:
            spec = dataclasses.replace(spec, fuse_remap=True)
        if spec.vmem_budget_bytes is None and \
                spec.device_budget_bytes is not None:
            spec = dataclasses.replace(
                spec,
                vmem_budget_bytes=derive_vmem_budget(
                    spec.device_budget_bytes))
        if spec.residency == "auto" and spec.device_budget_bytes is None:
            # auto without a budget can only ever resolve to full
            spec = dataclasses.replace(spec, residency="full")
        if spec.residency == "full":
            spec = dataclasses.replace(spec, chunk_nnz=None, stream_ring=2)
        return spec


@dataclasses.dataclass(frozen=True)
class PlanSpace:
    """Candidate values per searchable knob (the autotuner's domain).

    Each field lists the values that dimension may take; ``base`` carries
    the non-searched remainder (kappa policy, rank hint, interpret mode).
    """

    backend: tuple = ("pallas_fused",)
    schedule: tuple = SCHEDULES
    block_p: tuple = (64, 128, 256)
    rows_pp: tuple = (None,)
    vmem_budget_bytes: tuple = (None,)
    dedup: tuple = (True, False)
    fuse_remap: tuple = (True,)
    exchange: tuple = ("permute",)
    residency: tuple = ("auto",)
    chunk_nnz: tuple = (None,)
    base: PlanSpec = PlanSpec()

    def specs(self) -> tuple[PlanSpec, ...]:
        """The cartesian product as canonicalized, deduplicated PlanSpecs
        (deterministic enumeration order — the autotuner's tie-break)."""
        seen: dict[PlanSpec, None] = {}
        axes = [getattr(self, f) for f in SPACE_DIMS]
        for combo in itertools.product(*axes):
            spec = dataclasses.replace(
                self.base, **dict(zip(SPACE_DIMS, combo))).canonical()
            seen.setdefault(spec, None)
        return tuple(seen)

    @property
    def size(self) -> int:
        return len(self.specs())


def make_engine(tensor, spec: PlanSpec | None = None, *,
                start_mode: int = 0, cache=None, mesh=None,
                data_axis: str = "data", ladder=None, resume=None):
    """Build a device-resident engine from one declarative ``spec``.

    ``tensor`` is a raw COO triple ``(indices, values, dims)`` or a
    prebuilt :class:`~repro.core.flycoo.FlycooTensor` (its plans win).
    ``cache`` is a :class:`repro.core.plancache.PlanCache` (``None`` uses
    the process-wide default; pass ``cache=False`` to force cold planning).
    With ``mesh``, the state is sharded via ``dist.shard_state`` under the
    spec's exchange schedule, and raw COO input is planned with per-mode
    kappa rounded to the device count.

    The spec's ``residency`` picks the memory tier: ``"full"`` returns a
    device-resident ``EngineState`` (or ``DistState`` with a mesh),
    ``"stream"`` the out-of-core ``StreamState``
    (:mod:`repro.engine.stream`), and ``"auto"`` compares the resident
    footprint (:func:`repro.engine.stream.resident_bytes`) against
    ``device_budget_bytes`` — tensors that don't fit stream, tensors that
    do stay resident.

    ``ladder`` (``True`` / :class:`repro.resilience.LadderPolicy`)
    enables the residency rung of the degradation ladder: if placing the
    *full* layout OOMs on a single device, the factory falls back to the
    streaming tier (recorded as a ``resilience_degradations`` counter +
    span — never silent) instead of dying. ``ladder=None`` defers first
    to ``spec.ladder``, then to the ambient ``REPRO_LADDER`` env policy
    (:func:`repro.resilience.ladder.from_env`) — fleet defaults need no
    code changes.

    ``resume`` (a :class:`repro.resilience.Snapshot`) is validated
    against this engine's problem before any state is built: the snapshot
    must carry one factor per mode with matching row counts, so a resumed
    ALS loop can never silently continue from a different tensor's
    factors. (The ALS entry points additionally match the full content
    fingerprint — this is the structural floor.)
    """
    from repro.core.flycoo import FlycooTensor
    from repro.core.plancache import DEFAULT_CACHE
    from repro.obs.trace import span

    from .api import init
    from .stream import resident_bytes, stream_init

    spec = (spec or PlanSpec()).canonical()
    config = spec.to_config()
    if ladder is None:
        ladder = spec.ladder
    policy = resolve_policy(ladder)
    if cache is None:
        cache = DEFAULT_CACHE
    elif cache is False:
        cache = None

    if resume is not None:
        dims = (tensor.dims if isinstance(tensor, FlycooTensor)
                else tuple(int(d) for d in tensor[2]))
        shapes = tuple(int(f.shape[0]) for f in resume.factors)
        if shapes != tuple(dims):
            raise ValueError(
                f"snapshot {resume.path!r} does not match this problem: "
                f"factor rows {shapes} != dims {tuple(dims)}")

    with span("factory.make_engine", backend=spec.backend,
              schedule=spec.schedule, residency=spec.residency,
              sharded=mesh is not None) as sp:
        if mesh is not None and not isinstance(tensor, FlycooTensor):
            # raw COO + mesh: per-mode kappa rounded to the device count so
            # every device owns an equal, contiguous run of partitions
            indices, values, dims = tensor
            n_dev = int(mesh.shape[data_axis])
            kappas = [config.kappa_for(int(d), n_dev) for d in dims]
            builder = cache.get_tensor if cache is not None else None
            if builder is None:
                from repro.core.flycoo import build_flycoo as builder
            tensor = builder(indices, values, dims, kappa=kappas,
                             rows_pp=config.resolve_rows_pp(),
                             block_p=config.block_p,
                             schedule=config.schedule)

        residency = spec.residency
        if residency == "auto":
            # plans are needed to size the resident footprint; build once
            # through the cache and hand the planned tensor down either tier
            from .api import _as_flycoo

            tensor = _as_flycoo(tensor, config, cache=cache)
            over = (config.device_budget_bytes is not None
                    and resident_bytes(tensor, config)
                    > config.device_budget_bytes)
            residency = "stream" if (over and mesh is None) else "full"
        sp.set("resolved_residency", residency)

        if residency == "full":
            cz = _chaos.active()
            try:
                if cz is not None:
                    cz.on_resident_init()
                state = init(tensor, config, start_mode, cache=cache)
            except Exception as exc:
                # residency rung of the degradation ladder: the full
                # layout doesn't fit -> stream it (single-device only;
                # bitwise-identical results, see engine.stream)
                if (policy is None or mesh is not None
                        or classify(exc) != "oom"):
                    raise
                record_degradation("oom", "full", "stream",
                                   site="factory.residency")
                sp.set("resolved_residency", "stream")
                residency = "stream"
            else:
                if mesh is None:
                    return state
                return shard_state(state, mesh,
                                   spec.to_dist_config(data_axis))

        if mesh is not None:
            raise ValueError(
                "residency='stream' is a single-device tier; drop mesh "
                "or use residency='full'")
        return stream_init(tensor, config, start_mode, cache=cache)


__all__ = ["PlanSpec", "PlanSpace", "make_engine", "SPACE_DIMS"]
