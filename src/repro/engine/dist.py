"""Distributed spMTTKRP engine: sharded ``EngineState`` under ``shard_map``.

Cluster-scope version of the paper's Observation 2 on top of the functional
engine (:mod:`repro.engine.api`): partitions — and hence the output rows
they own — are dealt to devices along the mesh's ``data`` axis, so the
elementwise computation needs NO cross-device reduction; each device
segment-sums into rows it exclusively owns. The rank dimension may
optionally shard over ``model`` (MTTKRP is embarrassingly parallel over
rank).

The dynamic remap (Alg. 3) becomes a *static* cross-device permutation:
which element moves from which device to which is fixed by the FLYCOO
plans, so the exchange is precomputed host-side into an
:class:`ExchangeSchedule` and executed as a ``collective_permute``
round-robin — hop ``h`` sends a bounded buffer from every device ``k`` to
device ``(k + h) % n_dev`` — instead of the baseline ``all_gather`` of the
full element list (kept as ``DistConfig(exchange="all_gather")`` for
comparison). AMPED (arXiv:2507.15121) and load-balanced spMTTKRP
(arXiv:1904.03329) both identify this exchange, not the compute, as the
multi-GPU bottleneck.

Sharded layout numbering
------------------------
A :class:`DistState` stores the layout in *device-major* slot numbering:
device ``k`` owns global slots ``[k * S_loc, (k+1) * S_loc)`` where
``S_loc = max_d S_d_loc``, and within a device the mode-``d`` layout
occupies the first ``S_d_loc`` local slots — its ``kappa_d / n_dev``
contiguous partitions' blocks, laid out by the mode's block schedule.
Under the ``rect`` schedule ``S_d_loc = S_d / n_dev`` exactly; under
``compact`` each device's real block count differs (partitions are
nnz-balanced, not block-identical), so ``S_d_loc`` is the max device's
block count and shorter devices carry trailing all-pad blocks (dead
slots, descriptor repeating the last real partition). This requires every
mode's ``kappa`` to be a multiple of ``n_dev`` — build tensors with
:func:`repro.core.distributed.build_sharded_flycoo` or pick partition
counts via :meth:`ExecutionConfig.kappa_for`.

Public surface:

  DistConfig                            frozen mesh-axis/exchange policy
  shard_state(state, mesh[, dist])      EngineState -> DistState (host, once)
  dist_mttkrp(dstate, factors)          one mode + exchange, one dispatch
  dist_all_modes(dstate, factors)       whole rotation: ONE jitted lax.scan
                                        inside shard_map (fold hook as in
                                        ``engine.all_modes`` -> distributed
                                        CPD-ALS sweeps are single programs)
  schedule_for_plans / exchange_bytes   host-side schedule + traffic model
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.obs.metrics import gauge as _obs_gauge
from repro.obs.trace import span
from repro.resilience import chaos as _chaos
from repro.sharding import ShardingCtx

from .api import _JIT_CACHE, DISPATCH_COUNTS, TRACE_COUNTS, FoldFn
from .backends import compute_lrow, get_backend
from .config import ExecutionConfig
from .state import EngineState, ModeSched, ModeStatic

try:  # jax >= 0.6 spells it jax.shard_map
    from jax import shard_map as _shard_map

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
except ImportError:  # pragma: no cover - depends on jax version
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map_old(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=False)


EXCHANGES = ("permute", "all_gather")


@dataclasses.dataclass(frozen=True)
class DistConfig:
    """Static distribution policy (hashable; part of the jit cache key).

    Attributes:
      data_axis: mesh axis partitions/rows/slots shard over.
      model_axis: optional mesh axis the factor rank dim shards over
        (incompatible with a ``fold`` hook — grams need the full rank).
      exchange: remap exchange strategy — ``"permute"`` runs the
        precomputed collective_permute schedule, ``"all_gather"`` the
        baseline full-element-list gather + scatter-slice.
      pad_hop: per-hop buffer slot counts round up to this multiple.
    """

    data_axis: str = "data"
    model_axis: str | None = None
    exchange: str = "permute"
    pad_hop: int = 8

    def __post_init__(self):
        if self.exchange not in EXCHANGES:
            raise ValueError(
                f"exchange {self.exchange!r} not in {EXCHANGES}")
        if self.pad_hop < 1:
            raise ValueError("pad_hop must be >= 1")


# --------------------------------------------------------------------------
# Static exchange schedule (host-side, derived from the FLYCOO plans).
# --------------------------------------------------------------------------
class ExchangeSchedule(NamedTuple):
    """Per-(mode -> next mode) transition, per round-robin hop, the padded
    slot capacity of the send buffer. ``hops[d][h-1]`` bounds how many
    elements any device sends to its ``+h``-neighbour while remapping the
    mode-``d`` layout into mode ``d+1``. Static truth derived from the
    plans — the traced exchange cannot overflow it."""

    n_dev: int
    hops: tuple[tuple[int, ...], ...]

    def permute_slots(self, d: int) -> int:
        """Total send-buffer slots one device uses for transition ``d``."""
        return sum(self.hops[d])


def row_bytes(nmodes: int) -> int:
    """Wire bytes per element row: val f32 + idx i32*N + alpha i32*N."""
    return 4 * (1 + 2 * nmodes)


def _schedule_from_devs(devs_by_mode: Sequence[np.ndarray], n_dev: int,
                        pad_hop: int) -> ExchangeSchedule:
    """Build the schedule from each element's owning device in every mode."""
    n = len(devs_by_mode)
    hops = []
    for d in range(n):
        src, dst = devs_by_mode[d], devs_by_mode[(d + 1) % n]
        counts = np.bincount(src * n_dev + dst,
                             minlength=n_dev * n_dev).reshape(n_dev, n_dev)
        per_hop = []
        for h in range(1, n_dev):
            cap = int(max(counts[k, (k + h) % n_dev] for k in range(n_dev)))
            if cap:
                cap = ((cap + pad_hop - 1) // pad_hop) * pad_hop
            per_hop.append(cap)
        hops.append(tuple(per_hop))
    return ExchangeSchedule(n_dev=n_dev, hops=tuple(hops))


def element_devices(plan, n_dev: int) -> np.ndarray:
    """(nnz,) owning device per element for a ``ModePlan`` sharded over
    ``n_dev`` devices: device ``k`` owns partitions
    ``[k*kappa/n_dev, (k+1)*kappa/n_dev)``. Schedule-agnostic — the
    partition comes from the block->partition descriptor, which under
    ``rect`` degenerates to the fixed slot stride."""
    if plan.kappa % n_dev != 0:
        raise ValueError(
            f"mode-{plan.mode} kappa {plan.kappa} not divisible by "
            f"n_dev {n_dev}; build with kappa_for / build_sharded_flycoo")
    part = plan.block_part[plan.slot_of_elem // plan.block_p]
    return (part // (plan.kappa // n_dev)).astype(np.int64)


def schedule_for_plans(plans, n_dev: int,
                       pad_hop: int = 8) -> ExchangeSchedule:
    """Exchange schedule for a tensor's ``ModePlan`` list (host-only; needs
    no devices — used by benchmarks to model traffic at any scale)."""
    return _schedule_from_devs([element_devices(p, n_dev) for p in plans],
                               n_dev, pad_hop)


# --------------------------------------------------------------------------
# Device-major block geometry (host-side, schedule-aware).
# --------------------------------------------------------------------------
def _block_geometry(static: ModeStatic, bpart: np.ndarray, n_dev: int):
    """Per-mode block geometry under device-major sharding.

    Returns ``(kappa_loc, blocks_per_dev, dev_first_block, nblocks_loc)``:
    device ``k`` owns partitions ``[k*kappa_loc, (k+1)*kappa_loc)`` whose
    blocks are contiguous (partition-major layout) starting at global
    block ``dev_first_block[k]``; every device's local layout is padded to
    ``nblocks_loc = max blocks_per_dev`` blocks.
    """
    kappa_loc = static.kappa // n_dev
    part_blocks = np.bincount(bpart, minlength=static.kappa)
    blocks_per_dev = part_blocks.reshape(n_dev, kappa_loc).sum(axis=1)
    dev_first_block = np.concatenate([[0], np.cumsum(blocks_per_dev)])[:-1]
    return kappa_loc, blocks_per_dev, dev_first_block, int(
        blocks_per_dev.max())


def _local_static(static: ModeStatic, nblocks_loc: int,
                  n_dev: int) -> ModeStatic:
    """The per-device ``ModeStatic`` (kappa_loc partitions, padded-uniform
    local block count)."""
    return ModeStatic(kappa=static.kappa // n_dev, rows_pp=static.rows_pp,
                      blocks_pp=static.blocks_pp, block_p=static.block_p,
                      dim=static.dim, nblocks=nblocks_loc,
                      schedule=static.schedule)


def _local_sched(ms: ModeSched, static: ModeStatic, geom,
                 n_dev: int) -> ModeSched:
    """Device-major re-layout of one mode's schedule tables: each device's
    block run is sliced out and padded to the uniform local block count.
    Pad blocks repeat the last real partition id (so the descriptor stays
    nondecreasing and never re-triggers a tile init) and carry zeroed
    dedup tables (``nuniq = 0`` -> the kernel issues no DMAs for them)."""
    kappa_loc, blocks_per_dev, dev_first_block, nblocks_loc = geom
    p = static.block_p
    sloc = nblocks_loc * p
    bp = np.asarray(ms.bpart)
    lbp = np.empty((n_dev, nblocks_loc), dtype=np.int32)
    for k in range(n_dev):
        nb = int(blocks_per_dev[k])
        seg = bp[dev_first_block[k]:dev_first_block[k] + nb] - k * kappa_loc
        lbp[k, :nb] = seg
        lbp[k, nb:] = seg[-1] if nb else kappa_loc - 1
    out = {"bpart": jnp.asarray(lbp.reshape(-1))}
    if ms.uidx is not None:
        nm1 = ms.uidx.shape[0]
        uidx = np.asarray(ms.uidx)
        upos = np.asarray(ms.upos)
        nuniq = np.asarray(ms.nuniq)
        luidx = np.zeros((nm1, n_dev * sloc), dtype=np.int32)
        lupos = np.zeros((n_dev * sloc, nm1), dtype=np.int32)
        lnuniq = np.zeros((nm1, n_dev * nblocks_loc), dtype=np.int32)
        for k in range(n_dev):
            nb = int(blocks_per_dev[k])
            g0 = int(dev_first_block[k])
            luidx[:, k * sloc:k * sloc + nb * p] = \
                uidx[:, g0 * p:(g0 + nb) * p]
            lupos[k * sloc:k * sloc + nb * p] = upos[g0 * p:(g0 + nb) * p]
            lnuniq[:, k * nblocks_loc:k * nblocks_loc + nb] = \
                nuniq[:, g0:g0 + nb]
        out.update(uidx=jnp.asarray(luidx), upos=jnp.asarray(lupos),
                   nuniq=jnp.asarray(lnuniq))
    return ModeSched(**out)


def exchange_bytes(schedule: ExchangeSchedule, nmodes: int,
                   slocs: Sequence[int]) -> list[dict]:
    """Per-device wire traffic of one full rotation, per mode transition:
    the collective_permute schedule vs the all_gather baseline. ``slocs``
    is the per-mode local padded slot count ``S_d / n_dev`` — the baseline
    gathers each remote device's mode-``d`` element list, so transition
    ``d`` ships ``(n_dev - 1) * slocs[d]`` rows per device."""
    rb = row_bytes(nmodes)
    out = []
    for d in range(len(schedule.hops)):
        out.append({
            "mode": d,
            "permute_bytes": schedule.permute_slots(d) * rb,
            "all_gather_bytes": (schedule.n_dev - 1) * slocs[d] * rb,
        })
    return out


# --------------------------------------------------------------------------
# DistState: the sharded EngineState.
# --------------------------------------------------------------------------
@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class DistState:
    """Immutable sharded engine state (device-major slot numbering).

    Array leaves mirror :class:`~repro.engine.state.EngineState` but hold
    *global* arrays placed over the mesh: ``val (n_dev*S_loc,)``,
    ``idx/alpha (n_dev*S_loc, N)`` sharded along the ``data`` axis, the
    replicated per-mode ``relabel`` tables, and the per-mode ``sched``
    block-schedule tables in device-major layout (sharded so every device
    holds its local descriptor/dedup slices). ``alpha`` entries are in the
    device-major dist numbering (see module docstring), so remap
    destinations encode both target device and target local slot.
    ``lstatics`` holds each mode's *per-device* plan constants
    (``kappa/n_dev`` partitions, padded-uniform local block count).
    """

    val: jax.Array
    idx: jax.Array
    alpha: jax.Array
    relabel: tuple[jax.Array, ...]
    sched: tuple[ModeSched, ...]
    mode: int
    dims: tuple[int, ...]
    statics: tuple[ModeStatic, ...]
    lstatics: tuple[ModeStatic, ...]
    config: ExecutionConfig
    dist: DistConfig
    n_dev: int
    schedule: ExchangeSchedule
    mesh: Mesh

    # ------------------------------------------------------------ derived
    @property
    def nmodes(self) -> int:
        return len(self.dims)

    @property
    def slocs(self) -> tuple[int, ...]:
        """Per-mode local padded slot counts ``S_d_loc``."""
        return tuple(s.padded_nnz for s in self.lstatics)

    @property
    def smax_loc(self) -> int:
        """Per-device slot count (max over per-mode local padded sizes)."""
        return max(self.slocs)

    @property
    def imax(self) -> int:
        return max(self.dims)

    def aux_key(self):
        return (self.mode, self.dims, self.statics, self.lstatics,
                self.config, self.dist, self.n_dev, self.schedule,
                self.mesh)

    def replace(self, **kw) -> "DistState":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------- pytree
    def tree_flatten(self):
        children = (self.val, self.idx, self.alpha, self.relabel,
                    self.sched)
        # aux IS the jit-cache key: one definition, no drift between what
        # forces a retrace and what keys the _JIT_CACHE programs.
        return children, self.aux_key()

    @classmethod
    def tree_unflatten(cls, aux, children):
        val, idx, alpha, relabel, sched = children
        (mode, dims, statics, lstatics, config, dist, n_dev, schedule,
         mesh) = aux
        return cls(val=val, idx=idx, alpha=alpha, relabel=tuple(relabel),
                   sched=tuple(sched), mode=mode, dims=dims,
                   statics=statics, lstatics=lstatics, config=config,
                   dist=dist, n_dev=n_dev, schedule=schedule, mesh=mesh)


# --------------------------------------------------------------------------
# shard_state: place an EngineState over the mesh.
# --------------------------------------------------------------------------
def shard_state(state: EngineState, mesh: Mesh | ShardingCtx,
                dist: DistConfig | None = None) -> DistState:
    """Shard a single-device :class:`EngineState` over ``mesh``'s data axis.

    ``mesh`` may be a raw :class:`jax.sharding.Mesh` or a
    :class:`repro.sharding.ShardingCtx` — with a ctx (and no explicit
    ``dist``) the data/model axes follow the ctx's dp/tp convention.

    Renumbers every mode layout into device-major slots, precomputes the
    collective_permute :class:`ExchangeSchedule` from the alpha tables, and
    ``device_put``s the arrays with the matching ``NamedSharding``s.
    Requires every mode's ``kappa`` to be a multiple of the data-axis size
    (see :meth:`ExecutionConfig.kappa_for`).
    """
    if isinstance(mesh, ShardingCtx):
        ctx, mesh = mesh, mesh.mesh
        if dist is None:
            dist = DistConfig(data_axis=ctx.data_axis,
                              model_axis=ctx.tp_axis)
    dist = dist or DistConfig()
    if dist.data_axis not in mesh.axis_names:
        raise ValueError(f"mesh has no axis {dist.data_axis!r}: "
                         f"{mesh.axis_names}")
    n_dev = mesh.shape[dist.data_axis]
    for s in state.statics:
        if s.kappa % n_dev != 0:
            raise ValueError(
                f"kappa {s.kappa} not divisible by n_dev {n_dev}; build "
                "the tensor with ExecutionConfig.kappa_for(dim, n_dev) "
                "(e.g. via core.distributed.build_sharded_flycoo)")

    n, m0 = state.nmodes, state.mode
    with span("dist.shard_state", n_dev=int(n_dev), nmodes=n):
        statics = state.statics
        with span("dist.renumber"):
            geoms = [_block_geometry(statics[d],
                                     np.asarray(state.sched[d].bpart),
                                     n_dev) for d in range(n)]
            lstatics = tuple(_local_static(statics[d], geoms[d][3], n_dev)
                             for d in range(n))
            slocs = [ls.padded_nnz for ls in lstatics]
            smax_loc = max(slocs)
            total = n_dev * smax_loc

            alpha = np.asarray(state.alpha)
            alive = alpha[:, m0] >= 0
            slots = alpha[alive].astype(np.int64)   # (nnz, n) per-mode slots
            # device-major renumbering: each device's contiguous block run
            # starts at local slot 0 ->
            # dslot = dev * smax_loc + (slot - first slot of dev)
            dslots = np.empty_like(slots)
            devs = np.empty_like(slots)
            for d in range(n):
                _, blocks_per_dev, dev_first_block, _ = geoms[d]
                p = statics[d].block_p
                dev_of_block = np.repeat(np.arange(n_dev), blocks_per_dev)
                dev = dev_of_block[slots[:, d] // p]
                dslots[:, d] = (dev * smax_loc + slots[:, d]
                                - dev_first_block[dev] * p)
                devs[:, d] = dev
        with span("dist.exchange_schedule"):
            schedule = _schedule_from_devs([devs[:, d] for d in range(n)],
                                           n_dev, dist.pad_hop)
            wire = _obs_gauge("dist_exchange_bytes",
                             "permute wire bytes per mode transition")
            for hop in exchange_bytes(schedule, n, slocs):
                wire.set(f"mode{hop['mode']}", hop["permute_bytes"])

        pos = dslots[:, m0]
        val = np.zeros(total, dtype=np.float32)
        idx = np.zeros((total, n), dtype=np.int32)
        nalpha = np.full((total, n), -1, dtype=np.int32)
        val[pos] = np.asarray(state.val)[alive]
        idx[pos] = np.asarray(state.idx)[alive]
        nalpha[pos] = dslots.astype(np.int32)

        da = dist.data_axis
        sh1 = NamedSharding(mesh, P(da))
        sh2 = NamedSharding(mesh, P(da, None))
        rep = NamedSharding(mesh, P())
        with span("dist.device_place"):
            sched = tuple(
                _place_sched(_local_sched(state.sched[d], statics[d],
                                          geoms[d], n_dev), mesh, da)
                for d in range(n))
            return DistState(
                val=jax.device_put(jnp.asarray(val), sh1),
                idx=jax.device_put(jnp.asarray(idx), sh2),
                alpha=jax.device_put(jnp.asarray(nalpha), sh2),
                relabel=tuple(jax.device_put(r, rep)
                              for r in state.relabel),
                sched=sched,
                mode=m0, dims=state.dims, statics=statics,
                lstatics=lstatics, config=state.config, dist=dist,
                n_dev=n_dev, schedule=schedule, mesh=mesh)


def _sched_pspecs(ms: ModeSched, da: str) -> ModeSched:
    """Partition specs matching one mode's device-major schedule tables."""
    return ModeSched(
        bpart=P(da),
        uidx=None if ms.uidx is None else P(None, da),
        upos=None if ms.upos is None else P(da, None),
        nuniq=None if ms.nuniq is None else P(None, da))


def _place_sched(ms: ModeSched, mesh: Mesh, da: str) -> ModeSched:
    specs = _sched_pspecs(ms, da)
    return ModeSched(*(None if x is None
                       else jax.device_put(x, NamedSharding(mesh, s))
                       for x, s in zip(ms, specs)))


# --------------------------------------------------------------------------
# Per-device exchange kernels (run inside shard_map).
# --------------------------------------------------------------------------
def _exchange_permute(v, ix, al, alive, *, nxt, hops, smax_loc, n_dev, da,
                      nmodes):
    """Static round-robin: hop ``h`` ships a bounded buffer to the ``+h``
    neighbour via collective_permute; local moves scatter directly."""
    me = lax.axis_index(da)
    dstg = al[:, nxt]                       # global dist slot (-1 dead)
    dst_dev = dstg // smax_loc              # floor div: dead -> -1
    mine = alive & (dst_dev == me)
    dst = jnp.where(mine, dstg % smax_loc, smax_loc)
    nval = jnp.zeros((smax_loc,), v.dtype).at[dst].set(
        v, mode="drop", unique_indices=True)
    nidx = jnp.zeros((smax_loc, nmodes), ix.dtype).at[dst].set(
        ix, mode="drop", unique_indices=True)
    nalpha = jnp.full((smax_loc, nmodes), -1, jnp.int32).at[dst].set(
        al, mode="drop", unique_indices=True)

    for h in range(1, n_dev):
        cap = hops[h - 1]
        if cap == 0:    # statically empty hop: no collective at all
            continue
        sel = alive & (dst_dev == (me + h) % n_dev)
        # pack outgoing elements densely; schedule guarantees fit <= cap
        bpos = jnp.where(sel, jnp.cumsum(sel) - 1, cap)
        bval = jnp.zeros((cap,), v.dtype).at[bpos].set(v, mode="drop")
        bidx = jnp.zeros((cap, nmodes), ix.dtype).at[bpos].set(
            ix, mode="drop")
        balpha = jnp.full((cap, nmodes), -1, jnp.int32).at[bpos].set(
            al, mode="drop")
        perm = [(k, (k + h) % n_dev) for k in range(n_dev)]
        rval = lax.ppermute(bval, da, perm)
        ridx = lax.ppermute(bidx, da, perm)
        ralpha = lax.ppermute(balpha, da, perm)
        rdst = ralpha[:, nxt]               # arrivals all target me
        rloc = jnp.where(rdst >= 0, rdst % smax_loc, smax_loc)
        nval = nval.at[rloc].set(rval, mode="drop", unique_indices=True)
        nidx = nidx.at[rloc].set(ridx, mode="drop", unique_indices=True)
        nalpha = nalpha.at[rloc].set(ralpha, mode="drop",
                                     unique_indices=True)
    return nval, nidx, nalpha


def _exchange_all_gather(v, ix, al, alive, *, d, nxt, smax_loc, n_dev, da,
                         nmodes):
    """Baseline (pre-engine ``DistributedMTTKRP``): gather the FULL element
    list on every device, scatter into the whole next layout, keep the
    local slice. O(n_dev * nnz) wire traffic per transition."""
    del alive
    total = n_dev * smax_loc
    vg = lax.all_gather(v, da, tiled=True)
    ig = lax.all_gather(ix, da, tiled=True)
    ag = lax.all_gather(al, da, tiled=True)
    alive_g = ag[:, d] >= 0
    dst = jnp.where(alive_g, ag[:, nxt], total)
    nval = jnp.zeros((total,), v.dtype).at[dst].set(
        vg, mode="drop", unique_indices=True)
    nidx = jnp.zeros((total, nmodes), ix.dtype).at[dst].set(
        ig, mode="drop", unique_indices=True)
    nalpha = jnp.full((total, nmodes), -1, jnp.int32).at[dst].set(
        ag, mode="drop", unique_indices=True)
    me = lax.axis_index(da)
    sl = lambda a: lax.dynamic_slice_in_dim(  # noqa: E731
        a, me * smax_loc, smax_loc, axis=0)
    return sl(nval), sl(nidx), sl(nalpha)


# --------------------------------------------------------------------------
# One mode on one device: local EC + output gather + remap exchange.
# --------------------------------------------------------------------------
def _dist_mode_branch(d: int, *, statics: Sequence[ModeStatic],
                      lstatics: Sequence[ModeStatic], n_dev: int,
                      smax_loc: int, schedule: ExchangeSchedule,
                      config: ExecutionConfig, dist: DistConfig,
                      fold: FoldFn | None, pad_out_to: int | None):
    """Traced per-device step for (static) mode ``d``; same contract as the
    single-device ``engine.api._mode_branch`` but over local shards."""
    s = statics[d]
    n = len(statics)
    nxt = (d + 1) % n
    lplan = lstatics[d]
    sloc = lplan.padded_nnz
    backend = get_backend(config)
    da = dist.data_axis

    def step(layout3, relabels, sched, factors, carry):
        val, idx, alpha = layout3           # local (smax_loc, ...) shards
        v, ix, al = val[:sloc], idx[:sloc], alpha[:sloc]
        alive = al[:, d] >= 0
        # EC over owned partitions only (Obs. 2: rows owned exclusively,
        # so the segment-sum needs no cross-device reduction). Backends see
        # the exact same contract as the single-device scan; fusing
        # backends (``pallas_fused``) run their plain-EC entry here — the
        # remap is the cross-device exchange below, not a local scatter —
        # so the in-kernel gather fusion (incl. the compact schedule's
        # in-block dedup) still applies per shard.
        lrow = compute_lrow(ix[:, d], relabels[d], s.rows_pp, alive)
        out_rel_loc = backend({"val": v, "idx": ix, "alpha": al,
                               "lrow": lrow, **sched[d]._asdict()},
                              tuple(factors), d, plan=lplan, config=config)
        # Devices own contiguous relabeled-row ranges (kappa % n_dev == 0),
        # so a tiled output gather IS the global relabeled result. This is
        # rows x R — small — not the element list.
        out_rel = lax.all_gather(out_rel_loc, da, tiled=True)
        out = jnp.take(out_rel, relabels[d], axis=0)
        if fold is not None:
            factors, carry = fold(d, out, factors, carry)
        if pad_out_to is not None:
            out = jnp.pad(out, ((0, pad_out_to - s.dim), (0, 0)))

        if dist.exchange == "permute":
            nl = _exchange_permute(v, ix, al, alive, nxt=nxt,
                                   hops=schedule.hops[d],
                                   smax_loc=smax_loc, n_dev=n_dev, da=da,
                                   nmodes=n)
        else:
            nl = _exchange_all_gather(v, ix, al, alive, d=d, nxt=nxt,
                                      smax_loc=smax_loc, n_dev=n_dev,
                                      da=da, nmodes=n)
        return nl, out, factors, carry

    return step


# --------------------------------------------------------------------------
# Program builders (shard_map-wrapped; pre-jit for lowering inspection).
# --------------------------------------------------------------------------
def _specs(dstate: DistState, fold: FoldFn | None):
    da, ma = dstate.dist.data_axis, dstate.dist.model_axis
    if fold is not None and ma is not None:
        raise ValueError("fold needs the full rank on every device; use "
                         "model_axis=None when folding (e.g. CPD-ALS)")
    layout_specs = (P(da), P(da, None), P(da, None))
    fac_spec = P(None, ma) if ma else P(None, None)
    sched_specs = tuple(_sched_pspecs(ms, da) for ms in dstate.sched)
    in_specs = (layout_specs, P(), sched_specs, fac_spec, P())
    return layout_specs, fac_spec, in_specs


def _build_dist_scan(dstate: DistState, fold: FoldFn | None):
    """The whole mode rotation as one ``lax.scan`` on every device, wrapped
    in shard_map. Captures only static aux, never the caller's arrays."""
    n, m0, imax = dstate.nmodes, dstate.mode, dstate.imax
    dims, smax_loc = dstate.dims, dstate.smax_loc
    seq = tuple((m0 + i) % n for i in range(n))
    branches = [
        _dist_mode_branch(d, statics=dstate.statics,
                          lstatics=dstate.lstatics, n_dev=dstate.n_dev,
                          smax_loc=smax_loc, schedule=dstate.schedule,
                          config=dstate.config, dist=dstate.dist,
                          fold=fold, pad_out_to=imax)
        for d in range(n)
    ]
    layout_specs, fac_spec, in_specs = _specs(dstate, fold)

    def local_run(layout3, relabels, sched, factors, carry):
        TRACE_COUNTS["dist_all_modes"] += 1  # trace-time side effect

        def body(sc, mode_t):
            layout3, factors, carry = sc
            nl, out, factors, carry = lax.switch(
                mode_t,
                [lambda l3, f, c, b=b: b(l3, relabels, sched, f, c)
                 for b in branches],
                layout3, factors, carry)
            return (nl, factors, carry), out

        (layout3, factors, carry), outs = lax.scan(
            body, (layout3, factors, carry),
            jnp.asarray(seq, dtype=jnp.int32))
        by_mode = tuple(outs[seq.index(d)][: dims[d]] for d in range(n))
        return layout3, by_mode, factors, carry

    out_specs = (layout_specs, fac_spec, fac_spec, P())
    return shard_map(local_run, dstate.mesh, in_specs, out_specs)


def _build_dist_step(dstate: DistState):
    """Single-mode program: EC + exchange for the resident mode only."""
    d = dstate.mode
    step = _dist_mode_branch(d, statics=dstate.statics,
                             lstatics=dstate.lstatics, n_dev=dstate.n_dev,
                             smax_loc=dstate.smax_loc,
                             schedule=dstate.schedule, config=dstate.config,
                             dist=dstate.dist, fold=None, pad_out_to=None)
    layout_specs, fac_spec, in_specs = _specs(dstate, None)

    def local_run(layout3, relabels, sched, factors, carry):
        TRACE_COUNTS["dist_mttkrp"] += 1  # trace-time side effect
        nl, out, _, _ = step(layout3, relabels, sched, factors, carry)
        return nl, out

    return shard_map(local_run, dstate.mesh, in_specs,
                     (layout_specs, fac_spec))


# --------------------------------------------------------------------------
# Public execution API.
# --------------------------------------------------------------------------
def _gate_dispatch(dstate: DistState, policy, what: str):
    """Run the chaos hook for one dist dispatch, retrying *transient*
    failures with the same policy-driven backoff stream uploads use.
    Non-transient faults (exchange, device loss, compile) propagate to
    the caller's ladder. Yields nothing; returns after the gate passes.
    """
    attempt = 0
    while True:
        _c = _chaos.active()
        if _c is None:
            return
        try:
            _c.on_dist_dispatch(dstate.config.backend,
                                exchange=dstate.dist.exchange,
                                n_dev=int(dstate.n_dev), attempt=attempt)
            return
        except Exception as exc:
            from repro.resilience.ladder import (backoff_delay, classify,
                                                 record_retry)
            if policy is None or classify(exc) != "transient" \
                    or attempt >= policy.max_retries:
                raise
            record_retry("dist.dispatch", attempt,
                         backoff_delay(policy, attempt,
                                       token=(what, dstate.mode)),
                         kind="dist")
            attempt += 1


def dist_mttkrp(dstate: DistState, factors: Sequence[jax.Array], *,
                policy=None):
    """MTTKRP for the resident mode + cross-device remap exchange; returns
    ``(out, next_dstate)`` with ``out`` of shape ``(dims[mode], R)``."""
    key = ("dist_mttkrp", dstate.aux_key())
    fn = _JIT_CACHE.get(key)
    if fn is None:
        donate = (0,) if dstate.config.resolve_donate() else ()
        fn = _JIT_CACHE[key] = jax.jit(_build_dist_step(dstate),
                                       donate_argnums=donate)
    _gate_dispatch(dstate, policy, "dist_mttkrp")
    DISPATCH_COUNTS["dist_mttkrp"] += 1
    with span("engine.dispatch", kind="dist_mttkrp", mode=dstate.mode,
              n_dev=int(dstate.n_dev)):
        (nval, nidx, nalpha), out = fn(
            (dstate.val, dstate.idx, dstate.alpha), dstate.relabel,
            dstate.sched, tuple(factors), None)
    nxt = (dstate.mode + 1) % dstate.nmodes
    return out, dstate.replace(val=nval, idx=nidx, alpha=nalpha, mode=nxt)


def dist_all_modes(dstate: DistState, factors: Sequence[jax.Array], *,
                   fold: FoldFn | None = None, carry=None, policy=None):
    """Distributed spMTTKRP along all modes: ONE jitted ``lax.scan`` under
    ``shard_map``, starting from any resident mode, with the sharded layout
    as (donation-ready) carry. Same contract as ``engine.all_modes``:
    without ``fold`` returns ``(outs, next_dstate)``; with ``fold`` (a
    stable module-level callable) returns
    ``(outs, next_dstate, factors, carry)`` — which is how distributed
    CPD-ALS sweeps stay single traced programs. ``policy`` (a
    ``LadderPolicy``) retries transient dispatch failures in place; other
    fault kinds propagate to the caller's ladder rungs."""
    key = ("dist_all_modes", dstate.aux_key(), fold)
    fn = _JIT_CACHE.get(key)
    if fn is None:
        donate = (0,) if dstate.config.resolve_donate() else ()
        fn = _JIT_CACHE[key] = jax.jit(_build_dist_scan(dstate, fold),
                                       donate_argnums=donate)
    _gate_dispatch(dstate, policy, "dist_all_modes")
    DISPATCH_COUNTS["dist_all_modes"] += 1
    with span("engine.dispatch", kind="dist_all_modes",
              start_mode=dstate.mode, n_dev=int(dstate.n_dev)):
        layout3, outs, out_factors, out_carry = fn(
            (dstate.val, dstate.idx, dstate.alpha), dstate.relabel,
            dstate.sched, tuple(factors), carry)
    nval, nidx, nalpha = layout3
    next_state = dstate.replace(val=nval, idx=nidx, alpha=nalpha)
    if fold is None:
        return list(outs), next_state
    return list(outs), next_state, list(out_factors), out_carry


def surviving_mesh(mesh: Mesh, lost: int, kappas: Sequence[int],
                   data_axis: str = "data") -> Mesh:
    """The largest viable 1-D data mesh after ``lost`` devices die.

    Simulated/elastic device loss drops the highest-ordinal devices; the
    survivor count is then rounded *down* to the largest ``n`` that
    divides every mode's partition count (``build_sharded_flycoo`` sizes
    kappa as a multiple of the original device count, so halving always
    works). Raises when nothing viable remains — losing the whole mesh is
    not a rung, it is an outage.
    """
    devices = list(np.asarray(mesh.devices).reshape(-1))
    alive = devices[:len(devices) - int(lost)]
    n = len(alive)
    while n >= 1 and any(int(k) % n for k in kappas):
        n -= 1
    if n < 1:
        raise RuntimeError(
            f"no viable mesh after losing {lost} of {len(devices)} "
            f"device(s) (kappas {tuple(int(k) for k in kappas)})")
    return Mesh(np.asarray(alive[:n]), (data_axis,))


def lowered_text(dstate: DistState, factors: Sequence[jax.Array], *,
                 fold: FoldFn | None = None, carry=None) -> str:
    """StableHLO of the dist_all_modes program (acceptance: the permute
    exchange lowers to collective_permute with no element-list all_gather)."""
    fn = _build_dist_scan(dstate, fold)
    return jax.jit(fn).lower(
        (dstate.val, dstate.idx, dstate.alpha), dstate.relabel,
        dstate.sched, tuple(factors), carry).as_text()


__all__ = ["DistConfig", "DistState", "ExchangeSchedule", "shard_state",
           "dist_mttkrp", "dist_all_modes", "schedule_for_plans",
           "element_devices", "exchange_bytes", "row_bytes", "lowered_text",
           "surviving_mesh", "EXCHANGES"]
