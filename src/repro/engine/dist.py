"""Distributed spMTTKRP engine: sharded ``EngineState`` under ``shard_map``.

Cluster-scope version of the paper's Observation 2 on top of the functional
engine (:mod:`repro.engine.api`): partitions — and hence the output rows
they own — are dealt to devices along the mesh's ``data`` axis, so the
elementwise computation needs NO cross-device reduction; each device
segment-sums into rows it exclusively owns. The rank dimension may
optionally shard over ``model`` (MTTKRP is embarrassingly parallel over
rank).

The dynamic remap (Alg. 3) becomes a *static* cross-device permutation:
which element moves from which device to which is fixed by the FLYCOO
plans, so the exchange is precomputed host-side into an
:class:`ExchangeSchedule` and executed as a ``collective_permute``
round-robin — hop ``h`` sends a bounded buffer from every device ``k`` to
device ``(k + h) % n_dev`` — instead of the baseline ``all_gather`` of the
full element list (kept as ``DistConfig(exchange="all_gather")`` for
comparison). AMPED (arXiv:2507.15121) and load-balanced spMTTKRP
(arXiv:1904.03329) both identify this exchange, not the compute, as the
multi-GPU bottleneck.

Sharded layout numbering
------------------------
A :class:`DistState` stores the layout in *device-major* slot numbering:
device ``k`` owns global slots ``[k * S_loc, (k+1) * S_loc)`` where
``S_loc = max_d S_d / n_dev``, and within a device the mode-``d`` layout
occupies the first ``S_d / n_dev`` local slots (its ``kappa_d / n_dev``
partitions, contiguous). This requires every mode's ``kappa`` to be a
multiple of ``n_dev`` — build tensors with
:func:`repro.core.distributed.build_sharded_flycoo` or pick partition
counts via :meth:`ExecutionConfig.kappa_for`.

Public surface:

  DistConfig                            frozen mesh-axis/exchange policy
  shard_state(state, mesh[, dist])      EngineState -> DistState (host, once)
  dist_mttkrp(dstate, factors)          one mode + exchange, one dispatch
  dist_all_modes(dstate, factors)       whole rotation: ONE jitted lax.scan
                                        inside shard_map (fold hook as in
                                        ``engine.all_modes`` -> distributed
                                        CPD-ALS sweeps are single programs)
  schedule_for_plans / exchange_bytes   host-side schedule + traffic model
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sharding import ShardingCtx

from .api import _JIT_CACHE, DISPATCH_COUNTS, TRACE_COUNTS, FoldFn
from .backends import compute_lrow, get_backend
from .config import ExecutionConfig
from .state import EngineState, ModeStatic

try:  # jax >= 0.6 spells it jax.shard_map
    from jax import shard_map as _shard_map

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
except ImportError:  # pragma: no cover - depends on jax version
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map_old(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=False)


EXCHANGES = ("permute", "all_gather")


@dataclasses.dataclass(frozen=True)
class DistConfig:
    """Static distribution policy (hashable; part of the jit cache key).

    Attributes:
      data_axis: mesh axis partitions/rows/slots shard over.
      model_axis: optional mesh axis the factor rank dim shards over
        (incompatible with a ``fold`` hook — grams need the full rank).
      exchange: remap exchange strategy — ``"permute"`` runs the
        precomputed collective_permute schedule, ``"all_gather"`` the
        baseline full-element-list gather + scatter-slice.
      pad_hop: per-hop buffer slot counts round up to this multiple.
    """

    data_axis: str = "data"
    model_axis: str | None = None
    exchange: str = "permute"
    pad_hop: int = 8

    def __post_init__(self):
        if self.exchange not in EXCHANGES:
            raise ValueError(
                f"exchange {self.exchange!r} not in {EXCHANGES}")
        if self.pad_hop < 1:
            raise ValueError("pad_hop must be >= 1")


# --------------------------------------------------------------------------
# Static exchange schedule (host-side, derived from the FLYCOO plans).
# --------------------------------------------------------------------------
class ExchangeSchedule(NamedTuple):
    """Per-(mode -> next mode) transition, per round-robin hop, the padded
    slot capacity of the send buffer. ``hops[d][h-1]`` bounds how many
    elements any device sends to its ``+h``-neighbour while remapping the
    mode-``d`` layout into mode ``d+1``. Static truth derived from the
    plans — the traced exchange cannot overflow it."""

    n_dev: int
    hops: tuple[tuple[int, ...], ...]

    def permute_slots(self, d: int) -> int:
        """Total send-buffer slots one device uses for transition ``d``."""
        return sum(self.hops[d])


def row_bytes(nmodes: int) -> int:
    """Wire bytes per element row: val f32 + idx i32*N + alpha i32*N."""
    return 4 * (1 + 2 * nmodes)


def _schedule_from_slots(slots_by_mode: Sequence[np.ndarray],
                         sizes: Sequence[int], n_dev: int,
                         pad_hop: int) -> ExchangeSchedule:
    """Build the schedule from each element's slot in every mode layout."""
    n = len(slots_by_mode)
    devs = [np.asarray(slots_by_mode[d]) // (sizes[d] // n_dev)
            for d in range(n)]
    hops = []
    for d in range(n):
        src, dst = devs[d], devs[(d + 1) % n]
        counts = np.bincount(src * n_dev + dst,
                             minlength=n_dev * n_dev).reshape(n_dev, n_dev)
        per_hop = []
        for h in range(1, n_dev):
            cap = int(max(counts[k, (k + h) % n_dev] for k in range(n_dev)))
            if cap:
                cap = ((cap + pad_hop - 1) // pad_hop) * pad_hop
            per_hop.append(cap)
        hops.append(tuple(per_hop))
    return ExchangeSchedule(n_dev=n_dev, hops=tuple(hops))


def schedule_for_plans(plans, n_dev: int,
                       pad_hop: int = 8) -> ExchangeSchedule:
    """Exchange schedule for a tensor's ``ModePlan`` list (host-only; needs
    no devices — used by benchmarks to model traffic at any scale)."""
    for p in plans:
        if p.kappa % n_dev != 0:
            raise ValueError(
                f"mode-{p.mode} kappa {p.kappa} not divisible by "
                f"n_dev {n_dev}; build with kappa_for / build_sharded_flycoo")
    return _schedule_from_slots([p.slot_of_elem for p in plans],
                                [p.padded_nnz for p in plans], n_dev,
                                pad_hop)


def exchange_bytes(schedule: ExchangeSchedule, nmodes: int,
                   slocs: Sequence[int]) -> list[dict]:
    """Per-device wire traffic of one full rotation, per mode transition:
    the collective_permute schedule vs the all_gather baseline. ``slocs``
    is the per-mode local padded slot count ``S_d / n_dev`` — the baseline
    gathers each remote device's mode-``d`` element list, so transition
    ``d`` ships ``(n_dev - 1) * slocs[d]`` rows per device."""
    rb = row_bytes(nmodes)
    out = []
    for d in range(len(schedule.hops)):
        out.append({
            "mode": d,
            "permute_bytes": schedule.permute_slots(d) * rb,
            "all_gather_bytes": (schedule.n_dev - 1) * slocs[d] * rb,
        })
    return out


# --------------------------------------------------------------------------
# DistState: the sharded EngineState.
# --------------------------------------------------------------------------
@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class DistState:
    """Immutable sharded engine state (device-major slot numbering).

    Array leaves mirror :class:`~repro.engine.state.EngineState` but hold
    *global* arrays placed over the mesh: ``val (n_dev*S_loc,)``,
    ``idx/alpha (n_dev*S_loc, N)`` sharded along the ``data`` axis, and the
    replicated per-mode ``relabel`` tables. ``alpha`` entries are in the
    device-major dist numbering (see module docstring), so remap
    destinations encode both target device and target local slot.
    """

    val: jax.Array
    idx: jax.Array
    alpha: jax.Array
    relabel: tuple[jax.Array, ...]
    mode: int
    dims: tuple[int, ...]
    statics: tuple[ModeStatic, ...]
    config: ExecutionConfig
    dist: DistConfig
    n_dev: int
    schedule: ExchangeSchedule
    mesh: Mesh

    # ------------------------------------------------------------ derived
    @property
    def nmodes(self) -> int:
        return len(self.dims)

    @property
    def slocs(self) -> tuple[int, ...]:
        """Per-mode local padded slot counts ``S_d / n_dev``."""
        return tuple(s.padded_nnz // self.n_dev for s in self.statics)

    @property
    def smax_loc(self) -> int:
        """Per-device slot count (max over per-mode local padded sizes)."""
        return max(self.slocs)

    @property
    def imax(self) -> int:
        return max(self.dims)

    def aux_key(self):
        return (self.mode, self.dims, self.statics, self.config, self.dist,
                self.n_dev, self.schedule, self.mesh)

    def replace(self, **kw) -> "DistState":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------- pytree
    def tree_flatten(self):
        children = (self.val, self.idx, self.alpha, self.relabel)
        # aux IS the jit-cache key: one definition, no drift between what
        # forces a retrace and what keys the _JIT_CACHE programs.
        return children, self.aux_key()

    @classmethod
    def tree_unflatten(cls, aux, children):
        val, idx, alpha, relabel = children
        mode, dims, statics, config, dist, n_dev, schedule, mesh = aux
        return cls(val=val, idx=idx, alpha=alpha, relabel=tuple(relabel),
                   mode=mode, dims=dims, statics=statics, config=config,
                   dist=dist, n_dev=n_dev, schedule=schedule, mesh=mesh)


# --------------------------------------------------------------------------
# shard_state: place an EngineState over the mesh.
# --------------------------------------------------------------------------
def shard_state(state: EngineState, mesh: Mesh | ShardingCtx,
                dist: DistConfig | None = None) -> DistState:
    """Shard a single-device :class:`EngineState` over ``mesh``'s data axis.

    ``mesh`` may be a raw :class:`jax.sharding.Mesh` or a
    :class:`repro.sharding.ShardingCtx` — with a ctx (and no explicit
    ``dist``) the data/model axes follow the ctx's dp/tp convention.

    Renumbers every mode layout into device-major slots, precomputes the
    collective_permute :class:`ExchangeSchedule` from the alpha tables, and
    ``device_put``s the arrays with the matching ``NamedSharding``s.
    Requires every mode's ``kappa`` to be a multiple of the data-axis size
    (see :meth:`ExecutionConfig.kappa_for`).
    """
    if isinstance(mesh, ShardingCtx):
        ctx, mesh = mesh, mesh.mesh
        if dist is None:
            dist = DistConfig(data_axis=ctx.data_axis,
                              model_axis=ctx.tp_axis)
    dist = dist or DistConfig()
    if dist.data_axis not in mesh.axis_names:
        raise ValueError(f"mesh has no axis {dist.data_axis!r}: "
                         f"{mesh.axis_names}")
    n_dev = mesh.shape[dist.data_axis]
    for s in state.statics:
        if s.kappa % n_dev != 0:
            raise ValueError(
                f"kappa {s.kappa} not divisible by n_dev {n_dev}; build "
                "the tensor with ExecutionConfig.kappa_for(dim, n_dev) "
                "(e.g. via core.distributed.build_sharded_flycoo)")

    n, m0 = state.nmodes, state.mode
    sizes = [s.padded_nnz for s in state.statics]
    slocs = [sz // n_dev for sz in sizes]
    smax_loc = max(slocs)
    total = n_dev * smax_loc

    alpha = np.asarray(state.alpha)
    alive = alpha[:, m0] >= 0
    slots = alpha[alive].astype(np.int64)           # (nnz, n) per-mode slots
    # device-major renumbering: slot -> dev * smax_loc + (slot % S_d_loc)
    dslots = np.empty_like(slots)
    for d in range(n):
        dev, loc = slots[:, d] // slocs[d], slots[:, d] % slocs[d]
        dslots[:, d] = dev * smax_loc + loc
    schedule = _schedule_from_slots([slots[:, d] for d in range(n)], sizes,
                                    n_dev, dist.pad_hop)

    pos = dslots[:, m0]
    val = np.zeros(total, dtype=np.float32)
    idx = np.zeros((total, n), dtype=np.int32)
    nalpha = np.full((total, n), -1, dtype=np.int32)
    val[pos] = np.asarray(state.val)[alive]
    idx[pos] = np.asarray(state.idx)[alive]
    nalpha[pos] = dslots.astype(np.int32)

    da = dist.data_axis
    sh1 = NamedSharding(mesh, P(da))
    sh2 = NamedSharding(mesh, P(da, None))
    rep = NamedSharding(mesh, P())
    return DistState(
        val=jax.device_put(jnp.asarray(val), sh1),
        idx=jax.device_put(jnp.asarray(idx), sh2),
        alpha=jax.device_put(jnp.asarray(nalpha), sh2),
        relabel=tuple(jax.device_put(r, rep) for r in state.relabel),
        mode=m0, dims=state.dims, statics=state.statics,
        config=state.config, dist=dist, n_dev=n_dev, schedule=schedule,
        mesh=mesh)


# --------------------------------------------------------------------------
# Per-device exchange kernels (run inside shard_map).
# --------------------------------------------------------------------------
def _exchange_permute(v, ix, al, alive, *, nxt, hops, smax_loc, n_dev, da,
                      nmodes):
    """Static round-robin: hop ``h`` ships a bounded buffer to the ``+h``
    neighbour via collective_permute; local moves scatter directly."""
    me = lax.axis_index(da)
    dstg = al[:, nxt]                       # global dist slot (-1 dead)
    dst_dev = dstg // smax_loc              # floor div: dead -> -1
    mine = alive & (dst_dev == me)
    dst = jnp.where(mine, dstg % smax_loc, smax_loc)
    nval = jnp.zeros((smax_loc,), v.dtype).at[dst].set(
        v, mode="drop", unique_indices=True)
    nidx = jnp.zeros((smax_loc, nmodes), ix.dtype).at[dst].set(
        ix, mode="drop", unique_indices=True)
    nalpha = jnp.full((smax_loc, nmodes), -1, jnp.int32).at[dst].set(
        al, mode="drop", unique_indices=True)

    for h in range(1, n_dev):
        cap = hops[h - 1]
        if cap == 0:    # statically empty hop: no collective at all
            continue
        sel = alive & (dst_dev == (me + h) % n_dev)
        # pack outgoing elements densely; schedule guarantees fit <= cap
        bpos = jnp.where(sel, jnp.cumsum(sel) - 1, cap)
        bval = jnp.zeros((cap,), v.dtype).at[bpos].set(v, mode="drop")
        bidx = jnp.zeros((cap, nmodes), ix.dtype).at[bpos].set(
            ix, mode="drop")
        balpha = jnp.full((cap, nmodes), -1, jnp.int32).at[bpos].set(
            al, mode="drop")
        perm = [(k, (k + h) % n_dev) for k in range(n_dev)]
        rval = lax.ppermute(bval, da, perm)
        ridx = lax.ppermute(bidx, da, perm)
        ralpha = lax.ppermute(balpha, da, perm)
        rdst = ralpha[:, nxt]               # arrivals all target me
        rloc = jnp.where(rdst >= 0, rdst % smax_loc, smax_loc)
        nval = nval.at[rloc].set(rval, mode="drop", unique_indices=True)
        nidx = nidx.at[rloc].set(ridx, mode="drop", unique_indices=True)
        nalpha = nalpha.at[rloc].set(ralpha, mode="drop",
                                     unique_indices=True)
    return nval, nidx, nalpha


def _exchange_all_gather(v, ix, al, alive, *, d, nxt, smax_loc, n_dev, da,
                         nmodes):
    """Baseline (pre-engine ``DistributedMTTKRP``): gather the FULL element
    list on every device, scatter into the whole next layout, keep the
    local slice. O(n_dev * nnz) wire traffic per transition."""
    del alive
    total = n_dev * smax_loc
    vg = lax.all_gather(v, da, tiled=True)
    ig = lax.all_gather(ix, da, tiled=True)
    ag = lax.all_gather(al, da, tiled=True)
    alive_g = ag[:, d] >= 0
    dst = jnp.where(alive_g, ag[:, nxt], total)
    nval = jnp.zeros((total,), v.dtype).at[dst].set(
        vg, mode="drop", unique_indices=True)
    nidx = jnp.zeros((total, nmodes), ix.dtype).at[dst].set(
        ig, mode="drop", unique_indices=True)
    nalpha = jnp.full((total, nmodes), -1, jnp.int32).at[dst].set(
        ag, mode="drop", unique_indices=True)
    me = lax.axis_index(da)
    sl = lambda a: lax.dynamic_slice_in_dim(  # noqa: E731
        a, me * smax_loc, smax_loc, axis=0)
    return sl(nval), sl(nidx), sl(nalpha)


# --------------------------------------------------------------------------
# One mode on one device: local EC + output gather + remap exchange.
# --------------------------------------------------------------------------
def _dist_mode_branch(d: int, *, statics: Sequence[ModeStatic], n_dev: int,
                      smax_loc: int, schedule: ExchangeSchedule,
                      config: ExecutionConfig, dist: DistConfig,
                      fold: FoldFn | None, pad_out_to: int | None):
    """Traced per-device step for (static) mode ``d``; same contract as the
    single-device ``engine.api._mode_branch`` but over local shards."""
    s = statics[d]
    n = len(statics)
    nxt = (d + 1) % n
    sloc = s.padded_nnz // n_dev
    lplan = ModeStatic(kappa=s.kappa // n_dev, rows_pp=s.rows_pp,
                       blocks_pp=s.blocks_pp, block_p=s.block_p, dim=s.dim)
    backend = get_backend(config)
    da = dist.data_axis

    def step(layout3, relabels, factors, carry):
        val, idx, alpha = layout3           # local (smax_loc, ...) shards
        v, ix, al = val[:sloc], idx[:sloc], alpha[:sloc]
        alive = al[:, d] >= 0
        # EC over owned partitions only (Obs. 2: rows owned exclusively,
        # so the segment-sum needs no cross-device reduction). Backends see
        # the exact same contract as the single-device scan; fusing
        # backends (``pallas_fused``) run their plain-EC entry here — the
        # remap is the cross-device exchange below, not a local scatter —
        # so the in-kernel gather fusion still applies per shard.
        lrow = compute_lrow(ix[:, d], relabels[d], s.rows_pp, alive)
        out_rel_loc = backend({"val": v, "idx": ix, "alpha": al,
                               "lrow": lrow},
                              tuple(factors), d, plan=lplan, config=config)
        # Devices own contiguous relabeled-row ranges (kappa % n_dev == 0),
        # so a tiled output gather IS the global relabeled result. This is
        # rows x R — small — not the element list.
        out_rel = lax.all_gather(out_rel_loc, da, tiled=True)
        out = jnp.take(out_rel, relabels[d], axis=0)
        if fold is not None:
            factors, carry = fold(d, out, factors, carry)
        if pad_out_to is not None:
            out = jnp.pad(out, ((0, pad_out_to - s.dim), (0, 0)))

        if dist.exchange == "permute":
            nl = _exchange_permute(v, ix, al, alive, nxt=nxt,
                                   hops=schedule.hops[d],
                                   smax_loc=smax_loc, n_dev=n_dev, da=da,
                                   nmodes=n)
        else:
            nl = _exchange_all_gather(v, ix, al, alive, d=d, nxt=nxt,
                                      smax_loc=smax_loc, n_dev=n_dev,
                                      da=da, nmodes=n)
        return nl, out, factors, carry

    return step


# --------------------------------------------------------------------------
# Program builders (shard_map-wrapped; pre-jit for lowering inspection).
# --------------------------------------------------------------------------
def _specs(dstate: DistState, fold: FoldFn | None):
    da, ma = dstate.dist.data_axis, dstate.dist.model_axis
    if fold is not None and ma is not None:
        raise ValueError("fold needs the full rank on every device; use "
                         "model_axis=None when folding (e.g. CPD-ALS)")
    layout_specs = (P(da), P(da, None), P(da, None))
    fac_spec = P(None, ma) if ma else P(None, None)
    in_specs = (layout_specs, P(), fac_spec, P())
    return layout_specs, fac_spec, in_specs


def _build_dist_scan(dstate: DistState, fold: FoldFn | None):
    """The whole mode rotation as one ``lax.scan`` on every device, wrapped
    in shard_map. Captures only static aux, never the caller's arrays."""
    n, m0, imax = dstate.nmodes, dstate.mode, dstate.imax
    dims, smax_loc = dstate.dims, dstate.smax_loc
    seq = tuple((m0 + i) % n for i in range(n))
    branches = [
        _dist_mode_branch(d, statics=dstate.statics, n_dev=dstate.n_dev,
                          smax_loc=smax_loc, schedule=dstate.schedule,
                          config=dstate.config, dist=dstate.dist,
                          fold=fold, pad_out_to=imax)
        for d in range(n)
    ]
    layout_specs, fac_spec, in_specs = _specs(dstate, fold)

    def local_run(layout3, relabels, factors, carry):
        TRACE_COUNTS["dist_all_modes"] += 1  # trace-time side effect

        def body(sc, mode_t):
            layout3, factors, carry = sc
            nl, out, factors, carry = lax.switch(
                mode_t,
                [lambda l3, f, c, b=b: b(l3, relabels, f, c)
                 for b in branches],
                layout3, factors, carry)
            return (nl, factors, carry), out

        (layout3, factors, carry), outs = lax.scan(
            body, (layout3, factors, carry),
            jnp.asarray(seq, dtype=jnp.int32))
        by_mode = tuple(outs[seq.index(d)][: dims[d]] for d in range(n))
        return layout3, by_mode, factors, carry

    out_specs = (layout_specs, fac_spec, fac_spec, P())
    return shard_map(local_run, dstate.mesh, in_specs, out_specs)


def _build_dist_step(dstate: DistState):
    """Single-mode program: EC + exchange for the resident mode only."""
    d = dstate.mode
    step = _dist_mode_branch(d, statics=dstate.statics, n_dev=dstate.n_dev,
                             smax_loc=dstate.smax_loc,
                             schedule=dstate.schedule, config=dstate.config,
                             dist=dstate.dist, fold=None, pad_out_to=None)
    layout_specs, fac_spec, in_specs = _specs(dstate, None)

    def local_run(layout3, relabels, factors, carry):
        TRACE_COUNTS["dist_mttkrp"] += 1  # trace-time side effect
        nl, out, _, _ = step(layout3, relabels, factors, carry)
        return nl, out

    return shard_map(local_run, dstate.mesh, in_specs,
                     (layout_specs, fac_spec))


# --------------------------------------------------------------------------
# Public execution API.
# --------------------------------------------------------------------------
def dist_mttkrp(dstate: DistState, factors: Sequence[jax.Array]):
    """MTTKRP for the resident mode + cross-device remap exchange; returns
    ``(out, next_dstate)`` with ``out`` of shape ``(dims[mode], R)``."""
    key = ("dist_mttkrp", dstate.aux_key())
    fn = _JIT_CACHE.get(key)
    if fn is None:
        donate = (0,) if dstate.config.resolve_donate() else ()
        fn = _JIT_CACHE[key] = jax.jit(_build_dist_step(dstate),
                                       donate_argnums=donate)
    DISPATCH_COUNTS["dist_mttkrp"] += 1
    (nval, nidx, nalpha), out = fn(
        (dstate.val, dstate.idx, dstate.alpha), dstate.relabel,
        tuple(factors), None)
    nxt = (dstate.mode + 1) % dstate.nmodes
    return out, dstate.replace(val=nval, idx=nidx, alpha=nalpha, mode=nxt)


def dist_all_modes(dstate: DistState, factors: Sequence[jax.Array], *,
                   fold: FoldFn | None = None, carry=None):
    """Distributed spMTTKRP along all modes: ONE jitted ``lax.scan`` under
    ``shard_map``, starting from any resident mode, with the sharded layout
    as (donation-ready) carry. Same contract as ``engine.all_modes``:
    without ``fold`` returns ``(outs, next_dstate)``; with ``fold`` (a
    stable module-level callable) returns
    ``(outs, next_dstate, factors, carry)`` — which is how distributed
    CPD-ALS sweeps stay single traced programs."""
    key = ("dist_all_modes", dstate.aux_key(), fold)
    fn = _JIT_CACHE.get(key)
    if fn is None:
        donate = (0,) if dstate.config.resolve_donate() else ()
        fn = _JIT_CACHE[key] = jax.jit(_build_dist_scan(dstate, fold),
                                       donate_argnums=donate)
    DISPATCH_COUNTS["dist_all_modes"] += 1
    layout3, outs, out_factors, out_carry = fn(
        (dstate.val, dstate.idx, dstate.alpha), dstate.relabel,
        tuple(factors), carry)
    nval, nidx, nalpha = layout3
    next_state = dstate.replace(val=nval, idx=nidx, alpha=nalpha)
    if fold is None:
        return list(outs), next_state
    return list(outs), next_state, list(out_factors), out_carry


def lowered_text(dstate: DistState, factors: Sequence[jax.Array], *,
                 fold: FoldFn | None = None, carry=None) -> str:
    """StableHLO of the dist_all_modes program (acceptance: the permute
    exchange lowers to collective_permute with no element-list all_gather)."""
    fn = _build_dist_scan(dstate, fold)
    return jax.jit(fn).lower(
        (dstate.val, dstate.idx, dstate.alpha), dstate.relabel,
        tuple(factors), carry).as_text()


__all__ = ["DistConfig", "DistState", "ExchangeSchedule", "shard_state",
           "dist_mttkrp", "dist_all_modes", "schedule_for_plans",
           "exchange_bytes", "row_bytes", "lowered_text", "EXCHANGES"]
