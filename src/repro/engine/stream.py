"""Out-of-core streaming spMTTKRP engine: tensors larger than device memory.

The resident engine (:mod:`repro.engine.api`) keeps the whole FLYCOO
element list on device. This subsystem keeps it on the HOST and streams
partition-aligned *chunks* of each mode's block schedule through the
device, double-buffered: while chunk ``k`` runs the elementwise
computation, chunk ``k+1`` is already uploading (async ``jax.device_put``
onto a ring of ``config.stream_ring`` buffers). It is the same
double-buffered-DMA idiom the fused Pallas kernel uses for factor rows,
one level up the memory hierarchy (AMPED, arXiv:2507.15121; out-of-memory
MTTKRP, arXiv:2201.12523).

Why chunking preserves bitwise equality
---------------------------------------
Chunks are *whole partitions* (:func:`repro.core.partition.
chunk_schedule`): every output row is owned by exactly one partition
(paper Observation 2), and a partition's slots are a contiguous run of the
partition-major layout, so each chunk's elementwise computation touches a
disjoint, contiguous relabeled-row range ``[part_start[c]*rows_pp,
part_start[c+1]*rows_pp)`` and sees its slots in exactly the order the
resident engine does. Per-chunk results therefore concatenate
bitwise-exactly into the resident result — no accumulation across chunks,
no reassociation. The unchanged backend contract serves every chunk
(``xla | ref | pallas | pallas_fused``); chunks are padded to one uniform
``(chunk_kappa, chunk_blocks)`` shape so each mode compiles ONE program
(pad blocks repeat the last real partition and carry all-pad slots, the
``engine.dist`` device-padding pattern). Short chunks' row overhang is
handled by an ascending ``dynamic_update_slice`` into an over-allocated
accumulator: each later chunk overwrites its predecessor's overhang, and
the final slice keeps exactly ``kappa * rows_pp`` rows.

The Alg. 3 remap is the streaming analogue of ``engine.dist``'s exchange:
each chunk emits its next-mode *fragment* (the chunk's alive elements
scattered through ``alpha[:, d+1]``) which is reassembled host-side into
the next rotation's layout while the device crunches the next chunk — the
device never holds more than the chunk ring, the factor matrices, and the
output accumulator.

Public surface:

  StreamPlan / plan_stream(tensor, config)   per-mode chunk schedules sized
                                             to ``device_budget_bytes``
  StreamState / stream_init(tensor, config)  host layout + device chunk ring
  stream_mttkrp(state, factors)              one mode, chunked + prefetched
  stream_all_modes(state, factors)           full rotation (fold hook as in
                                             ``engine.all_modes``)
  cp_als_stream(tensor, rank, ...)           out-of-core CPD-ALS
  resident_bytes / resolve_chunk_slots /     the budget model ``factory.
  stream_transfer_model                      make_engine`` and ``engine.
                                             autotune`` price streaming with
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.partition import (ChunkSchedule, chunk_bpart,
                                  chunk_schedule)
from repro.obs.metrics import counter as _obs_counter
from repro.obs.metrics import gauge as _obs_gauge
from repro.obs.probe import device_peak_bytes
from repro.obs.trace import span
from repro.resilience import chaos as _chaos
from repro.resilience import guard as _guard
from repro.resilience.ladder import (backoff_delay, classify, next_backend,
                                     record_degradation, record_retry,
                                     resolve_policy)
from repro.resilience.snapshot import as_store, fingerprint

from .api import _JIT_CACHE, DISPATCH_COUNTS, TRACE_COUNTS, _as_flycoo
from .backends import get_backend
from .config import ExecutionConfig
from .dist import row_bytes
from .state import ModeStatic, mode_static_from_plan

#: Chunk size (kernel slots) when neither ``chunk_nnz`` nor
#: ``device_budget_bytes`` is configured.
DEFAULT_CHUNK_SLOTS = 1 << 20


# --------------------------------------------------------------------------
# Budget model (host-side, plan-free where possible).
# --------------------------------------------------------------------------
def _wants_tables(config: ExecutionConfig, schedule: str) -> bool:
    """Whether streamed chunks must carry the in-block dedup tables — the
    exact condition ``engine.api._mode_sched`` uses for residency."""
    return (schedule == "compact"
            and getattr(get_backend(config), "needs_dedup", False))


def bytes_per_slot(nmodes: int, tables: bool) -> int:
    """Device bytes one streamed kernel slot costs: val f32 + idx i32*N +
    lrow i32, plus the dedup tables (uidx + upos, i32*(N-1) each) when the
    backend consumes them, plus 4 bytes slack covering the per-block
    descriptor/nuniq amortization — kept conservative so ring sizing from
    a budget never lands over it."""
    b = 4 * (2 + nmodes) + 4
    if tables:
        b += 8 * (nmodes - 1)
    return b


def chunk_device_bytes(cs: ChunkSchedule, nmodes: int, tables: bool) -> int:
    """Exact device bytes of one uploaded (uniformly padded) chunk."""
    s, nb = cs.chunk_slots, cs.chunk_blocks
    b = s * 4 * (2 + nmodes) + nb * 4
    if tables:
        b += s * 8 * (nmodes - 1) + nb * 4 * (nmodes - 1)
    return b


def stream_fixed_bytes(dims: Sequence[int], config: ExecutionConfig,
                       rank: int | None = None,
                       statics: Sequence[ModeStatic] | None = None) -> int:
    """Device bytes the streaming engine holds *besides* the chunk ring:
    full factor matrices, the relabel tables, the over-allocated output
    accumulator (bounded by ``2 * rmax * R``), and one mode output."""
    rank = rank or config.rank_hint
    if statics is not None:
        rmax = max(s.relabeled_rows for s in statics)
    else:
        rmax = 0
        for dim in dims:
            kappa = config.kappa_for(int(dim))
            rmax = max(rmax, kappa * math.ceil(int(dim) / kappa))
    acc = 2 * rmax * rank * 4
    factors = sum(int(d) for d in dims) * rank * 4
    out = max(int(d) for d in dims) * rank * 4
    relabel = sum(int(d) for d in dims) * 4
    return acc + factors + out + relabel


def resolve_chunk_slots(config: ExecutionConfig, dims: Sequence[int], *,
                        tables: bool = False,
                        statics: Sequence[ModeStatic] | None = None) -> int:
    """Target kernel slots per streamed chunk — the ONE sizing rule.

    Priority: explicit ``chunk_nnz``; else derive from
    ``device_budget_bytes`` so the whole ring (``stream_ring`` uniformly
    padded chunks) plus the fixed state fits the budget; else the library
    default. Never below one kernel block — a partition larger than the
    target still forms an (oversized) chunk of its own, so streaming
    always completes; it may just exceed an impossibly small budget.
    """
    if config.chunk_nnz is not None:
        return max(config.block_p, int(config.chunk_nnz))
    if config.device_budget_bytes is None:
        return DEFAULT_CHUNK_SLOTS
    fixed = stream_fixed_bytes(dims, config, statics=statics)
    avail = config.device_budget_bytes - fixed
    slots = avail // (config.stream_ring * bytes_per_slot(len(dims), tables))
    return int(max(config.block_p, slots))


def resident_bytes(tensor, config: ExecutionConfig,
                   rank: int | None = None) -> int:
    """Device footprint of the FULL-residency engine (``engine.init``) for
    ``tensor``: the S_max-padded layout triple, the per-mode schedule
    tables, the relabel tables, the factors and one rotation of outputs.
    This is the threshold ``residency="auto"`` compares
    ``device_budget_bytes`` against."""
    rank = rank or config.rank_hint
    n = tensor.nmodes
    statics = [mode_static_from_plan(p) for p in tensor.plans]
    smax = max(s.padded_nnz for s in statics)
    total = smax * 4 * (1 + 2 * n)            # val + idx + alpha
    tables = _wants_tables(config, statics[0].schedule)
    for s in statics:
        total += s.nblocks * 4                 # bpart descriptor
        if tables:
            total += s.padded_nnz * 8 * (n - 1) + s.nblocks * 4 * (n - 1)
    total += sum(int(d) for d in tensor.dims) * 4          # relabel
    total += sum(int(d) for d in tensor.dims) * rank * 4   # factors
    total += max(int(d) for d in tensor.dims) * rank * 4   # mode output
    return total


# --------------------------------------------------------------------------
# StreamPlan: per-mode chunk schedules + chunk-local plan constants.
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class StreamPlan:
    """Partition-aligned chunking of every mode's block schedule.

    ``chunks[d]`` slices mode ``d``'s (compact or rect) block schedule
    into chunks of at most ``target_slots`` kernel slots (whole partitions
    only); ``lstatics[d]`` is the chunk-local :class:`ModeStatic` every
    chunk of that mode runs under (uniform ``chunk_kappa`` partitions /
    ``chunk_blocks`` blocks — ONE trace per mode). ``tables`` records
    whether chunks carry the in-block dedup tables.
    """

    target_slots: int
    chunks: tuple[ChunkSchedule, ...]
    lstatics: tuple[ModeStatic, ...]
    tables: bool

    @property
    def total_chunks(self) -> int:
        return sum(cs.nchunks for cs in self.chunks)

    def mode_h2d_bytes(self, d: int, nmodes: int) -> int:
        """Uploaded bytes for one full pass over mode ``d``'s chunks."""
        cs = self.chunks[d]
        return cs.nchunks * chunk_device_bytes(cs, nmodes, self.tables)


def plan_stream(tensor, config: ExecutionConfig) -> StreamPlan:
    """Build the chunk schedules for ``tensor`` under ``config``'s budget
    (see :func:`resolve_chunk_slots` for the sizing rule)."""
    statics = tuple(mode_static_from_plan(p) for p in tensor.plans)
    tables = _wants_tables(config, statics[0].schedule)
    target = resolve_chunk_slots(config, tensor.dims, tables=tables,
                                 statics=statics)
    chunks = tuple(chunk_schedule(p, target) for p in tensor.plans)
    lstatics = tuple(
        ModeStatic(kappa=cs.chunk_kappa, rows_pp=s.rows_pp,
                   blocks_pp=s.blocks_pp, block_p=s.block_p, dim=s.dim,
                   nblocks=cs.chunk_blocks, schedule=s.schedule)
        for s, cs in zip(statics, chunks))
    return StreamPlan(target_slots=target, chunks=chunks,
                      lstatics=lstatics, tables=tables)


def _stream_plan_key(tensor, config: ExecutionConfig) -> str:
    """Structural key of a :func:`plan_stream` result: the plan geometry
    (per-mode partition/block structure) plus every config knob the chunk
    sizing reads. Two tensors with identical structure — notably the SAME
    tensor replanned under a degraded budget seen before — share a key."""
    import hashlib

    tables = _wants_tables(
        config, mode_static_from_plan(tensor.plans[0]).schedule)
    h = hashlib.sha256()
    h.update(repr((tuple(int(d) for d in tensor.dims), int(tensor.nnz),
                   config.chunk_nnz, config.device_budget_bytes,
                   config.stream_ring, config.block_p, config.rank_hint,
                   tables)).encode())
    for p in tensor.plans:
        h.update(repr((int(p.kappa), int(p.rows_pp), int(p.block_p),
                       int(p.blocks_pp), int(p.nblocks),
                       p.schedule)).encode())
        h.update(np.ascontiguousarray(p.part_nnz).tobytes())
        h.update(np.ascontiguousarray(p.block_part).tobytes())
    return h.hexdigest()


def plan_stream_cached(tensor, config: ExecutionConfig,
                       cache=None) -> StreamPlan:
    """:func:`plan_stream` through the :class:`~repro.core.plancache.
    PlanCache` structural tier — a replan under a config seen before
    (streaming re-init, resume, or a chunk-budget ladder rung replaying a
    degraded budget) is a cache hit instead of a from-scratch chunking.
    ``cache=None`` uses the process default; ``cache=False`` plans cold.
    Hits/misses land on the ``stream_replan_outcomes`` obs counter."""
    from repro.core.plancache import DEFAULT_CACHE

    if cache is None:
        cache = DEFAULT_CACHE
    elif cache is False:
        return plan_stream(tensor, config)
    return cache.get_stream_plan(
        _stream_plan_key(tensor, config),
        lambda: plan_stream(tensor, config))


def stream_transfer_model(tensor, config: ExecutionConfig) -> dict:
    """Modeled transfer traffic of one full streamed rotation: per-mode
    chunk H2D bytes (uniformly padded uploads) and remap-fragment bytes
    (``nnz`` element rows reassembled into the next layout per hop). The
    autotuner's streaming cost term and the fig11 oversubscription rows
    both read this one model."""
    plan = plan_stream(tensor, config)
    n = tensor.nmodes
    rb = row_bytes(n)
    per_mode = []
    for d in range(n):
        per_mode.append({
            "mode": d,
            "nchunks": plan.chunks[d].nchunks,
            "chunk_slots": plan.chunks[d].chunk_slots,
            "h2d_bytes": plan.mode_h2d_bytes(d, n),
            "fragment_bytes": tensor.nnz * rb,
        })
    return {
        "target_slots": plan.target_slots,
        "total_chunks": plan.total_chunks,
        "h2d_bytes": sum(m["h2d_bytes"] for m in per_mode),
        "fragment_bytes": sum(m["fragment_bytes"] for m in per_mode),
        "per_mode": per_mode,
    }


# --------------------------------------------------------------------------
# StreamState: host layout + device chunk ring.
# --------------------------------------------------------------------------
@dataclasses.dataclass
class StreamStats:
    """Mutable transfer/residency observability (shared across rotations)."""

    h2d_bytes: int = 0            # uploaded chunk bytes (host -> device)
    fragment_bytes: int = 0       # remap fragment bytes reassembled per hop
    chunks_streamed: int = 0
    modes_streamed: int = 0
    uploads: int = 0
    overlapped_uploads: int = 0   # uploads issued ahead of their compute
    upload_retries: int = 0       # transient-failure upload re-attempts
    budget_halvings: int = 0      # chunk-budget ladder rungs taken (OOM)
    backend_steps: int = 0        # backend ladder rungs taken (compile)
    peak_ring_bytes: int = 0      # max live device bytes of the chunk ring
    peak_ring_chunks: int = 0

    @property
    def transfer_bytes(self) -> int:
        return self.h2d_bytes + self.fragment_bytes

    @property
    def overlap_efficiency(self) -> float:
        """Fraction of uploads issued while earlier chunks were still in
        flight (1.0 = every upload but each mode's first was prefetched)."""
        return self.overlapped_uploads / max(self.uploads, 1)

    def as_row(self) -> dict:
        return {
            "h2d_bytes": self.h2d_bytes,
            "fragment_bytes": self.fragment_bytes,
            "transfer_bytes": self.transfer_bytes,
            "chunks_streamed": self.chunks_streamed,
            "modes_streamed": self.modes_streamed,
            "upload_retries": self.upload_retries,
            "budget_halvings": self.budget_halvings,
            "backend_steps": self.backend_steps,
            "peak_ring_bytes": self.peak_ring_bytes,
            "peak_ring_chunks": self.peak_ring_chunks,
            "overlap_efficiency": self.overlap_efficiency,
            "device_peak_bytes": device_peak_bytes(),
        }


def _mirror_stats(stats: StreamStats, before: StreamStats) -> None:
    """Mirror one mode pass's :class:`StreamStats` deltas onto the
    ``repro.obs`` metrics registry, so exported traces carry the
    count-derived transfer/overlap numbers next to the spans they are
    cross-checked against (the CI ``obs-smoke`` gate compares the two)."""
    counts = _obs_counter("stream_counts",
                          "streamed uploads / chunks / mode passes")
    counts.inc("uploads", stats.uploads - before.uploads)
    counts.inc("overlapped_uploads",
               stats.overlapped_uploads - before.overlapped_uploads)
    counts.inc("upload_retries",
               stats.upload_retries - before.upload_retries)
    counts.inc("chunks", stats.chunks_streamed - before.chunks_streamed)
    counts.inc("modes", 1)
    nbytes = _obs_counter("stream_bytes",
                          "streamed transfer bytes by direction")
    nbytes.inc("h2d", stats.h2d_bytes - before.h2d_bytes)
    nbytes.inc("fragment", stats.fragment_bytes - before.fragment_bytes)
    peaks = _obs_gauge("stream_peaks", "chunk ring high-water marks")
    peaks.max("ring_bytes", stats.peak_ring_bytes)
    peaks.max("ring_chunks", stats.peak_ring_chunks)
    dev_peak = device_peak_bytes()
    if dev_peak is not None:
        peaks.max("device_bytes", dev_peak)


@dataclasses.dataclass
class StreamState:
    """Host-resident engine state for the streaming tier.

    The FLYCOO layout of the *resident mode* lives in host numpy
    (``val (S_d,)``, ``idx/alpha (S_d, N)``, ``lrow (S_d,)`` — natural
    per-mode size, no S_max padding: nothing here rides a scan carry).
    Only the relabel tables (small, ``sum I_d`` ints) and the factor
    matrices stay device-resident; element data visits the device one
    chunk ring at a time. ``tensor`` is the canonical host copy — its
    plans drive chunk slicing and (lazily, per mode) the dedup tables.
    """

    tensor: object                      # FlycooTensor (host)
    plan: StreamPlan
    statics: tuple[ModeStatic, ...]
    val: np.ndarray                     # (S_mode,) f32 host layout
    idx: np.ndarray                     # (S_mode, N) i32
    alpha: np.ndarray                   # (S_mode, N) i32, -1 dead
    lrow: np.ndarray                    # (S_mode,) i32, -1 dead
    relabel: tuple                      # N x (I_d,) device arrays
    mode: int
    dims: tuple[int, ...]
    config: ExecutionConfig
    stats: StreamStats

    @property
    def nmodes(self) -> int:
        return len(self.dims)

    def replace(self, **kw) -> "StreamState":
        return dataclasses.replace(self, **kw)


def _host_lrow(plan, idx: np.ndarray, alpha: np.ndarray,
               d: int) -> np.ndarray:
    """Host-side ``compute_lrow``: identical integers to the device path
    (relabel lookup mod rows_pp for alive slots, -1 for pads)."""
    alive = alpha[:, d] >= 0
    rel = plan.row_relabel[idx[:, d]]
    return np.where(alive, (rel % plan.rows_pp).astype(np.int32),
                    np.int32(-1))


def stream_init(tensor, config: ExecutionConfig | None = None,
                start_mode: int = 0, *, cache=None) -> StreamState:
    """Build the host-resident streaming state for ``tensor``.

    Same input contract as ``engine.init`` (prebuilt
    :class:`~repro.core.flycoo.FlycooTensor` or raw COO triple, optionally
    through a :class:`~repro.core.plancache.PlanCache`), but the layout is
    materialized HOST-side at the start mode's natural size — the device
    never sees more than the chunk ring.
    """
    config = config or ExecutionConfig()
    with span("stream.init", start_mode=start_mode) as sp:
        tensor = _as_flycoo(tensor, config, cache=cache)
        n = tensor.nmodes
        if not 0 <= start_mode < n:
            raise ValueError(
                f"start_mode {start_mode} out of range for {n} modes")
        statics = tuple(mode_static_from_plan(p) for p in tensor.plans)
        plan = plan_stream_cached(tensor, config, cache=cache)
        sp.set("total_chunks", plan.total_chunks)
        sp.set("target_slots", plan.target_slots)

        base = tensor.plans[start_mode]
        s = base.padded_nnz
        val = np.zeros(s, dtype=np.float32)
        idx = np.zeros((s, n), dtype=np.int32)
        alpha = np.full((s, n), -1, dtype=np.int32)
        val[base.slot_of_elem] = tensor.values
        idx[base.slot_of_elem] = tensor.indices
        for d in range(n):
            alpha[base.slot_of_elem, d] = \
                tensor.plans[d].slot_of_elem.astype(np.int32)

        return StreamState(
            tensor=tensor, plan=plan, statics=statics,
            val=val, idx=idx, alpha=alpha,
            lrow=_host_lrow(base, idx, alpha, start_mode),
            relabel=tuple(jnp.asarray(p.row_relabel) for p in tensor.plans),
            mode=int(start_mode), dims=tensor.dims, config=config,
            stats=StreamStats())


# --------------------------------------------------------------------------
# Per-chunk device step (one jitted program per mode).
# --------------------------------------------------------------------------
def _step_fn(d: int, lplan: ModeStatic, config: ExecutionConfig):
    """Jitted chunk step: backend EC under the chunk-local plan, then an
    ascending full-tile ``dynamic_update_slice`` at the (traced) chunk row
    offset — one trace serves every chunk of the mode."""
    key = ("stream_ec", d, lplan, config)
    fn = _JIT_CACHE.get(key)
    if fn is None:
        backend = get_backend(config)

        def run(acc, chunk, factors, row0):
            TRACE_COUNTS["stream_ec"] += 1  # trace-time side effect
            out_rel = backend(dict(chunk), tuple(factors), d, plan=lplan,
                              config=config)
            return lax.dynamic_update_slice(
                acc, out_rel.astype(acc.dtype), (row0, 0))

        donate = (0,) if config.resolve_donate() else ()
        fn = _JIT_CACHE[key] = jax.jit(run, donate_argnums=donate)
    return fn


def _chunk_host_arrays(state: StreamState, d: int, c: int,
                       tables) -> dict[str, np.ndarray]:
    """Slice chunk ``c`` out of the host layout, padded to the mode's
    uniform chunk shape: pad slots carry ``val=0, lrow=-1`` and zeroed
    dedup tables (``nuniq=0`` -> the fused kernel issues no DMAs), pad
    blocks repeat the last real local partition — the ``engine.dist``
    device-padding pattern, per chunk instead of per device."""
    cs = state.plan.chunks[d]
    n = state.nmodes
    p = cs.block_p
    _, _, b0, b1 = cs.bounds(c)
    s0, s1 = b0 * p, b1 * p
    m = s1 - s0
    s = cs.chunk_slots
    val = np.zeros(s, dtype=np.float32)
    val[:m] = state.val[s0:s1]
    idx = np.zeros((s, n), dtype=np.int32)
    idx[:m] = state.idx[s0:s1]
    lrow = np.full(s, -1, dtype=np.int32)
    lrow[:m] = state.lrow[s0:s1]
    chunk = {"val": val, "idx": idx, "lrow": lrow,
             "bpart": chunk_bpart(state.tensor.plans[d], cs, c)}
    if tables is not None:
        uidx, upos, nuniq = tables
        cu = np.zeros((n - 1, s), dtype=np.int32)
        cu[:, :m] = uidx[:, s0:s1]
        cp = np.zeros((s, n - 1), dtype=np.int32)
        cp[:m] = upos[s0:s1]
        cn = np.zeros((n - 1, cs.chunk_blocks), dtype=np.int32)
        cn[:, :b1 - b0] = nuniq[:, b0:b1]
        chunk.update(uidx=cu, upos=cp, nuniq=cn)
    return chunk


def _mode_tables(state: StreamState, d: int):
    """Full-mode dedup tables when the configured backend consumes them
    (lazy, memoized on the tensor), else ``None``."""
    if not state.plan.tables:
        return None
    return (state.tensor.dedup_tables(d) if state.config.dedup
            else state.tensor.trivial_dedup_tables(d))


# --------------------------------------------------------------------------
# stream_mttkrp: one mode, chunk ring + host-side remap reassembly.
# --------------------------------------------------------------------------
def _upload(host: dict, mode: int, chunk: int, policy,
            stats: StreamStats) -> dict:
    """Place one chunk's host arrays on device, with bounded
    retry-with-backoff (seeded jitter) on *transient* transfer failures
    when a ladder policy is active. Non-transient failures (OOM, compile)
    propagate to the mode-level ladder."""
    attempt = 0
    while True:
        try:
            cz = _chaos.active()
            if cz is not None:
                cz.on_upload(mode, chunk, attempt)
            return {key: jax.device_put(a) for key, a in host.items()}
        except Exception as exc:
            if (policy is None or classify(exc) != "transient"
                    or attempt >= policy.max_retries):
                raise
            stats.upload_retries += 1
            record_retry("stream.upload", attempt,
                         backoff_delay(policy, attempt,
                                       token=("upload", mode, chunk)),
                         mode=mode, chunk=chunk)
            attempt += 1


def _with_config(state: StreamState,
                 config: ExecutionConfig) -> StreamState:
    """Rebuild the chunk plan under a degraded config. Safe mid-rotation:
    a failed mode attempt mutates neither the host layout nor the factors
    (the accumulator and next-mode fragments it built are local), and the
    chunk plan is derived purely from ``tensor`` + ``config``. Goes
    through the plan-cache structural tier: a degraded replan whose
    (structure, budget) point was chunked before is a cache hit."""
    return state.replace(config=config,
                         plan=plan_stream_cached(state.tensor, config))


def stream_mttkrp(state: StreamState, factors: Sequence[jax.Array],
                  mode: int | None = None, *, policy=None):
    """MTTKRP for the resident mode, streamed chunk-by-chunk; returns
    ``(out, next_state)`` with ``out (dims[mode], R)`` bitwise-identical
    to the resident ``engine.mttkrp``. The next-mode host layout (the
    Alg. 3 remap) is reassembled fragment-by-fragment while the device
    computes.

    With a ``policy`` (:class:`repro.resilience.LadderPolicy`) the mode
    rides the degradation ladder: an OOM halves the chunk budget and
    replans (up to ``max_budget_halvings`` — per-chunk results are
    partition-aligned, so any chunking concatenates bitwise-identically);
    a compile/lowering failure steps the backend down
    ``BACKEND_LADDER`` and replans (dedup tables follow the backend).
    The degraded config rides the returned state — later modes inherit
    it. Every transition is a ``resilience_degradations`` counter + span.
    """
    halvings = steps = 0
    while True:
        try:
            return _stream_mode_once(state, factors, mode, policy)
        except Exception as exc:
            if policy is None:
                raise
            kind = classify(exc)
            if kind == "oom" and halvings < policy.max_budget_halvings:
                cur = state.plan.target_slots
                new = max(state.config.block_p, cur // 2)
                if new >= cur:
                    raise
                halvings += 1
                state.stats.budget_halvings += 1
                record_degradation("oom", cur, new,
                                   site="stream.chunk_budget",
                                   mode=state.mode)
                state = _with_config(
                    state,
                    dataclasses.replace(state.config, chunk_nnz=new))
                continue
            if kind == "compile" and steps < policy.max_backend_steps:
                nb = next_backend(state.config.backend)
                if nb is None:
                    raise
                steps += 1
                state.stats.backend_steps += 1
                record_degradation("compile", state.config.backend, nb,
                                   site="stream.backend", mode=state.mode)
                state = _with_config(
                    state,
                    dataclasses.replace(state.config, backend=nb))
                continue
            raise


def _stream_mode_once(state: StreamState, factors: Sequence[jax.Array],
                      mode: int | None, policy):
    if mode is not None and mode != state.mode:
        raise ValueError(
            f"state holds the mode-{state.mode} layout; cannot compute "
            f"mode {mode} without rotating (use stream_all_modes)")
    d = state.mode
    n = state.nmodes
    nxt = (d + 1) % n
    cs = state.plan.chunks[d]
    st = state.statics[d]
    rows_pp = st.rows_pp
    rank = factors[0].shape[1]
    config = state.config
    stats = state.stats
    step = _step_fn(d, state.plan.lstatics[d], config)
    tables = _mode_tables(state, d)
    factors = tuple(factors)

    # Over-allocated accumulator: chunk c's full (chunk_kappa * rows_pp)
    # tile lands at row part_start[c] * rows_pp; later chunks overwrite the
    # previous chunk's overhang, the final slice drops the last one's.
    acc = jnp.zeros(((st.kappa + cs.chunk_kappa) * rows_pp, rank),
                    config.accum_dtype())

    # Next-mode host layout, filled fragment-by-fragment (Alg. 3, host).
    snxt = state.statics[nxt].padded_nnz
    nval = np.zeros(snxt, dtype=np.float32)
    nidx = np.zeros((snxt, n), dtype=np.int32)
    nalpha = np.full((snxt, n), -1, dtype=np.int32)

    cz = _chaos.active()
    if cz is not None:
        cz.on_dispatch(config.backend)
    before = dataclasses.replace(stats)
    ring: dict[int, dict] = {}
    chunk_bytes = 0
    with span("stream.mode", mode=d, nchunks=cs.nchunks):
        for c in range(cs.nchunks):
            # prefetch: keep chunks [c, c + ring) resident/uploading —
            # chunk c+1's H2D overlaps chunk c's kernel (async dispatch)
            for k in range(c, min(c + config.stream_ring, cs.nchunks)):
                if k not in ring:
                    with span("stream.upload", chunk=k,
                              prefetch=k > c) as up:
                        host = _chunk_host_arrays(state, d, k, tables)
                        ring[k] = _upload(host, d, k, policy, stats)
                        nbytes = sum(a.nbytes for a in host.values())
                        up.set("bytes", nbytes)
                    if not chunk_bytes:
                        chunk_bytes = nbytes
                    stats.h2d_bytes += nbytes
                    stats.uploads += 1
                    if k > c:
                        stats.overlapped_uploads += 1
            stats.peak_ring_chunks = max(stats.peak_ring_chunks, len(ring))
            stats.peak_ring_bytes = max(stats.peak_ring_bytes,
                                        len(ring) * chunk_bytes)
            if cz is not None:
                cz.on_chunk_compute(d, c)
            dev = ring.pop(c)
            DISPATCH_COUNTS["stream_ec"] += 1
            with span("stream.compute", chunk=c):
                acc = step(acc, dev, factors,
                           np.int32(cs.part_start[c] * rows_pp))
            del dev  # ring slot freed once the dispatched step completes

            # host-side remap fragment for chunk c (real slots only) while
            # the device crunches: scatter this chunk's alive elements into
            # the next-mode layout through alpha[:, nxt]
            with span("stream.remap", chunk=c):
                _, _, b0, b1 = cs.bounds(c)
                sl = slice(b0 * cs.block_p, b1 * cs.block_p)
                av = state.alpha[sl]
                alive = av[:, d] >= 0
                dst = av[alive, nxt]
                nval[dst] = state.val[sl][alive]
                nidx[dst] = state.idx[sl][alive]
                nalpha[dst] = av[alive]
            stats.fragment_bytes += int(alive.sum()) * row_bytes(n)
            stats.chunks_streamed += 1

        out_rel = acc[: st.kappa * rows_pp]
        out = jnp.take(out_rel, state.relabel[d], axis=0)
    stats.modes_streamed += 1
    _mirror_stats(stats, before)
    nxt_plan = state.tensor.plans[nxt]
    return out, state.replace(
        val=nval, idx=nidx, alpha=nalpha,
        lrow=_host_lrow(nxt_plan, nidx, nalpha, nxt), mode=nxt)


def stream_all_modes(state: StreamState, factors: Sequence[jax.Array], *,
                     fold=None, carry=None, policy=None):
    """spMTTKRP along all N modes, streamed (one host loop — the chunk
    residency *is* the host loop, unlike the resident engine's scan).

    Same contract as ``engine.all_modes``: outputs indexed by mode from
    any start mode; without ``fold`` returns ``(outs, next_state)``, with
    ``fold`` returns ``(outs, next_state, factors, carry)`` — the hook
    runs right after each mode's output (Gauss-Seidel ALS order), on the
    device-resident factors. ``policy`` enables the per-mode degradation
    ladder (see :func:`stream_mttkrp`); a degraded config sticks for the
    rest of the rotation via the returned state."""
    n = state.nmodes
    factors = tuple(factors)
    outs: list = [None] * n
    for _ in range(n):
        d = state.mode
        out, state = stream_mttkrp(state, factors, policy=policy)
        if fold is not None:
            factors, carry = fold(d, out, factors, carry)
        outs[d] = out
    if fold is None:
        return outs, state
    return outs, state, list(factors), carry


# --------------------------------------------------------------------------
# cp_als_stream: out-of-core CPD-ALS.
# --------------------------------------------------------------------------
def cp_als_stream(tensor, rank: int, iters: int = 10, key=None,
                  config: ExecutionConfig | None = None,
                  track_fit: bool = True, *, cache=None,
                  start_mode: int = 0, ladder=None, checkpoint=None,
                  checkpoint_every: int = 1, resume: bool = False):
    """CPD-ALS with the streamed engine — same sweep semantics as
    ``core.cpd.cp_als`` (Gauss-Seidel fold after each mode, fit via the
    sparse-CPD identity), for tensors whose FLYCOO layout exceeds device
    memory. Factor matrices stay device-resident; element data streams.

    Resilience (mirrors ``cp_als``):

    * ``ladder``: ``True`` / a :class:`repro.resilience.LadderPolicy`
      enables the degradation ladder (backend rungs, chunk-budget halving
      on OOM, upload retry-with-backoff) plus the per-sweep NaN guard
      with rollback + ridge-recovery replay.
    * ``checkpoint``: a directory or :class:`repro.resilience.
      SnapshotStore`; every ``checkpoint_every`` completed sweeps the
      ``(factors, lam, fits)`` state is snapshotted atomically under the
      problem fingerprint. ``resume=True`` restores the newest intact
      snapshot *for the same problem* and replays only the remaining
      sweeps — bitwise-identical final factors vs an uninterrupted run
      (at a sweep boundary the layout has rotated back to its start
      arrangement, so factors + lam are the complete dynamic state).
    """
    # lazy: core.cpd imports repro.engine at module scope
    from repro.core.cpd import (CPDResult, _als_fold, _als_fold_recovery,
                                _fit, init_factors)

    config = config or ExecutionConfig()
    policy = resolve_policy(ladder)
    if key is None:
        key = jax.random.PRNGKey(0)
    state = stream_init(tensor, config, start_mode, cache=cache)
    n = state.nmodes
    factors = tuple(init_factors(key, state.dims, rank))
    lam = jnp.ones((rank,), jnp.float32)
    norm_x_sq = float(
        np.sum(state.tensor.values.astype(np.float64) ** 2))

    store = as_store(checkpoint)
    fits: list = []
    first = 0
    fp = None
    if store is not None:
        fp = fingerprint(state.tensor.indices, state.tensor.values,
                         state.dims, rank, config=config, key=key,
                         start_mode=start_mode, extra="stream")
        if resume:
            snap = store.latest(fp)
            if snap is not None:
                factors = tuple(jnp.asarray(f) for f in snap.factors)
                lam = jnp.asarray(snap.lam)
                fits = list(snap.fits)
                first = snap.sweep
    for i in range(first, iters):
        cz = _chaos.active()
        if cz is not None:
            cz.maybe_kill(i)
        prev = (factors, lam)
        with span("cpd.sweep", sweep=i, streamed=True) as sp:
            outs, state, factors, lam = stream_all_modes(
                state, factors, fold=_als_fold, carry=lam, policy=policy)
            if cz is not None:
                factors = tuple(cz.mangle_factors(i, factors))
            if policy is not None and not _guard.all_finite(factors, lam):
                # roll back and replay the sweep under the stronger ridge:
                # the layout is bitwise back at its start arrangement, so
                # the replay sees exactly the pre-sweep problem.
                _guard.record_recovery("nan_rollback", sweep=i,
                                       streamed=True)
                factors, lam = prev
                outs, state, factors, lam = stream_all_modes(
                    state, factors, fold=_als_fold_recovery, carry=lam,
                    policy=policy)
            if track_fit:
                fit = _fit(norm_x_sq, outs[n - 1], factors, lam)
                fits.append(fit)
                sp.set("fit", float(fit))
                _obs_gauge("cpd_fit", "latest ALS fit per tier").set(
                    "streamed", float(fit))
        if store is not None and ((i + 1) % checkpoint_every == 0
                                  or i + 1 == iters):
            store.save(fp, i + 1, [np.asarray(f) for f in factors],
                       np.asarray(lam), fits)
    return CPDResult(factors=list(factors), lam=lam, fits=fits)


__all__ = ["StreamPlan", "StreamState", "StreamStats", "plan_stream",
           "plan_stream_cached", "stream_init", "stream_mttkrp",
           "stream_all_modes",
           "cp_als_stream", "resident_bytes", "resolve_chunk_slots",
           "stream_transfer_model", "stream_fixed_bytes", "bytes_per_slot",
           "chunk_device_bytes", "DEFAULT_CHUNK_SLOTS"]
