"""Backend registry for the spMTTKRP elementwise computation (Alg. 2/4).

Replaces the old string-typed ``backend=`` kwarg plumbing: a backend is a
named entry in ``BACKENDS`` selected by ``ExecutionConfig.backend``. Every
backend implements the same contract,

    ec(layout, factors, mode, plan=ModeStatic, config=ExecutionConfig)
        -> out_rel  (plan.relabeled_rows, R) f32

where ``layout`` holds the mode-``mode`` kernel layout slices
(``val (S_d,)``, ``idx (S_d, N)``, ``lrow (S_d,)``) and the result lives in
relabeled row space (caller un-relabels with the mode's relabel table).

Registered backends:
  xla     fused segment-sum over the relabeled row space (default)
  pallas  the fused one-hot-MXU Pallas kernel (interpret off-TPU)
  ref     unfused oracle-shaped path: materialize the (S, R) Hadamard
          partials, then segment-sum — the baseline the paper's fusion
          argument (Fig. 7) is measured against
"""
from __future__ import annotations

from typing import Callable, Protocol

import jax
import jax.numpy as jnp

from .config import ExecutionConfig
from .state import ModeStatic


class ECBackend(Protocol):
    def __call__(self, layout: dict, factors: tuple, mode: int, *,
                 plan: ModeStatic, config: ExecutionConfig) -> jax.Array: ...


BACKENDS: dict[str, ECBackend] = {}


def register_backend(name: str) -> Callable[[ECBackend], ECBackend]:
    """Decorator: add an elementwise-computation backend to the registry."""

    def deco(fn: ECBackend) -> ECBackend:
        BACKENDS[name] = fn
        return fn

    return deco


def get_backend(config_or_name: ExecutionConfig | str) -> ECBackend:
    name = (config_or_name.backend
            if isinstance(config_or_name, ExecutionConfig)
            else config_or_name)
    try:
        return BACKENDS[name]
    except KeyError:
        raise KeyError(
            f"unknown engine backend {name!r}; registered: "
            f"{sorted(BACKENDS)}") from None


# --------------------------------------------------------------------------
# Shared pieces.
# --------------------------------------------------------------------------
def compute_lrow(idx_d, row_relabel_d, rows_pp: int, alive):
    """Local row ids in the owning partition (relabel table lookup)."""
    rel = jnp.take(row_relabel_d, idx_d, axis=0, mode="fill", fill_value=0)
    return jnp.where(alive, rel % rows_pp, -1)


def _gather_partials(layout, factors, mode: int, accum_dtype):
    """ell(r) = val * prod_{w != d} Y_w[c_w, r]  (Alg. 2 lines 7-13)."""
    val, idx = layout["val"], layout["idx"]
    partials = val[:, None].astype(accum_dtype)
    for w, f in enumerate(factors):
        if w == mode:
            continue
        partials = partials * jnp.take(f, idx[:, w], axis=0, mode="fill",
                                       fill_value=0.0).astype(accum_dtype)
    return partials


def _segment_ids(layout, plan: ModeStatic):
    """Global relabeled row per slot; pads (lrow == -1) -> dump row 0."""
    stride = plan.blocks_pp * plan.block_p
    slot = jnp.arange(layout["val"].shape[0], dtype=jnp.int32)
    part = slot // stride
    lrow = layout["lrow"]
    return jnp.where(lrow < 0, 0, part * plan.rows_pp + lrow)


# --------------------------------------------------------------------------
# Backends.
# --------------------------------------------------------------------------
@register_backend("xla")
def ec_xla(layout, factors, mode: int, *, plan: ModeStatic,
           config: ExecutionConfig) -> jax.Array:
    """Fused XLA path: gather-multiply feeding segment-sum directly, so the
    (S, R) partials never round-trip HBM as a named intermediate."""
    partials = _gather_partials(layout, factors, mode, config.accum_dtype())
    gid = _segment_ids(layout, plan)
    return jax.ops.segment_sum(partials, gid,
                               num_segments=plan.relabeled_rows)


@register_backend("ref")
def ec_ref(layout, factors, mode: int, *, plan: ModeStatic,
           config: ExecutionConfig) -> jax.Array:
    """Unfused baseline: materialize partials, then reduce (paper Fig. 7's
    comparison point; also the oracle for backend parity tests)."""
    partials = _gather_partials(layout, factors, mode, config.accum_dtype())
    partials = jnp.asarray(partials)  # named intermediate, kept live
    gid = _segment_ids(layout, plan)
    return jax.ops.segment_sum(partials, gid,
                               num_segments=plan.relabeled_rows)


@register_backend("pallas")
def ec_pallas(layout, factors, mode: int, *, plan: ModeStatic,
              config: ExecutionConfig) -> jax.Array:
    """Fused Pallas TPU kernel (one-hot MXU segment reduction in VMEM)."""
    from repro.kernels import ops as kops

    gathered = jnp.stack(
        [jnp.take(f, layout["idx"][:, w], axis=0, mode="fill",
                  fill_value=0.0)
         for w, f in enumerate(factors) if w != mode],
        axis=1)  # (S, N-1, R)
    return kops.mttkrp_fused(
        gathered,
        layout["val"],
        layout["lrow"],
        kappa=plan.kappa,
        rows_pp=plan.rows_pp,
        blocks_pp=plan.blocks_pp,
        block_p=plan.block_p,
        interpret=config.resolve_interpret(),
    )


__all__ = ["BACKENDS", "register_backend", "get_backend", "compute_lrow",
           "ec_xla", "ec_ref", "ec_pallas"]
