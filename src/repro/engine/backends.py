"""Backend registry for the spMTTKRP elementwise computation (Alg. 2/4).

Replaces the old string-typed ``backend=`` kwarg plumbing: a backend is a
named entry in ``BACKENDS`` selected by ``ExecutionConfig.backend``. Every
backend implements the same contract,

    ec(layout, factors, mode, plan=ModeStatic, config=ExecutionConfig)
        -> out_rel  (plan.relabeled_rows, R) f32

where ``layout`` holds the mode-``mode`` kernel layout slices
(``val (S_d,)``, ``idx (S_d, N)``, ``lrow (S_d,)``, and — when the caller
has it resident, as the engine scan does — ``alpha (S_d, N)``) and the
result lives in relabeled row space (caller un-relabels with the mode's
relabel table). Under the ``compact`` block schedule (``plan.schedule ==
"compact"``) the layout additionally carries the per-mode schedule tables
from ``EngineState.sched``: the ``bpart (nblocks,)`` block->partition
descriptor (required — slot->partition is no longer a fixed stride) and
the in-block dedup tables ``uidx``/``upos``/``nuniq`` consumed by the
fused Pallas pipelines. The same contract serves the single-device scan
(``engine.api``) and the per-device shards under ``shard_map``
(``engine.dist``).

A backend may additionally expose a ``fused_remap`` attribute,

    fused_remap(layout, factors, mode, plan=, config=, smax=, next_mode=)
        -> (out_rel, (nval (smax,), nidx (smax, N), nalpha (smax, N)))

which performs EC *and* the Alg. 3 remap scatter in one kernel pass; the
engine's scan step delegates to it (unless ``config.fuse_remap`` is off)
instead of issuing three separate full-``S_max`` XLA scatters.

Registered backends:
  ============  =========================================================
  xla           fused segment-sum over the relabeled row space (default);
                segment ids come from the block->partition descriptor
                under the compact schedule, a fixed stride under rect
  pallas        one-hot-MXU Pallas kernel fed by an XLA-materialized
                ``(S, N-1, R)`` HBM gather — the fusion comparison
                baseline (interpret off-TPU). Compact schedule: the 1-D
                descriptor-driven grid (``mttkrp_fused_compact``)
  pallas_fused  zero-HBM-intermediate Pallas pipeline: factor rows are
                gathered *inside* the kernel grid (scalar-prefetched
                indices + double-buffered ANY->VMEM row DMA) and the
                Alg. 3 remap scatter is emitted by the same pass via
                ``fused_remap``. Compact schedule: the gather is
                *dedup-aware* — each block DMAs only its ``U <= P``
                unique factor rows (plan-sorted ``uidx``/``nuniq``) and
                the EC body routes slots through ``upos`` with a one-hot
                MXU stage select
  ref           unfused oracle-shaped path: materialize the (S, R)
                Hadamard partials, then segment-sum — the baseline the
                paper's fusion argument (Fig. 7) is measured against
  ============  =========================================================

Every backend serves both block schedules (``plan.schedule``): the
``compact`` grid walks only real blocks (a ``(nblocks,)`` descriptor
names each block's partition), ``rect`` is the padded baseline.
"""
from __future__ import annotations

from typing import Callable, Protocol

import jax
import jax.numpy as jnp

from .config import ExecutionConfig
from .state import ModeStatic


class ECBackend(Protocol):
    def __call__(self, layout: dict, factors: tuple, mode: int, *,
                 plan: ModeStatic, config: ExecutionConfig) -> jax.Array: ...


BACKENDS: dict[str, ECBackend] = {}


def register_backend(name: str) -> Callable[[ECBackend], ECBackend]:
    """Decorator: add an elementwise-computation backend to the registry."""

    def deco(fn: ECBackend) -> ECBackend:
        BACKENDS[name] = fn
        return fn

    return deco


def get_backend(config_or_name: ExecutionConfig | str) -> ECBackend:
    name = (config_or_name.backend
            if isinstance(config_or_name, ExecutionConfig)
            else config_or_name)
    try:
        return BACKENDS[name]
    except KeyError:
        raise KeyError(
            f"unknown engine backend {name!r}; registered: "
            f"{sorted(BACKENDS)}") from None


# --------------------------------------------------------------------------
# Shared pieces.
# --------------------------------------------------------------------------
def compute_lrow(idx_d, row_relabel_d, rows_pp: int, alive):
    """Local row ids in the owning partition (relabel table lookup)."""
    rel = jnp.take(row_relabel_d, idx_d, axis=0, mode="fill", fill_value=0)
    return jnp.where(alive, rel % rows_pp, -1)


def _gather_partials(layout, factors, mode: int, accum_dtype):
    """ell(r) = val * prod_{w != d} Y_w[c_w, r]  (Alg. 2 lines 7-13).

    Pad slots are masked via ``lrow == -1`` rather than relying on their
    ``val`` being zero: pads carry in-bounds ``idx = 0``, so an unmasked
    product would dump ``val * prod Y_w[0]`` into segment 0 (the Pallas
    kernels get this for free from the one-hot comparison).
    """
    val, idx = layout["val"], layout["idx"]
    partials = val[:, None].astype(accum_dtype)
    for w, f in enumerate(factors):
        if w == mode:
            continue
        partials = partials * jnp.take(f, idx[:, w], axis=0, mode="fill",
                                       fill_value=0.0).astype(accum_dtype)
    return jnp.where((layout["lrow"] >= 0)[:, None], partials, 0)


def _segment_ids(layout, plan: ModeStatic):
    """Global relabeled row per slot; pads (lrow == -1) -> dump row 0.

    The owning partition is a fixed slot stride under the ``rect``
    schedule; under ``compact`` it is the block->partition descriptor
    lookup (the layout must carry ``bpart``)."""
    slot = jnp.arange(layout["val"].shape[0], dtype=jnp.int32)
    if plan.schedule == "compact":
        if layout.get("bpart") is None:
            raise KeyError(
                "compact-schedule layout needs the 'bpart' block->partition "
                "descriptor (see EngineState.sched)")
        part = jnp.take(layout["bpart"], slot // plan.block_p, axis=0)
    else:
        part = slot // (plan.blocks_pp * plan.block_p)
    lrow = layout["lrow"]
    return jnp.where(lrow < 0, 0, part * plan.rows_pp + lrow)


# --------------------------------------------------------------------------
# Backends.
# --------------------------------------------------------------------------
@register_backend("xla")
def ec_xla(layout, factors, mode: int, *, plan: ModeStatic,
           config: ExecutionConfig) -> jax.Array:
    """Fused XLA path: gather-multiply feeding segment-sum directly, so the
    (S, R) partials never round-trip HBM as a named intermediate."""
    partials = _gather_partials(layout, factors, mode, config.accum_dtype())
    gid = _segment_ids(layout, plan)
    return jax.ops.segment_sum(partials, gid,
                               num_segments=plan.relabeled_rows)


@register_backend("ref")
def ec_ref(layout, factors, mode: int, *, plan: ModeStatic,
           config: ExecutionConfig) -> jax.Array:
    """Unfused baseline: materialize partials, then reduce (paper Fig. 7's
    comparison point; also the oracle for backend parity tests)."""
    partials = _gather_partials(layout, factors, mode, config.accum_dtype())
    partials = jnp.asarray(partials)  # named intermediate, kept live
    gid = _segment_ids(layout, plan)
    return jax.ops.segment_sum(partials, gid,
                               num_segments=plan.relabeled_rows)


@register_backend("pallas")
def ec_pallas(layout, factors, mode: int, *, plan: ModeStatic,
              config: ExecutionConfig) -> jax.Array:
    """Fused Pallas TPU kernel (one-hot MXU segment reduction in VMEM)."""
    from repro.kernels import ops as kops

    gathered = jnp.stack(
        [jnp.take(f, layout["idx"][:, w], axis=0, mode="fill",
                  fill_value=0.0)
         for w, f in enumerate(factors) if w != mode],
        axis=1)  # (S, N-1, R)
    if plan.schedule == "compact":
        return kops.mttkrp_fused_compact(
            gathered,
            layout["val"],
            layout["lrow"],
            layout["bpart"],
            kappa=plan.kappa,
            rows_pp=plan.rows_pp,
            nblocks=plan.nblocks,
            block_p=plan.block_p,
            interpret=config.resolve_interpret(),
        )
    return kops.mttkrp_fused(
        gathered,
        layout["val"],
        layout["lrow"],
        kappa=plan.kappa,
        rows_pp=plan.rows_pp,
        blocks_pp=plan.blocks_pp,
        block_p=plan.block_p,
        interpret=config.resolve_interpret(),
    )


def _fused_lidx(layout, nmodes: int, mode: int):
    """(N-1, S) scalar-prefetch table: per slot, the row of each *input*
    factor to gather (pads hold in-bounds 0 — killed later by the one-hot
    / dst < 0, so the garbage gather is harmless)."""
    idx = layout["idx"]
    return jnp.stack([idx[:, w] for w in range(nmodes) if w != mode]
                     ).astype(jnp.int32)


@register_backend("pallas_fused")
def ec_pallas_fused(layout, factors, mode: int, *, plan: ModeStatic,
                    config: ExecutionConfig) -> jax.Array:
    """Zero-HBM-intermediate Pallas pipeline: the factor-row gather happens
    inside the kernel grid (scalar-prefetched indices, double-buffered
    ANY->VMEM row DMA), so no ``(S, N-1, R)`` intermediate is ever
    materialized. Under the compact schedule the gather is dedup-aware:
    each block DMAs only its unique factor rows. This entry is the
    plain-EC contract used under ``shard_map`` too; the single-device scan
    step upgrades to ``fused_remap`` below."""
    from repro.kernels import ops as kops

    inputs = tuple(f for w, f in enumerate(factors) if w != mode)
    if plan.schedule == "compact":
        return kops.mttkrp_fused_gather_compact(
            layout["val"],
            layout["lrow"],
            layout["upos"],
            layout["bpart"],
            layout["uidx"],
            layout["nuniq"],
            inputs,
            kappa=plan.kappa,
            rows_pp=plan.rows_pp,
            nblocks=plan.nblocks,
            block_p=plan.block_p,
            interpret=config.resolve_interpret(),
        )
    return kops.mttkrp_fused_gather(
        layout["val"],
        layout["lrow"],
        _fused_lidx(layout, len(factors), mode),
        inputs,
        kappa=plan.kappa,
        rows_pp=plan.rows_pp,
        blocks_pp=plan.blocks_pp,
        block_p=plan.block_p,
        interpret=config.resolve_interpret(),
    )


def _pallas_fused_remap(layout, factors, mode: int, *, plan: ModeStatic,
                        config: ExecutionConfig, smax: int, next_mode: int):
    """EC + Alg. 3 remap in ONE Pallas pass (see module docstring). The
    remap destinations are ``alpha[:, next_mode]`` verbatim: alive slots
    hold their next-layout slot, pads hold -1 and are skipped in-kernel."""
    from repro.kernels import ops as kops

    inputs = tuple(f for w, f in enumerate(factors) if w != mode)
    if plan.schedule == "compact":
        out_rel, nval, nidx, nalpha = kops.mttkrp_fused_remap_compact(
            layout["val"],
            layout["idx"],
            layout["alpha"],
            layout["lrow"],
            layout["upos"],
            layout["bpart"],
            layout["uidx"],
            layout["nuniq"],
            inputs,
            kappa=plan.kappa,
            rows_pp=plan.rows_pp,
            nblocks=plan.nblocks,
            block_p=plan.block_p,
            smax=smax,
            next_mode=next_mode,
            interpret=config.resolve_interpret(),
        )
        return out_rel, (nval, nidx, nalpha)
    out_rel, nval, nidx, nalpha = kops.mttkrp_fused_remap(
        layout["val"],
        layout["idx"],
        layout["alpha"],
        layout["lrow"],
        _fused_lidx(layout, len(factors), mode),
        inputs,
        kappa=plan.kappa,
        rows_pp=plan.rows_pp,
        blocks_pp=plan.blocks_pp,
        block_p=plan.block_p,
        smax=smax,
        next_mode=next_mode,
        interpret=config.resolve_interpret(),
    )
    return out_rel, (nval, nidx, nalpha)


ec_pallas_fused.fused_remap = _pallas_fused_remap
# engine.init builds the per-mode dedup tables (EngineState.sched) only
# for backends that declare they consume them.
ec_pallas_fused.needs_dedup = True


__all__ = ["BACKENDS", "register_backend", "get_backend", "compute_lrow",
           "ec_xla", "ec_ref", "ec_pallas", "ec_pallas_fused"]
