"""Functional spMTTKRP engine (paper Alg. 5 as pure functions).

Public surface:

  ExecutionConfig                  frozen, hashable execution policy
  EngineState                      pytree layout state (scan/shard_map ready)
  init(tensor, config)             -> EngineState
  mttkrp(state, factors[, mode])   -> (out, EngineState)
  all_modes(state, factors)        -> (outs_by_mode, EngineState), ONE
                                      jitted lax.scan over the mode rotation
  BACKENDS / register_backend / get_backend
                                   elementwise-computation backend registry
                                   (``xla`` | ``pallas`` | ``pallas_fused``
                                   | ``ref``; replaces string-typed
                                   ``backend=`` kwargs). ``pallas_fused`` is
                                   the zero-HBM-intermediate pipeline: the
                                   factor gather runs inside the kernel grid
                                   and the Alg. 3 remap scatter is fused
                                   into the same pass (``fuse_remap`` knob).
                                   Every backend serves both block
                                   schedules: ``schedule="compact"`` (the
                                   default — descriptor-driven grid of real
                                   blocks + in-block factor-row dedup) and
                                   ``"rect"`` (the padded baseline)
  dist (DistConfig / shard_state / dist_mttkrp / dist_all_modes)
                                   multi-device subsystem: EngineState sharded
                                   under shard_map, remap exchanged via a
                                   static collective_permute schedule
  stream (StreamPlan / StreamState / stream_init / stream_mttkrp /
          stream_all_modes / cp_als_stream)
                                   out-of-core residency tier for tensors
                                   larger than device memory: the FLYCOO
                                   layout lives host-side and visits the
                                   device as a double-buffered ring of
                                   partition-aligned chunks
                                   (``stream_ring`` buffers, chunk k+1
                                   uploading while chunk k computes), each
                                   chunk served by the UNCHANGED backend
                                   contract — every backend row below works
                                   streamed, bitwise-identical to the
                                   resident engine; the Alg. 3 remap is
                                   reassembled host-side per chunk (the
                                   streaming analogue of dist's exchange)

Residency — which tier holds the element list:

  ``ExecutionConfig.residency`` / ``PlanSpec.residency`` picks it:
  ``"full"`` (classic device-resident engine), ``"stream"`` (the chunk
  ring), or ``"auto"`` — ``make_engine`` compares the resident footprint
  (``stream.resident_bytes``) against ``device_budget_bytes`` and streams
  exactly when the tensor does not fit. One budget drives everything:
  ``device_budget_bytes`` sizes the chunk ring (``chunk_nnz`` overrides),
  and — via ``derive_vmem_budget`` in ``PlanSpec.canonical()`` — the VMEM
  share that sizes row tiles (``rows_pp``), so the two tiers can never
  disagree about memory. The autotuner prices streamed specs with a
  transfer-bytes term (chunk H2D + remap fragments per hop), so tuned
  chunk sizes are chosen, not guessed.
  PlanSpec / PlanSpace / make_engine
                                   declarative plan+backend factory: one
                                   frozen spec naming every searchable knob
                                   (backend, schedule, block_p, kappa
                                   policy, rows_pp, vmem budget, dedup,
                                   fuse_remap, exchange), canonicalized and
                                   enumerable as a ``PlanSpace``;
                                   ``make_engine(tensor_or_coo, spec)``
                                   builds the FLYCOO layout (through the
                                   sparsity-signature ``PlanCache`` by
                                   default) and returns a ready
                                   ``EngineState`` — pass ``mesh=`` to get a
                                   sharded ``DistState`` instead
  autotune (analytic_cost / modeled_cost / autotune / hill_climb)
                                   cost-model-guided knob search over a
                                   PlanSpace: analytic nnz-histogram ranking
                                   prunes the space, exact modeled cost (pad
                                   slots + dedup DMA rows) picks the winner,
                                   optional measured greedy hill-climb;
                                   deterministic under a fixed seed and never
                                   worse than the default spec on modeled
                                   cost
  ExecutionConfig(dedup=False)     keeps the compact schedule but feeds the
                                   fused kernels trivial identity dedup
                                   tables — an autotunable knob for tensors
                                   whose blocks have no row reuse

Observability (``repro.obs``):

  Every layer is instrumented with hierarchical wall-clock spans —
  ``factory.make_engine`` (cache lookup -> per-mode ``plan.mode`` ->
  dedup tables -> device placement), ``autotune`` stages (analytic /
  exact / measured), ``engine.dispatch`` per jitted call, streamed
  ``stream.mode``/``stream.upload``/``stream.compute``/``stream.remap``
  per chunk, ``dist.shard_state`` + exchange-schedule build, and
  ``cpd.sweep`` with per-sweep fit. Tracing is OFF by default and free
  when off (a single ``is None`` test per span site); enable with
  ``repro.obs.enable()`` or ``REPRO_TRACE=1`` (``REPRO_TRACE=path.json``
  additionally writes a Perfetto-loadable Chrome trace at exit), then
  export with ``obs.write_chrome_trace(path)`` / summarize with
  ``obs.render_report()``.

  ``TRACE_COUNTS`` / ``DISPATCH_COUNTS`` (below) live on the
  ``repro.obs`` metrics registry as the ``engine_traces`` /
  ``engine_dispatches`` counters — same dict-style surface as before
  (``DISPATCH_COUNTS["all_modes"]``, ``reset_counters()``), but exported
  with every trace alongside the stream transfer counters, plan-cache
  outcome taxonomy, and CPD fit gauges. The span-derived streaming
  ``overlap_efficiency`` (``obs.stream_overlap_from_spans``) is the
  profiler-timeline cross-check of ``StreamStats.overlap_efficiency``.

Resilience (``repro.resilience``):

  Long runs survive the failures that used to kill them, and every
  recovery is observable — never silent:

  * **Degradation ladder** — ``ladder=True`` (or a ``LadderPolicy``) on
    ``make_engine`` / ``cp_als`` / ``cp_als_stream`` enables policy-driven
    fallback: a compile/lowering failure steps the backend down
    ``BACKEND_LADDER`` (``pallas_fused -> pallas -> xla -> ref``; every
    rung bitwise-identical), a resident-placement OOM drops residency
    ``full -> stream``, a streamed-chunk OOM halves ``chunk_nnz`` and
    replans (partition-aligned chunks make ANY chunking bitwise-equal),
    and transient ``device_put`` upload failures retry with bounded
    exponential backoff + seeded jitter (attempts surface in
    ``StreamStats.upload_retries``). Each transition lands on the obs
    registry as a ``resilience_degradations`` / ``resilience_retries``
    counter + span.
  * **Checkpoint/resume** — ``checkpoint=dir`` on ``cp_als`` /
    ``cp_als_stream`` writes atomic, checksummed sweep snapshots bound to
    the problem fingerprint; ``resume=True`` restores the newest intact
    one and continues bitwise-identically (at a sweep boundary
    ``(factors, lam)`` are the complete dynamic state). Corrupt blobs are
    quarantined and skipped, same as the ``PlanCache`` disk tier.
  * **NaN guard** — under a ladder policy each sweep is checked for
    NaN/Inf; a burst rolls the sweep back and replays it under a
    stronger ridge (``resilience_recoveries`` counter).
  * **Chaos** — ``REPRO_CHAOS="upload_fail=1,oom_chunk=3,..."`` installs
    deterministic seeded fault injectors through the
    stream/factory/plancache/dispatch hooks (``engine.dist`` dispatch
    included: ``exchange_fail=k``, ``device_lost=k``,
    ``dist_transient=k``); ``obs.resilience_report()`` pairs every
    injected fault with the resilience event that answered it (the CI
    chaos gate asserts ``unanswered == []``).
  * **Distributed resilience** — the ladder extends to the sharded tier.
    Sharded runs write the **v2 sharded snapshot** format: per-device
    factor shards keyed by row offset, plus the saving mesh's
    fingerprint (device count, axis shape, platform) and the
    ``DistConfig`` knobs inside the digest-covered meta. The *problem*
    fingerprint deliberately excludes the mesh, so ``resume=True`` on a
    **different** device count gathers the shards host-side and
    re-shards onto the current mesh — elastic restart, bitwise-equal
    final factors (device-major partition order makes the sweep
    mesh-independent). Dist-specific rungs: an exchange failure steps
    ``collective_permute -> all_gather`` (bitwise by the exchange
    parity guarantee); a device loss shrinks the mesh onto the
    survivors via ``dist.surviving_mesh`` (kappa-divisibility decides
    the survivor count), rebuilds ``DistState``, and rolls back to the
    latest snapshot — re-plan + re-shard, never silent; transient dist
    dispatch failures retry with the same seeded backoff as stream
    uploads (``resilience_retries["dist.dispatch"]``). ``REPRO_LADDER``
    installs an ambient policy from the environment, mirroring
    ``REPRO_CHAOS``.

Migration from the deprecated stateful executor:

  MTTKRPExecutor(t, backend=b)     -> s = engine.init(t, ExecutionConfig(backend=b))
  exe.step(factors)                -> out, s = engine.mttkrp(s, factors)
  exe.all_modes(factors)           -> outs, s = engine.all_modes(s, factors)
  exe.layout / exe.current_mode    -> s.val / s.idx / s.alpha / s.mode
"""
from .config import (ExecutionConfig, KAPPA_POLICIES, RESIDENCIES,
                     SCHEDULES, derive_vmem_budget,
                     platform_default_interpret)
from .state import (EngineState, ModeSched, ModeStatic,
                    mode_static_from_plan)
from .backends import (BACKENDS, register_backend, get_backend,
                       compute_lrow)
from .api import (init, mttkrp, all_modes, scan_jaxpr, reset_counters,
                  TRACE_COUNTS, DISPATCH_COUNTS, FoldFn)
from . import dist
from .dist import (DistConfig, DistState, ExchangeSchedule, shard_state,
                   dist_mttkrp, dist_all_modes, surviving_mesh)
from .factory import PlanSpec, PlanSpace, make_engine, SPACE_DIMS
from . import autotune
from . import stream
from .stream import (StreamPlan, StreamState, cp_als_stream, plan_stream,
                     plan_stream_cached, resident_bytes, stream_all_modes,
                     stream_init, stream_mttkrp)

__all__ = [
    "ExecutionConfig", "KAPPA_POLICIES", "SCHEDULES", "RESIDENCIES",
    "derive_vmem_budget",
    "platform_default_interpret", "EngineState", "ModeSched", "ModeStatic",
    "mode_static_from_plan", "BACKENDS", "register_backend", "get_backend",
    "compute_lrow", "init", "mttkrp", "all_modes", "scan_jaxpr",
    "reset_counters", "TRACE_COUNTS", "DISPATCH_COUNTS", "FoldFn",
    "dist", "DistConfig", "DistState", "ExchangeSchedule", "shard_state",
    "dist_mttkrp", "dist_all_modes", "surviving_mesh",
    "PlanSpec", "PlanSpace", "make_engine", "SPACE_DIMS", "autotune",
    "stream", "StreamPlan", "StreamState", "stream_init", "stream_mttkrp",
    "stream_all_modes", "cp_als_stream", "plan_stream",
    "plan_stream_cached", "resident_bytes",
]
