"""Functional spMTTKRP engine: ``init`` / ``mttkrp`` / ``all_modes``.

The paper's Alg. 5 as pure functions over a pytree
:class:`~repro.engine.state.EngineState`:

  init(tensor, config)            -> EngineState           (host, once)
  mttkrp(state, factors[, mode])  -> (out, EngineState)    (one mode + remap)
  all_modes(state, factors)       -> (outs, EngineState)   (one jitted scan)

``all_modes`` is a *single* jitted program: ``lax.scan`` over the mode
sequence, each step a ``lax.switch`` into that mode's statically-shaped
elementwise computation + dynamic remap (Alg. 2 + 3). There is no per-mode
Python dispatch, the T_in/T_out layout swap is the scan carry (donated on
TPU/GPU), and the rotation may start at *any* resident mode — the old
executor's ``current_mode == 0`` restriction is gone.

An optional ``fold`` callback runs inside the scan after each mode's
MTTKRP with that mode's output — this is how CPD-ALS updates factor
matrices mode-by-mode (Gauss-Seidel) while keeping the whole sweep one
traced program (see ``repro.core.cpd``).
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.obs.metrics import REGISTRY
from repro.obs.trace import span
from repro.resilience import chaos as _chaos

from .backends import compute_lrow, get_backend
from .config import ExecutionConfig
from .state import (EngineState, ModeSched, ModeStatic,
                    mode_static_from_plan)

# Fold callback: fold(mode, out_d, factors, carry) -> (factors, carry),
# called inside the traced scan with *static* mode and out_d of shape
# (dims[mode], R). Must be a stable (module-level) callable: its identity
# is part of the jit cache key.
FoldFn = Callable[[int, jax.Array, tuple, object], tuple]

# Observability: traces = how many times a program was (re)built; dispatches
# = how many jitted calls were issued. The benchmarks report the host-loop
# elimination as dispatches-per-sweep (was nmodes, now 1). These live on the
# repro.obs metrics registry (exported with every trace); the module-level
# names and dict-style access (`TRACE_COUNTS["all_modes"]`, `dict(...)`,
# `reset_counters()`) are the stable public surface.
TRACE_COUNTS = REGISTRY.counter(
    "engine_traces", "program (re)builds per entry point")
DISPATCH_COUNTS = REGISTRY.counter(
    "engine_dispatches", "jitted calls issued per entry point")

_JIT_CACHE: dict = {}


def reset_counters() -> None:
    TRACE_COUNTS.clear()
    DISPATCH_COUNTS.clear()


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------
def init(tensor, config: ExecutionConfig | None = None,
         start_mode: int = 0, *, cache=None) -> EngineState:
    """Build the device-resident engine state for ``tensor``.

    ``tensor`` is a prebuilt :class:`~repro.core.flycoo.FlycooTensor` (its
    plans govern the layout) or a raw COO triple ``(indices, values, dims)``
    — then the FLYCOO plans are built here under ``config``'s kappa policy,
    through ``cache`` (a :class:`repro.core.plancache.PlanCache`) when one
    is given so repeated/streaming inits skip ``plan_mode``.
    The returned state holds the ``start_mode`` layout, padded to the
    uniform slot count ``S_max`` so every mode shares one pytree shape.
    """
    config = config or ExecutionConfig()
    with span("engine.init", start_mode=start_mode) as sp:
        tensor = _as_flycoo(tensor, config, cache=cache)
        n = tensor.nmodes
        if not 0 <= start_mode < n:
            raise ValueError(
                f"start_mode {start_mode} out of range for {n} modes")
        statics = tuple(mode_static_from_plan(p) for p in tensor.plans)
        smax = max(s.padded_nnz for s in statics)
        sp.set("nmodes", n)
        sp.set("smax", smax)

        with span("engine.host_layout", mode=start_mode):
            base = tensor.plans[start_mode]
            val = np.zeros(smax, dtype=np.float32)
            idx = np.zeros((smax, n), dtype=np.int32)
            alpha = np.full((smax, n), -1, dtype=np.int32)
            val[base.slot_of_elem] = tensor.values
            idx[base.slot_of_elem] = tensor.indices
            for d in range(n):
                alpha[base.slot_of_elem, d] = \
                    tensor.plans[d].slot_of_elem.astype(np.int32)

        with span("engine.sched_tables"):
            sched = tuple(_mode_sched(tensor, d, config) for d in range(n))
        with span("engine.device_place"):
            return EngineState(
                val=jnp.asarray(val),
                idx=jnp.asarray(idx),
                alpha=jnp.asarray(alpha),
                relabel=tuple(jnp.asarray(p.row_relabel)
                              for p in tensor.plans),
                sched=sched,
                mode=int(start_mode),
                dims=tensor.dims,
                statics=statics,
                config=config,
            )


def _mode_sched(tensor, d: int, config: ExecutionConfig) -> ModeSched:
    """Device-resident per-mode schedule tables: the block->partition
    descriptor always; the in-block factor-row dedup tables only when the
    configured backend consumes them (``needs_dedup`` registry attribute —
    the fused Pallas pipeline) under the compact schedule, so xla/ref/
    pallas states skip the per-block sort and the device-resident
    ``(N-1, S_d)`` tables entirely. ``config.dedup=False`` installs the
    trivial tables instead (one row DMA per slot, no host-side sort)."""
    plan = tensor.plans[d]
    bpart = jnp.asarray(plan.block_part)
    if plan.schedule != "compact" or \
            not getattr(get_backend(config), "needs_dedup", False):
        return ModeSched(bpart=bpart)
    uidx, upos, nuniq = (tensor.dedup_tables(d) if config.dedup
                         else tensor.trivial_dedup_tables(d))
    return ModeSched(bpart=bpart, uidx=jnp.asarray(uidx),
                     upos=jnp.asarray(upos), nuniq=jnp.asarray(nuniq))


def _as_flycoo(tensor, config: ExecutionConfig, cache=None):
    from repro.core.flycoo import FlycooTensor, build_flycoo

    if isinstance(tensor, FlycooTensor):
        return tensor
    indices, values, dims = tensor
    kappa = config.kappa if config.kappa_policy == "fixed" else None
    build = cache.get_tensor if cache is not None else build_flycoo
    return build(indices, values, dims, kappa=kappa,
                 rows_pp=config.resolve_rows_pp(),
                 block_p=config.block_p,
                 schedule=config.schedule)


# --------------------------------------------------------------------------
# One mode: EC (Alg. 2/4) + dynamic remap (Alg. 3), statically shaped.
# --------------------------------------------------------------------------
def _mode_branch(d: int, *, statics: Sequence[ModeStatic], smax: int,
                 config: ExecutionConfig, fold: FoldFn | None,
                 pad_out_to: int | None):
    """Build the traced step for (static) mode ``d``.

    Returns a function (layout3, relabels, sched, factors, carry) ->
    ((nval, nidx, nalpha), out, factors, carry) where ``layout3`` is the
    S_max-padded (val, idx, alpha) triple, ``sched`` the per-mode schedule
    tables, and ``out`` is the mode-``d`` MTTKRP in user row space,
    zero-padded to ``pad_out_to`` rows when a uniform stacked shape is
    needed (the scan path).
    """
    plan = statics[d]
    n = len(statics)
    nxt = (d + 1) % n
    sd = plan.padded_nnz
    backend = get_backend(config)
    # Fusing backends (e.g. ``pallas_fused``) emit the Alg. 3 remap scatter
    # inside the EC kernel pass; ``config.fuse_remap=False`` keeps the XLA
    # scatter path as the comparison baseline.
    fused = (getattr(backend, "fused_remap", None)
             if config.fuse_remap else None)

    def step(layout3, relabels, sched, factors, carry):
        val, idx, alpha = layout3
        v, ix, al = val[:sd], idx[:sd], alpha[:sd]
        alive = al[:, d] >= 0
        lrow = compute_lrow(ix[:, d], relabels[d], plan.rows_pp, alive)
        layout = {"val": v, "idx": ix, "alpha": al, "lrow": lrow,
                  **sched[d]._asdict()}
        if fused is not None:
            # One Pallas pass: EC + remap; slots beyond S_{d+1} stay empty
            # (the kernel initializes the next layout to the pad pattern).
            out_rel, (nval, nidx, nalpha) = fused(
                layout, tuple(factors), d, plan=plan, config=config,
                smax=smax, next_mode=nxt)
            nval = nval.astype(val.dtype)
            nidx = nidx.astype(idx.dtype)
        else:
            out_rel = backend(layout, tuple(factors), d, plan=plan,
                              config=config)
            # Alg. 3: conflict-free scatter into the mode-(d+1) layout (pads
            # parked at S_max -> dropped); slots beyond S_{d+1} stay empty.
            dst = jnp.where(alive, al[:, nxt], smax)
            nval = jnp.zeros((smax,), val.dtype).at[dst].set(
                v, mode="drop", unique_indices=True)
            nidx = jnp.zeros((smax, n), idx.dtype).at[dst].set(
                ix, mode="drop", unique_indices=True)
            nalpha = jnp.full((smax, n), -1, jnp.int32).at[dst].set(
                al, mode="drop", unique_indices=True)
        out = jnp.take(out_rel, relabels[d], axis=0)  # un-relabel -> (I_d, R)
        if fold is not None:
            factors, carry = fold(d, out, factors, carry)
        if pad_out_to is not None:
            out = jnp.pad(out, ((0, pad_out_to - plan.dim), (0, 0)))
        return (nval, nidx, nalpha), out, factors, carry

    return step


# --------------------------------------------------------------------------
# mttkrp: one mode, one dispatch.
# --------------------------------------------------------------------------
def mttkrp(state: EngineState, factors: Sequence[jax.Array],
           mode: int | None = None):
    """MTTKRP for the resident mode + remap to the next; returns
    ``(out, next_state)``. ``mode`` (optional) must name the resident mode
    — the layout physically *is* mode-``state.mode``'s."""
    if mode is not None and mode != state.mode:
        raise ValueError(
            f"state holds the mode-{state.mode} layout; cannot compute "
            f"mode {mode} without rotating (use all_modes or step to it)")
    d = state.mode
    key = ("mttkrp", state.aux_key())
    fn = _JIT_CACHE.get(key)
    if fn is None:
        step = _mode_branch(d, statics=state.statics, smax=state.smax,
                            config=state.config, fold=None,
                            pad_out_to=None)

        def run(layout3, relabels, sched, factors):
            TRACE_COUNTS["mttkrp"] += 1  # trace-time side effect
            nl, out, _, _ = step(layout3, relabels, sched, factors, None)
            return nl, out

        donate = (0,) if state.config.resolve_donate() else ()
        fn = _JIT_CACHE[key] = jax.jit(run, donate_argnums=donate)
    _c = _chaos.active()
    if _c is not None:
        _c.on_dispatch(state.config.backend)
    DISPATCH_COUNTS["mttkrp"] += 1
    with span("engine.dispatch", kind="mttkrp", mode=d):
        (nval, nidx, nalpha), out = fn(
            (state.val, state.idx, state.alpha), state.relabel, state.sched,
            tuple(factors))
    nxt = (d + 1) % state.nmodes
    return out, state.replace(val=nval, idx=nidx, alpha=nalpha, mode=nxt)


# --------------------------------------------------------------------------
# all_modes: one jitted lax.scan over the full rotation.
# --------------------------------------------------------------------------
def _build_scan(state: EngineState, fold: FoldFn | None):
    """The traced all-modes program (pre-jit, for jaxpr inspection).

    Captures only the state's *static* aux (ints/tuples), never its device
    arrays — the built function lives in the long-lived jit cache and must
    not pin the first caller's layout buffers.
    """
    n, m0, smax, imax = state.nmodes, state.mode, state.smax, state.imax
    dims = state.dims
    seq = tuple((m0 + i) % n for i in range(n))
    branches = [
        _mode_branch(d, statics=state.statics, smax=smax,
                     config=state.config, fold=fold, pad_out_to=imax)
        for d in range(n)
    ]

    def run(layout3, relabels, sched, factors, carry):
        TRACE_COUNTS["all_modes"] += 1  # trace-time side effect

        def body(sc, mode_t):
            layout3, factors, carry = sc
            nl, out, factors, carry = lax.switch(
                mode_t,
                [lambda l3, f, c, b=b: b(l3, relabels, sched, f, c)
                 for b in branches],
                layout3, factors, carry)
            return (nl, factors, carry), out

        (layout3, factors, carry), outs = lax.scan(
            body, (layout3, factors, carry),
            jnp.asarray(seq, dtype=jnp.int32))
        # outs[i] is mode seq[i], padded to imax rows; hand back per-mode
        # views in mode order, statically sliced to each I_d.
        by_mode = tuple(
            outs[seq.index(d)][: dims[d]] for d in range(n))
        return layout3, by_mode, factors, carry

    return run


def all_modes(state: EngineState, factors: Sequence[jax.Array], *,
              fold: FoldFn | None = None, carry=None):
    """spMTTKRP along all N modes as ONE jitted ``lax.scan`` dispatch.

    Starts from the resident ``state.mode`` (any mode — the alpha tables
    rotate the layout back to it by the end) and returns outputs indexed
    by mode, i.e. ``outs[d]`` is the mode-``d`` MTTKRP of shape
    ``(dims[d], R)``.

    Without ``fold``: returns ``(outs, next_state)``.
    With ``fold`` (stable module-level callable, see :data:`FoldFn`):
    returns ``(outs, next_state, factors, carry)`` — the hook runs inside
    the scan right after each mode's output, which is how an ALS sweep
    stays a single traced program.
    """
    key = ("all_modes", state.aux_key(), fold)
    fn = _JIT_CACHE.get(key)
    if fn is None:
        donate = (0,) if state.config.resolve_donate() else ()
        fn = _JIT_CACHE[key] = jax.jit(_build_scan(state, fold),
                                       donate_argnums=donate)
    _c = _chaos.active()
    if _c is not None:
        _c.on_dispatch(state.config.backend)
    DISPATCH_COUNTS["all_modes"] += 1
    with span("engine.dispatch", kind="all_modes", start_mode=state.mode):
        layout3, outs, out_factors, out_carry = fn(
            (state.val, state.idx, state.alpha), state.relabel, state.sched,
            tuple(factors), carry)
    nval, nidx, nalpha = layout3
    next_state = state.replace(val=nval, idx=nidx, alpha=nalpha)
    if fold is None:
        return list(outs), next_state
    return list(outs), next_state, list(out_factors), out_carry


def scan_jaxpr(state: EngineState, factors: Sequence[jax.Array],
               fold: FoldFn | None = None, carry=None):
    """Jaxpr of the all-modes program (tests assert it is one scan)."""
    return jax.make_jaxpr(_build_scan(state, fold))(
        (state.val, state.idx, state.alpha), state.relabel, state.sched,
        tuple(factors), carry)


__all__ = ["init", "mttkrp", "all_modes", "scan_jaxpr", "reset_counters",
           "TRACE_COUNTS", "DISPATCH_COUNTS", "FoldFn"]
