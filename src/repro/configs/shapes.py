"""Assigned input shapes (one set, shared by all 10 LM-family archs).

  train_4k     seq 4096  x global_batch 256   -> train_step
  prefill_32k  seq 32768 x global_batch 32    -> prefill (forward, no grad)
  decode_32k   KV cache 32768, global_batch 128 -> serve_step (1 new token)
  long_500k    KV cache 524288, global_batch 1  -> serve_step; sub-quadratic
               archs only (hybrid/ssm) — full-attention archs skip (DESIGN.md §5)
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    step: str                  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# archs able to run 524288-token decode (recurrent state / windowed cache)
SUBQUADRATIC_ARCHS = {"recurrentgemma-9b", "rwkv6-3b"}


def applicable(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in SUBQUADRATIC_ARCHS
    return True


def cells(archs) -> list[tuple[str, str]]:
    """All assigned (arch x shape) cells, with documented skips applied."""
    out = []
    for a in archs:
        for s in SHAPES:
            if applicable(a, s):
                out.append((a, s))
    return out
