"""Configs: the 10 assigned architectures x 4 input shapes.

``input_specs`` builds weak-type-correct ShapeDtypeStruct stand-ins for every
model input of a (arch, shape) cell — no device allocation, shardable — the
contract the multi-pod dry-run lowers against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models.common import ModelConfig
from .archs import ARCHS, get_config, smoke
from .shapes import SHAPES, ShapeSpec, applicable, cells

WHISPER_CROSS_LEN = 1500  # real whisper encoder output length (30 s audio)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStructs for the step function of this (arch, shape) cell.

    train/prefill: token batch (+ stub modality embeddings);
    decode: one new token; the KV/state cache spec comes from
    ``jax.eval_shape`` over ``init_cache`` (launch/dryrun attaches shardings).
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    emb = jnp.dtype(cfg.compute_dtype)
    if shape.step in ("train", "prefill"):
        if cfg.kind == "vlm":
            n_txt = s - cfg.n_img_tokens
            specs = {
                "tokens": jax.ShapeDtypeStruct((b, n_txt), i32),
                "embeds": jax.ShapeDtypeStruct(
                    (b, cfg.n_img_tokens, cfg.d_model), emb),
            }
        elif cfg.kind == "audio":
            specs = {
                "tokens": jax.ShapeDtypeStruct((b, s), i32),
                "enc_embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), emb),
            }
        else:
            specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if shape.step == "train":
            tgt = specs["tokens"].shape
            specs["targets"] = jax.ShapeDtypeStruct(tgt, i32)
        return specs
    # decode: one token against a cache of seq_len
    return {"token": jax.ShapeDtypeStruct((b, 1), i32)}


def cache_specs(cfg: ModelConfig, shape: ShapeSpec):
    """ShapeDtypeStruct pytree for the decode cache (no allocation)."""
    from ..models import transformer

    return jax.eval_shape(
        lambda: transformer.init_cache(
            cfg, shape.global_batch, shape.seq_len,
            enc_len=WHISPER_CROSS_LEN if cfg.kind == "audio" else 0))


__all__ = ["ARCHS", "SHAPES", "get_config", "smoke", "applicable", "cells",
           "input_specs", "cache_specs", "WHISPER_CROSS_LEN"]
