"""Registry for the 10 assigned architectures (one module per arch).

Each ``configs/<id>.py`` holds the exact assigned config; ``smoke()``
returns a reduced same-family config for CPU tests. Full configs are only
lowered via the dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses

from ..models.common import ModelConfig
from .command_r_plus_104b import CONFIG as COMMAND_R_PLUS_104B
from .olmo_1b import CONFIG as OLMO_1B
from .qwen2_5_3b import CONFIG as QWEN2_5_3B
from .tinyllama_1_1b import CONFIG as TINYLLAMA_1_1B
from .recurrentgemma_9b import CONFIG as RECURRENTGEMMA_9B
from .qwen3_moe_235b_a22b import CONFIG as QWEN3_MOE_235B
from .olmoe_1b_7b import CONFIG as OLMOE_1B_7B
from .paligemma_3b import CONFIG as PALIGEMMA_3B
from .whisper_large_v3 import CONFIG as WHISPER_LARGE_V3
from .rwkv6_3b import CONFIG as RWKV6_3B

ARCHS: dict[str, ModelConfig] = {
    c.name: c for c in [
        COMMAND_R_PLUS_104B, OLMO_1B, QWEN2_5_3B, TINYLLAMA_1_1B,
        RECURRENTGEMMA_9B, QWEN3_MOE_235B, OLMOE_1B_7B, PALIGEMMA_3B,
        WHISPER_LARGE_V3, RWKV6_3B,
    ]
}


def get_config(name: str) -> ModelConfig:
    return ARCHS[name]


def smoke(name: str) -> ModelConfig:
    """Reduced same-family config: small widths, few experts, tiny vocab."""
    cfg = ARCHS[name]
    pat = cfg.block_pattern
    n_layers = max(2, len(pat))
    repl = dict(
        n_layers=n_layers if len(pat) == 1 else len(pat) + min(
            len(pat), cfg.n_layers - len(pat)),
        d_model=128,
        n_heads=4,
        n_kv_heads=max(1, min(4, cfg.n_kv_heads * 4 // cfg.n_heads)),
        head_dim=32,
        d_ff=256,
        vocab=512,
        window=min(cfg.window, 16) if cfg.window else 0,
        lru_width=128 if cfg.lru_width else 0,
        n_experts=8 if cfg.n_experts else 0,
        top_k=2 if cfg.top_k else 0,
        n_enc_layers=2 if cfg.n_enc_layers else 0,
        n_img_tokens=4 if cfg.n_img_tokens else 0,
        remat="none",
    )
    if cfg.kind == "ssm":
        repl["d_model"] = 128  # 2 rwkv heads of 64
        repl["n_heads"] = 2
        repl["n_kv_heads"] = 2
        repl["head_dim"] = 0
    return dataclasses.replace(cfg, **repl)
