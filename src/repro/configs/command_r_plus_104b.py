"""Assigned architecture config (see assignment table)."""
from ..models.common import ModelConfig

# --------------------------------------------------------------------- dense
# [hf:CohereForAI/c4ai-command-r-plus; unverified] GQA kv=8, no-bias,
# parallel attention/FFN block, LayerNorm, tied embeddings.
CONFIG = ModelConfig(
    name="command-r-plus-104b", kind="dense", n_layers=64, d_model=12288,
    n_heads=96, n_kv_heads=8, d_ff=33792, vocab=256000, norm="layernorm",
    act="swiglu", parallel_block=True, tie_embeddings=True,
    rope_theta=75_000_000.0,
)
