"""Assigned architecture config (see assignment table)."""
from ..models.common import ModelConfig

# -------------------------------------------------------------------- hybrid
# [arXiv:2402.19427; unverified] RG-LRU + local attn, 1 attn : 2 recurrent.
CONFIG = ModelConfig(
    name="recurrentgemma-9b", kind="hybrid", n_layers=38, d_model=4096,
    n_heads=16, n_kv_heads=1, head_dim=256, d_ff=12288, vocab=256000,
    norm="rmsnorm", act="geglu", tie_embeddings=True,
    block_pattern=("rec", "rec", "local"), window=2048, lru_width=4096,
)
