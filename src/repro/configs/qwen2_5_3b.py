"""Assigned architecture config (see assignment table)."""
from ..models.common import ModelConfig

# [hf:Qwen/Qwen2.5-3B; hf] GQA kv=2, QKV bias, tied embeddings.
CONFIG = ModelConfig(
    name="qwen2.5-3b", kind="dense", n_layers=36, d_model=2048, n_heads=16,
    n_kv_heads=2, d_ff=11008, vocab=151936, norm="rmsnorm", act="swiglu",
    qkv_bias=True, tie_embeddings=True, rope_theta=1_000_000.0,
)
