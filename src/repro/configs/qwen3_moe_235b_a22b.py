"""Assigned architecture config (see assignment table)."""
from ..models.common import ModelConfig

# ----------------------------------------------------------------------- moe
# [hf:Qwen/Qwen3-235B-A22B; hf] 128 experts top-8, QK-norm, d_ff/expert 1536.
CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", kind="moe", n_layers=94, d_model=4096,
    n_heads=64, n_kv_heads=4, head_dim=128, d_ff=1536, vocab=151936,
    norm="rmsnorm", act="swiglu", qk_norm=True, rope_theta=1_000_000.0,
    n_experts=128, top_k=8, block_pattern=("moe",),
)
