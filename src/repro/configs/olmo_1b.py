"""Assigned architecture config (see assignment table)."""
from ..models.common import ModelConfig

# [arXiv:2402.00838; hf] non-parametric LN, tied embeddings, swiglu.
CONFIG = ModelConfig(
    name="olmo-1b", kind="dense", n_layers=16, d_model=2048, n_heads=16,
    n_kv_heads=16, d_ff=8192, vocab=50304, norm="layernorm_np", act="swiglu",
    tie_embeddings=True,
)
