"""Assigned architecture config (see assignment table)."""
from ..models.common import ModelConfig

# [arXiv:2401.02385; hf] llama2-arch small.
CONFIG = ModelConfig(
    name="tinyllama-1.1b", kind="dense", n_layers=22, d_model=2048,
    n_heads=32, n_kv_heads=4, d_ff=5632, vocab=32000, norm="rmsnorm",
    act="swiglu",
)
