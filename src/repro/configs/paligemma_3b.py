"""Assigned architecture config (see assignment table)."""
from ..models.common import ModelConfig

# ----------------------------------------------------------------------- vlm
# [arXiv:2407.07726; hf] SigLIP (stubbed) + gemma backbone, prefix-LM.
CONFIG = ModelConfig(
    name="paligemma-3b", kind="vlm", n_layers=18, d_model=2048, n_heads=8,
    n_kv_heads=1, head_dim=256, d_ff=16384, vocab=257216, norm="rmsnorm",
    act="geglu", tie_embeddings=True, n_img_tokens=256,
)
