"""Assigned architecture config (see assignment table)."""
from ..models.common import ModelConfig

# [arXiv:2409.02060; hf] 64 experts top-8.
CONFIG = ModelConfig(
    name="olmoe-1b-7b", kind="moe", n_layers=16, d_model=2048, n_heads=16,
    n_kv_heads=16, d_ff=1024, vocab=50304, norm="rmsnorm", act="swiglu",
    qk_norm=True, n_experts=64, top_k=8, block_pattern=("moe",),
)
