"""Assigned architecture config (see assignment table)."""
from ..models.common import ModelConfig

# ----------------------------------------------------------------------- ssm
# [arXiv:2404.05892; hf] Finch: attn-free, data-dependent decay.
CONFIG = ModelConfig(
    name="rwkv6-3b", kind="ssm", n_layers=32, d_model=2560, n_heads=40,
    n_kv_heads=40, d_ff=8960, vocab=65536, norm="layernorm",
    block_pattern=("rwkv",),
)
