"""Assigned architecture config (see assignment table)."""
from ..models.common import ModelConfig

# --------------------------------------------------------------------- audio
# [arXiv:2212.04356; unverified] enc-dec, conv frontend stubbed; sinusoidal
# positions (rope_theta=0); 32 encoder + 32 decoder layers.
CONFIG = ModelConfig(
    name="whisper-large-v3", kind="audio", n_layers=32, d_model=1280,
    n_heads=20, n_kv_heads=20, d_ff=5120, vocab=51866, norm="layernorm",
    act="gelu", qkv_bias=True, rope_theta=0.0, n_enc_layers=32,
    block_pattern=("dec",), tie_embeddings=True,
)
