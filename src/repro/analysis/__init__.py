"""Roofline + HLO traffic analysis (dry-run artifacts only)."""
from .hlo import collective_bytes
from .roofline import HW, Roofline, analyze, corrected_costs, model_flops

__all__ = ["collective_bytes", "HW", "Roofline", "analyze",
           "corrected_costs", "model_flops"]
