"""Three-term roofline from dry-run records (TPU v5e targets).

    compute term    = FLOPs_per_device / peak_flops
    memory term     = HLO_bytes_per_device / hbm_bw
    collective term = collective_bytes_per_device / link_bw

Scan correction (cost_analysis counts a while-body once): with variant
compiles F(nonloop) and F(stage_s) (one cycle), per-cycle body cost is
``F(stage_s) - F(nonloop)`` and the corrected total is

    F(full) + sum_s (rep_s - 1) * body_s

For bytes, the optimizer's parameter traffic lives *outside* the scan and is
already fully counted in F(full), so the body correction subtracts an
analytic estimate of the cycle's optimizer read/write bytes.

Roofline fraction (the §Perf score) = MODEL_FLOPS-ideal time / max(term):
    MODEL_FLOPS = 6*N_active*tokens (train) or 2*N_active*tokens (inference)
"""
from __future__ import annotations

import dataclasses

HW = {
    "peak_flops": 197e12,   # bf16 / chip (TPU v5e)
    "hbm_bw": 819e9,        # B/s per chip
    "link_bw": 50e9,        # B/s per ICI link
}

_ADAM_RW_F32 = 28   # g+m+v+p reads, m+v+p writes (4B each)
_ADAM_RW_BF16 = 20  # bf16 moments


@dataclasses.dataclass
class Roofline:
    flops: float            # corrected, per device
    bytes: float
    coll_bytes: float
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    model_flops_total: float
    useful_ratio: float     # MODEL_FLOPS / (corrected flops * chips)
    roofline_fraction: float
    est_step_s: float

    def as_dict(self):
        return dataclasses.asdict(self)


def _tokens(rec) -> float:
    from ..configs import SHAPES

    shape = SHAPES[rec["shape"]]
    if rec["step"] == "decode":
        return shape.global_batch  # one new token per sequence
    return shape.global_batch * shape.seq_len


def model_flops(rec) -> float:
    n = rec["active_params"]
    toks = _tokens(rec)
    mult = 6.0 if rec["step"] == "train" else 2.0
    return mult * n * toks


def corrected_costs(rec, opt_bf16: bool = False) -> tuple[float, float, float]:
    """(flops, bytes, collective bytes) per device, scan-corrected.

    With variants present, costs come from cost-mode compiles only:
        nonloop + sum_s rep_s * (variant_s - nonloop [- opt traffic])
    (the full compile's numbers carry scanned chunk loops => undercount).
    The optimizer's stacked-param traffic is charged once, analytically,
    because it lives outside every scan in the full program.
    """
    variants = rec.get("variants")
    if not variants or "nonloop" not in variants:
        return (rec["cost"]["flops_per_device"],
                rec["cost"]["bytes_per_device"],
                rec["collectives_per_device"]["total"])
    nl = variants["nonloop"]
    rw = _ADAM_RW_BF16 if opt_bf16 else _ADAM_RW_F32
    n_dev = rec["n_devices"]
    f = nl["flops_per_device"]
    b = nl["bytes_per_device"]
    c = nl["collectives_per_device"]["total"]
    for tag, v in variants.items():
        if tag == "nonloop" or v["rep"] < 1:
            continue
        body_f = max(v["flops_per_device"] - nl["flops_per_device"], 0.0)
        body_b = v["bytes_per_device"] - nl["bytes_per_device"]
        body_c = (v["collectives_per_device"]["total"]
                  - nl["collectives_per_device"]["total"])
        body_params = max(v.get("params", 0) - nl.get("params", 0), 0)
        if rec["step"] == "train" and body_params:
            # remove the cycle's optimizer traffic from the body, then
            # charge the full stacked-param traffic once at the end
            body_b -= body_params * rw / n_dev
        body_b = max(body_b, 0.0)
        body_c = max(body_c, 0.0)
        f += v["rep"] * body_f
        b += v["rep"] * body_b
        c += v["rep"] * body_c
    if rec["step"] == "train":
        b += rec["params"] * rw / n_dev  # stacked-param optimizer traffic
    return f, b, c


def analyze(rec, hw=HW, opt_bf16: bool = False) -> Roofline:
    f, b, c = corrected_costs(rec, opt_bf16=opt_bf16)
    t_comp = f / hw["peak_flops"]
    t_mem = b / hw["hbm_bw"]
    t_coll = c / hw["link_bw"]
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec)
    n_dev = rec["n_devices"]
    est = max(terms.values())
    ideal = mf / (n_dev * hw["peak_flops"])
    return Roofline(
        flops=f, bytes=b, coll_bytes=c,
        t_compute=t_comp, t_memory=t_mem, t_collective=t_coll,
        dominant=dominant,
        model_flops_total=mf,
        useful_ratio=mf / max(f * n_dev, 1.0),
        roofline_fraction=ideal / max(est, 1e-12),
        est_step_s=est,
    )
