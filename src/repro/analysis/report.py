"""Render EXPERIMENTS.md sections from experiments/dryrun/*.json records."""
from __future__ import annotations

import glob
import json
import os

from .roofline import analyze

_OPT_BF16 = {"command-r-plus-104b", "qwen3-moe-235b-a22b"}


def load_records(dry_dir: str) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dry_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def _fmt_bytes(x: float) -> str:
    return f"{x / 1e9:.2f}"


def dryrun_table(recs: list[dict]) -> str:
    lines = [
        "| arch | shape | mesh | ok | peak GB/dev | HLO GFLOP/dev | "
        "HLO GB/dev | coll GB/dev | compile s |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | - | **FAIL** "
                         f"| - | - | - | - | - |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | yes | "
            f"{r['memory']['peak_per_device_gb']:.2f} | "
            f"{r['cost']['flops_per_device'] / 1e9:.0f} | "
            f"{_fmt_bytes(r['cost']['bytes_per_device'])} | "
            f"{_fmt_bytes(r['collectives_per_device']['total'])} | "
            f"{r['compile_s']} |")
    return "\n".join(lines)


_FIX_HINTS = {
    "compute": "raise arithmetic intensity (bigger per-chip batch, fuse "
               "elementwise chains into the matmuls)",
    "memory": "cut HBM traffic: tighter remat policy, bf16 master/offload, "
              "fuse gather+hadamard (keep partials in VMEM)",
    "collective": "reshard to cut cross-chip bytes: cast-before-gather "
                  "params, reduce-scatter grads, overlap a2a with expert "
                  "compute",
}


def roofline_table(recs: list[dict]) -> str:
    lines = [
        "| arch | shape | t_compute s | t_memory s | t_collective s | "
        "dominant | MODEL_TF | useful ratio | roofline frac | "
        "what moves the dominant term |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if not r.get("ok") or r.get("mesh") != "16x16":
            continue
        rf = analyze(r, opt_bf16=r["arch"] in _OPT_BF16)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rf.t_compute:.4f} | "
            f"{rf.t_memory:.4f} | {rf.t_collective:.4f} | {rf.dominant} | "
            f"{rf.model_flops_total / 1e12:.1f} | {rf.useful_ratio:.3f} | "
            f"{rf.roofline_fraction:.3f} | "
            f"{_FIX_HINTS[rf.dominant]} |")
    return "\n".join(lines)


def hillclimb_table(dry_dir: str, hc_dir: str) -> str:
    """Render §Perf Phase-2: baseline vs optimized per hillclimb cell."""
    import collections

    base = {}
    for r in load_records(dry_dir):
        if r.get("ok") and r.get("mesh") == "16x16":
            base[(r["arch"], r["shape"])] = r
    rows = ["| cell | change | peak GB/dev | t_compute | t_memory | "
            "t_collective | dominant | roofline frac | verdict |",
            "|---|---|---|---|---|---|---|---|---|"]
    recs = collections.defaultdict(list)
    for r in load_records(hc_dir):
        if r.get("ok"):
            recs[(r["arch"], r["shape"])].append(r)

    def fmt(r, label, ref=None):
        rf = analyze(r, opt_bf16=r["arch"] in _OPT_BF16)
        frac = rf.roofline_fraction
        verdict = ""
        if ref is not None:
            rfb = analyze(ref, opt_bf16=ref["arch"] in _OPT_BF16)
            d = {"compute": rf.t_compute / max(rfb.t_compute, 1e-12),
                 "memory": rf.t_memory / max(rfb.t_memory, 1e-12),
                 "collective":
                 rf.t_collective / max(rfb.t_collective, 1e-12)}
            verdict = (f"dom term x{d[rfb.dominant]:.2f}; "
                       f"frac {rfb.roofline_fraction:.3f}->{frac:.3f}")
        return (f"| {r['arch']} x {r['shape']} | {label} | "
                f"{r['memory']['peak_per_device_gb']:.2f} | "
                f"{rf.t_compute:.4f} | {rf.t_memory:.4f} | "
                f"{rf.t_collective:.4f} | {rf.dominant} | {frac:.3f} | "
                f"{verdict} |")

    for key, hcs in sorted(recs.items()):
        b = base.get(key)
        if b is not None:
            rows.append(fmt(b, "baseline (paper-faithful framework)"))
        for r in sorted(hcs, key=lambda x: x.get("opt_tag", "")):
            rows.append(fmt(r, r.get("opt_tag", "?"), ref=b))
    return "\n".join(rows)


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "experiments",
        "dryrun"))
    args = ap.parse_args()
    recs = load_records(args.dry_dir)
    print("## Dry-run\n")
    print(dryrun_table(recs))
    print("\n## Roofline\n")
    print(roofline_table(recs))
    hc_dir = os.path.join(args.dry_dir, "..", "hillclimb")
    if os.path.isdir(hc_dir):
        print("\n## Perf hillclimbs\n")
        print(hillclimb_table(args.dry_dir, hc_dir))


if __name__ == "__main__":
    main()
