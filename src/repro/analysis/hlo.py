"""Parse compiled (post-SPMD) HLO text for collective traffic.

``cost_analysis()`` does not report collective bytes, so we scan the
compiled module for all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops and account ring-algorithm bytes-on-the-wire per
device. Ops inside ``while`` bodies appear once; the roofline module scales
loop-body contributions by trip count via config variants (see
analysis/roofline.py).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\](?:\{[^}]*\})?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return 1


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes-on-the-wire by collective kind (ring algorithm).

    result-shape conventions (R = result bytes, n = group size):
      all-gather         R * (n-1)/n     (result is the gathered buffer)
      all-reduce         R * 2(n-1)/n    (reduce-scatter + all-gather)
      reduce-scatter     R * (n-1)       (operand = n*R streamed through)
      all-to-all         R * (n-1)/n
      collective-permute R
    """
    out: dict = defaultdict(float)
    counts: dict = defaultdict(int)
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if "-done(" in line:  # async pair: count only the -start
            continue
        tuple_part, dtype, dims, kind = m.groups()
        if tuple_part is not None:
            rbytes = sum(_shape_bytes(dt, dm) for dt, dm in
                         _SHAPE_RE.findall(tuple_part))
        else:
            rbytes = _shape_bytes(dtype, dims)
        n = max(_group_size(line), 1)
        if n == 1:
            continue
        # CPU FloatNormalization promotes bf16 reduces to f32 and marks the
        # reducer "<op>_promoted": halve to recover the TPU-native bf16 bytes.
        if "_promoted" in line and kind in ("all-reduce", "reduce-scatter"):
            rbytes //= 2
        if kind == "all-gather":
            b = rbytes * (n - 1) / n
        elif kind == "all-reduce":
            b = rbytes * 2 * (n - 1) / n
        elif kind == "reduce-scatter":
            b = rbytes * (n - 1)
        elif kind == "all-to-all":
            b = rbytes * (n - 1) / n
        else:  # collective-permute
            b = rbytes
        out[kind] += b
        counts[kind] += 1
    result = dict(out)
    result["total"] = float(sum(out.values()))
    result["counts"] = dict(counts)
    return result
