"""Atomic, content-addressed ALS sweep snapshots (checkpoint/resume).

An hour-long ``cp_als`` / ``cp_als_stream`` / distributed sweep dies on
the first preemption today, losing every completed sweep. This module
makes sweep boundaries durable:

* **Fingerprinted.** A snapshot is bound to a :func:`fingerprint` of the
  exact problem — tensor bytes (indices + values + dims), rank, the
  ``ExecutionConfig``/``PlanSpec`` repr, the init PRNG key and the start
  mode. Resume refuses snapshots from a *different* problem, because the
  whole point is bitwise-identical continuation: at a sweep boundary the
  engine layout has rotated back to its start-mode arrangement, so
  ``(factors, lam)`` are the complete dynamic state and replaying the
  remaining sweeps reproduces an uninterrupted run bit for bit.
* **Atomic + checksummed.** Writes go to a tmp file in the destination
  directory and are published with ``os.replace``; the payload digest is
  part of the *filename*, so a torn or bit-rotten blob is detected on
  load (recompute + compare), quarantined (renamed ``*.corrupt``), and
  the loader falls back to the next-older sweep instead of resuming from
  garbage.
* **Observable.** Saves/loads/corruptions tick the ``snapshot_events``
  counter and wrap in ``resilience.snapshot_*`` spans.

Layout: ``<dir>/<fp16>-sweep<NNNNNN>-<digest12>.npz`` — one flat npz per
snapshot (per-factor arrays + ``lam`` + ``fits`` + a JSON meta string),
``keep`` newest retained per fingerprint.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import re
from typing import Sequence

import numpy as np

from repro.obs.metrics import counter as _counter
from repro.obs.trace import span as _span

__all__ = ["fingerprint", "payload_digest", "Snapshot", "SnapshotStore",
           "as_store"]

_FORMAT_VERSION = 1
_NAME_RE = re.compile(
    r"(?P<fp>[0-9a-f]{16})-sweep(?P<sweep>\d{6})-(?P<digest>[0-9a-f]{12})"
    r"\.npz")


def fingerprint(indices, values, dims: Sequence[int], rank: int,
                config=None, key=None, start_mode: int = 0,
                extra: str = "") -> str:
    """Content address of one decomposition problem (sha256 hex).

    Hashes the exact tensor bytes plus every knob that changes the traced
    computation — two runs share a fingerprint iff an uninterrupted run
    and a resumed run would produce bitwise-identical factors.
    """
    h = hashlib.sha256()
    h.update(repr((tuple(int(d) for d in dims), int(rank),
                   int(start_mode), repr(config), extra,
                   _FORMAT_VERSION)).encode())
    h.update(np.ascontiguousarray(indices).tobytes())
    h.update(np.ascontiguousarray(values).tobytes())
    if key is not None:
        h.update(np.asarray(key).tobytes())
    return h.hexdigest()


def payload_digest(arrays: dict) -> str:
    """Order-stable sha256 over a dict of numpy arrays (key order is the
    caller's contract). Shared by the snapshot store and the
    ``PlanCache`` disk guardrail so both verify blobs the same way."""
    h = hashlib.sha256()
    for name in arrays:
        a = np.ascontiguousarray(arrays[name])
        h.update(name.encode())
        h.update(str(a.dtype).encode())
        h.update(repr(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()


def as_store(checkpoint) -> "SnapshotStore | None":
    """Normalize a user-facing ``checkpoint=`` argument: ``None``/``False``
    -> off, a directory path -> a fresh :class:`SnapshotStore` over it, a
    store -> itself."""
    if checkpoint is None or checkpoint is False:
        return None
    if isinstance(checkpoint, SnapshotStore):
        return checkpoint
    return SnapshotStore(os.fspath(checkpoint))


@dataclasses.dataclass
class Snapshot:
    """One loaded sweep snapshot (host numpy; ``sweep`` is the number of
    *completed* sweeps — resume continues at sweep ``sweep``)."""

    fingerprint: str
    sweep: int
    factors: list[np.ndarray]
    lam: np.ndarray
    fits: list[float]
    path: str


class SnapshotStore:
    """Directory of fingerprinted sweep snapshots; see module docstring.

    ``save`` is cheap relative to a sweep (host copy + one npz write) and
    safe to call every sweep; ``latest`` returns the newest *intact*
    snapshot for a fingerprint, quarantining any corrupt blob it meets on
    the way down.
    """

    def __init__(self, directory: str, keep: int = 3):
        if keep < 1:
            raise ValueError("keep must be >= 1")
        self.dir = os.fspath(directory)
        self.keep = keep
        self.saves = 0
        self.loads = 0
        self.corrupt = 0

    # ------------------------------------------------------------------ save
    def save(self, fp: str, sweep: int, factors, lam,
             fits: Sequence[float] = ()) -> str:
        """Persist one completed-sweep state; returns the blob path."""
        with _span("resilience.snapshot_save", sweep=sweep) as sp:
            arrays = {f"factor{i}": np.asarray(f)
                      for i, f in enumerate(factors)}
            arrays["lam"] = np.asarray(lam)
            arrays["fits"] = np.asarray(list(fits), dtype=np.float64)
            meta = {"version": _FORMAT_VERSION, "fingerprint": fp,
                    "sweep": int(sweep), "n_factors": len(factors)}
            arrays["meta"] = np.frombuffer(
                json.dumps(meta).encode(), dtype=np.uint8)
            digest = payload_digest(arrays)
            os.makedirs(self.dir, exist_ok=True)
            fn = os.path.join(
                self.dir, f"{fp[:16]}-sweep{sweep:06d}-{digest[:12]}.npz")
            tmp = os.path.join(self.dir,
                               f".tmp-{os.getpid()}-{fp[:16]}-{sweep}")
            with open(tmp, "wb") as f:
                np.savez(f, **arrays)
            os.replace(tmp, fn)
            sp.set("path", os.path.basename(fn))
        self.saves += 1
        _counter("snapshot_events",
                 "sweep snapshot saves/loads/corruptions").inc("save")
        self._gc(fp[:16])
        return fn

    def _gc(self, fp16: str) -> None:
        blobs = self._blobs(fp16)
        for _, fn in blobs[:-self.keep]:
            try:
                os.remove(os.path.join(self.dir, fn))
            except OSError:
                pass

    def _blobs(self, fp16: str | None = None) -> list[tuple[int, str]]:
        """(sweep, filename) of every snapshot blob, sweep-ascending."""
        try:
            names = os.listdir(self.dir)
        except FileNotFoundError:
            return []
        out = []
        for name in names:
            m = _NAME_RE.fullmatch(name)
            if m and (fp16 is None or m.group("fp") == fp16):
                out.append((int(m.group("sweep")), name))
        return sorted(out)

    # ------------------------------------------------------------------ load
    def load(self, path: str) -> Snapshot:
        """Load + checksum-verify one blob; raises ``ValueError`` on
        corruption (callers normally go through :meth:`latest`, which
        quarantines and falls back instead)."""
        m = _NAME_RE.fullmatch(os.path.basename(path))
        if m is None:
            raise ValueError(f"not a snapshot blob: {path}")
        with _span("resilience.snapshot_load") as sp:
            with np.load(path) as blob:
                arrays = {name: blob[name] for name in blob.files}
            meta = json.loads(bytes(arrays["meta"]).decode())
            # recompute in save order: factors, lam, fits, meta
            ordered = {f"factor{i}": arrays[f"factor{i}"]
                       for i in range(meta["n_factors"])}
            ordered["lam"] = arrays["lam"]
            ordered["fits"] = arrays["fits"]
            ordered["meta"] = arrays["meta"]
            digest = payload_digest(ordered)
            if digest[:12] != m.group("digest"):
                raise ValueError(
                    f"snapshot payload digest mismatch: {path}")
            sp.set("sweep", meta["sweep"])
        self.loads += 1
        _counter("snapshot_events",
                 "sweep snapshot saves/loads/corruptions").inc("load")
        return Snapshot(
            fingerprint=meta["fingerprint"], sweep=meta["sweep"],
            factors=[arrays[f"factor{i}"]
                     for i in range(meta["n_factors"])],
            lam=arrays["lam"], fits=list(arrays["fits"]), path=path)

    def latest(self, fp: str) -> Snapshot | None:
        """Newest intact snapshot for ``fp``; corrupt blobs met on the
        way are quarantined (``*.corrupt``) and skipped."""
        for _, name in reversed(self._blobs(fp[:16])):
            path = os.path.join(self.dir, name)
            try:
                snap = self.load(path)
            except Exception:
                self._quarantine(path)
                continue
            if snap.fingerprint != fp:  # 16-hex-char prefix collision
                continue
            return snap
        return None

    def _quarantine(self, path: str) -> None:
        self.corrupt += 1
        _counter("snapshot_events",
                 "sweep snapshot saves/loads/corruptions").inc("corrupt")
        with _span("resilience.snapshot_quarantine",
                   path=os.path.basename(path)):
            try:
                os.replace(path, path + ".corrupt")
            except OSError:
                pass
