"""Atomic, content-addressed ALS sweep snapshots (checkpoint/resume).

An hour-long ``cp_als`` / ``cp_als_stream`` / distributed sweep dies on
the first preemption today, losing every completed sweep. This module
makes sweep boundaries durable:

* **Fingerprinted.** A snapshot is bound to a :func:`fingerprint` of the
  exact problem — tensor bytes (indices + values + dims), rank, the
  ``ExecutionConfig``/``PlanSpec`` repr, the init PRNG key and the start
  mode. Resume refuses snapshots from a *different* problem, because the
  whole point is bitwise-identical continuation: at a sweep boundary the
  engine layout has rotated back to its start-mode arrangement, so
  ``(factors, lam)`` are the complete dynamic state and replaying the
  remaining sweeps reproduces an uninterrupted run bit for bit.
* **Atomic + checksummed.** Writes go to a tmp file in the destination
  directory and are published with ``os.replace``; the payload digest is
  part of the *filename*, so a torn or bit-rotten blob is detected on
  load (recompute + compare), quarantined (renamed ``*.corrupt``), and
  the loader falls back to the next-older sweep instead of resuming from
  garbage.
* **Observable.** Saves/loads/corruptions tick the ``snapshot_events``
  counter and wrap in ``resilience.snapshot_*`` spans.

Layout: ``<dir>/<fp16>-sweep<NNNNNN>-<digest12>.npz`` — one flat npz per
snapshot (per-factor arrays + ``lam`` + ``fits`` + a JSON meta string),
``keep`` newest retained per fingerprint.

**Sharded payloads (format v2).** A distributed sweep saves *per-device
factor shards* (``factor{i}_s{j}`` keyed by row offset) instead of one
monolithic array per factor, plus the saving mesh's fingerprint
(:func:`mesh_fingerprint`: device count, axis layout, platform) and the
``DistConfig`` knobs — both live in the JSON meta and are therefore part
of the payload digest. The *problem* fingerprint deliberately excludes
them: at a sweep boundary ``(factors, lam)`` are layout- and
mesh-independent, so a snapshot written on 4 devices restores on 2 (or
1) — :meth:`SnapshotStore.load` reassembles the shards host-side and the
caller re-shards onto the *current* mesh (``engine.dist.shard_state``,
the ``training/checkpoint.py`` reshard-on-load idiom). The recorded mesh
fingerprint says where the shards came from; it never constrains where
they may go.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import re
from typing import Sequence

import numpy as np

from repro.obs.metrics import counter as _counter
from repro.obs.trace import span as _span

__all__ = ["fingerprint", "payload_digest", "mesh_fingerprint",
           "factor_shards", "Snapshot", "SnapshotStore", "as_store"]

_FORMAT_VERSION = 1
_SHARDED_VERSION = 2
_NAME_RE = re.compile(
    r"(?P<fp>[0-9a-f]{16})-sweep(?P<sweep>\d{6})-(?P<digest>[0-9a-f]{12})"
    r"\.npz")


def fingerprint(indices, values, dims: Sequence[int], rank: int,
                config=None, key=None, start_mode: int = 0,
                extra: str = "") -> str:
    """Content address of one decomposition problem (sha256 hex).

    Hashes the exact tensor bytes plus every knob that changes the traced
    computation — two runs share a fingerprint iff an uninterrupted run
    and a resumed run would produce bitwise-identical factors.
    """
    h = hashlib.sha256()
    h.update(repr((tuple(int(d) for d in dims), int(rank),
                   int(start_mode), repr(config), extra,
                   _FORMAT_VERSION)).encode())
    h.update(np.ascontiguousarray(indices).tobytes())
    h.update(np.ascontiguousarray(values).tobytes())
    if key is not None:
        h.update(np.asarray(key).tobytes())
    return h.hexdigest()


def payload_digest(arrays: dict) -> str:
    """Order-stable sha256 over a dict of numpy arrays (key order is the
    caller's contract). Shared by the snapshot store and the
    ``PlanCache`` disk guardrail so both verify blobs the same way."""
    h = hashlib.sha256()
    for name in arrays:
        a = np.ascontiguousarray(arrays[name])
        h.update(name.encode())
        h.update(str(a.dtype).encode())
        h.update(repr(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()


def mesh_fingerprint(mesh) -> dict:
    """JSON-able identity of a device mesh: total device count, the
    ``{axis: size}`` layout and the platform of its devices. Recorded in
    sharded snapshot meta (and hence the payload digest) so a restore can
    tell — and report — that it is re-sharding onto a different mesh."""
    devices = np.asarray(mesh.devices).reshape(-1)
    return {"n_dev": int(devices.size),
            "axes": {str(k): int(v) for k, v in dict(mesh.shape).items()},
            "platform": str(getattr(devices[0], "platform", "unknown"))}


def factor_shards(arr) -> list[tuple[int, np.ndarray]]:
    """``(row_offset, host_shard)`` pairs covering ``arr`` exactly once.

    A jax array sharded along axis 0 yields one entry per distinct row
    range (replicas deduplicated); a replicated or plain host array
    yields a single ``(0, full)`` entry. Row order is ascending, so
    concatenation reassembles the array.
    """
    shards = getattr(arr, "addressable_shards", None)
    if shards is None:
        return [(0, np.asarray(arr))]
    seen: dict[int, np.ndarray] = {}
    for sh in shards:
        idx = sh.index[0] if sh.index else slice(None)
        row0 = int(idx.start or 0)
        if row0 not in seen:
            seen[row0] = np.asarray(sh.data)
    return sorted(seen.items())


def as_store(checkpoint) -> "SnapshotStore | None":
    """Normalize a user-facing ``checkpoint=`` argument: ``None``/``False``
    -> off, a directory path -> a fresh :class:`SnapshotStore` over it, a
    store -> itself."""
    if checkpoint is None or checkpoint is False:
        return None
    if isinstance(checkpoint, SnapshotStore):
        return checkpoint
    return SnapshotStore(os.fspath(checkpoint))


@dataclasses.dataclass
class Snapshot:
    """One loaded sweep snapshot (host numpy; ``sweep`` is the number of
    *completed* sweeps — resume continues at sweep ``sweep``)."""

    fingerprint: str
    sweep: int
    factors: list[np.ndarray]
    lam: np.ndarray
    fits: list[float]
    path: str
    mesh: dict | None = None      # saving mesh's fingerprint (v2 blobs)
    dist: str | None = None       # DistConfig repr at save time (v2)


class SnapshotStore:
    """Directory of fingerprinted sweep snapshots; see module docstring.

    ``save`` is cheap relative to a sweep (host copy + one npz write) and
    safe to call every sweep; ``latest`` returns the newest *intact*
    snapshot for a fingerprint, quarantining any corrupt blob it meets on
    the way down.
    """

    def __init__(self, directory: str, keep: int = 3):
        if keep < 1:
            raise ValueError("keep must be >= 1")
        self.dir = os.fspath(directory)
        self.keep = keep
        self.saves = 0
        self.loads = 0
        self.corrupt = 0

    # ------------------------------------------------------------------ save
    def save(self, fp: str, sweep: int, factors, lam,
             fits: Sequence[float] = (), *, mesh=None, dist=None) -> str:
        """Persist one completed-sweep state; returns the blob path.

        With ``mesh=`` the blob is written in the sharded v2 format:
        per-device factor shards plus the mesh fingerprint and the
        ``DistConfig`` repr in the digest-covered meta (module
        docstring). Without it the flat v1 format is written unchanged.
        """
        with _span("resilience.snapshot_save", sweep=sweep) as sp:
            arrays: dict = {}
            if mesh is not None:
                shard_meta = []
                for i, f in enumerate(factors):
                    shards = factor_shards(f)
                    shard_meta.append(
                        {"rows": [r for r, _ in shards],
                         "shape": [int(s) for s in np.shape(f)]})
                    for j, (_, data) in enumerate(shards):
                        arrays[f"factor{i}_s{j}"] = data
            else:
                for i, f in enumerate(factors):
                    arrays[f"factor{i}"] = np.asarray(f)
            arrays["lam"] = np.asarray(lam)
            arrays["fits"] = np.asarray(list(fits), dtype=np.float64)
            meta = {"version": (_SHARDED_VERSION if mesh is not None
                                else _FORMAT_VERSION),
                    "fingerprint": fp, "sweep": int(sweep),
                    "n_factors": len(factors)}
            if mesh is not None:
                meta["shards"] = shard_meta
                meta["mesh"] = mesh_fingerprint(mesh)
                meta["dist"] = repr(dist)
            arrays["meta"] = np.frombuffer(
                json.dumps(meta).encode(), dtype=np.uint8)
            digest = payload_digest(arrays)
            os.makedirs(self.dir, exist_ok=True)
            fn = os.path.join(
                self.dir, f"{fp[:16]}-sweep{sweep:06d}-{digest[:12]}.npz")
            tmp = os.path.join(self.dir,
                               f".tmp-{os.getpid()}-{fp[:16]}-{sweep}")
            with open(tmp, "wb") as f:
                np.savez(f, **arrays)
            os.replace(tmp, fn)
            sp.set("path", os.path.basename(fn))
        self.saves += 1
        _counter("snapshot_events",
                 "sweep snapshot saves/loads/corruptions").inc("save")
        self._gc(fp[:16])
        return fn

    def _gc(self, fp16: str) -> None:
        blobs = self._blobs(fp16)
        for _, fn in blobs[:-self.keep]:
            try:
                os.remove(os.path.join(self.dir, fn))
            except OSError:
                pass

    def _blobs(self, fp16: str | None = None) -> list[tuple[int, str]]:
        """(sweep, filename) of every snapshot blob, sweep-ascending."""
        try:
            names = os.listdir(self.dir)
        except FileNotFoundError:
            return []
        out = []
        for name in names:
            m = _NAME_RE.fullmatch(name)
            if m and (fp16 is None or m.group("fp") == fp16):
                out.append((int(m.group("sweep")), name))
        return sorted(out)

    # ------------------------------------------------------------------ load
    def load(self, path: str) -> Snapshot:
        """Load + checksum-verify one blob; raises ``ValueError`` on
        corruption (callers normally go through :meth:`latest`, which
        quarantines and falls back instead)."""
        m = _NAME_RE.fullmatch(os.path.basename(path))
        if m is None:
            raise ValueError(f"not a snapshot blob: {path}")
        with _span("resilience.snapshot_load") as sp:
            with np.load(path) as blob:
                arrays = {name: blob[name] for name in blob.files}
            meta = json.loads(bytes(arrays["meta"]).decode())
            sharded = meta["version"] >= _SHARDED_VERSION
            # recompute in save order: factors (or their shards), lam,
            # fits, meta
            ordered: dict = {}
            if sharded:
                for i, sm in enumerate(meta["shards"]):
                    for j in range(len(sm["rows"])):
                        ordered[f"factor{i}_s{j}"] = \
                            arrays[f"factor{i}_s{j}"]
            else:
                for i in range(meta["n_factors"]):
                    ordered[f"factor{i}"] = arrays[f"factor{i}"]
            ordered["lam"] = arrays["lam"]
            ordered["fits"] = arrays["fits"]
            ordered["meta"] = arrays["meta"]
            digest = payload_digest(ordered)
            if digest[:12] != m.group("digest"):
                raise ValueError(
                    f"snapshot payload digest mismatch: {path}")
            sp.set("sweep", meta["sweep"])
            if sharded:
                factors = []
                for i, sm in enumerate(meta["shards"]):
                    first = arrays[f"factor{i}_s0"]
                    full = np.empty(tuple(sm["shape"]), dtype=first.dtype)
                    for j, row0 in enumerate(sm["rows"]):
                        data = arrays[f"factor{i}_s{j}"]
                        full[row0:row0 + data.shape[0]] = data
                    factors.append(full)
            else:
                factors = [arrays[f"factor{i}"]
                           for i in range(meta["n_factors"])]
        self.loads += 1
        _counter("snapshot_events",
                 "sweep snapshot saves/loads/corruptions").inc("load")
        return Snapshot(
            fingerprint=meta["fingerprint"], sweep=meta["sweep"],
            factors=factors, lam=arrays["lam"],
            fits=list(arrays["fits"]), path=path,
            mesh=meta.get("mesh"), dist=meta.get("dist"))

    def latest(self, fp: str) -> Snapshot | None:
        """Newest intact snapshot for ``fp``; corrupt blobs met on the
        way are quarantined (``*.corrupt``) and skipped."""
        for _, name in reversed(self._blobs(fp[:16])):
            path = os.path.join(self.dir, name)
            try:
                snap = self.load(path)
            except Exception:
                self._quarantine(path)
                continue
            if snap.fingerprint != fp:  # 16-hex-char prefix collision
                continue
            return snap
        return None

    def _quarantine(self, path: str) -> None:
        self.corrupt += 1
        _counter("snapshot_events",
                 "sweep snapshot saves/loads/corruptions").inc("corrupt")
        with _span("resilience.snapshot_quarantine",
                   path=os.path.basename(path)):
            try:
                os.replace(path, path + ".corrupt")
            except OSError:
                pass
