"""repro.resilience — fault injection, checkpoint/resume, degradation
ladder, numerical guardrails.

The scaling tiers (streamed chunk ring, distributed exchange, plan
cache) assume hour-long runs on preemptible hardware; this package is
what lets those runs *finish*:

:mod:`~repro.resilience.snapshot`
    Atomic, content-addressed sweep snapshots. ``cp_als`` /
    ``cp_als_stream`` write one per ``checkpoint_every`` sweeps (tmp +
    ``os.replace``, payload digest in the filename); ``resume=True``
    loads the newest intact snapshot *for the same problem fingerprint*
    and replays the remaining sweeps — bitwise-identical final factors
    vs an uninterrupted run, because at a sweep boundary ``(factors,
    lam)`` are the complete dynamic state (the layout has rotated back
    to its start arrangement). Distributed sweeps write the *sharded*
    v2 format — per-device factor shards plus the saving mesh's
    fingerprint and ``DistConfig`` knobs inside the digest-covered
    meta. The problem fingerprint stays mesh-independent, so an elastic
    restart on a different device count restores the reassembled
    factors and re-shards them onto the *current* mesh, still bitwise.

:mod:`~repro.resilience.ladder`
    Policy-driven retry/fallback chain. The rung table:

    ======================  =======================================
    failure                 rung
    ======================  =======================================
    compile / lowering      backend ``pallas_fused -> pallas ->
                            xla -> ref`` (rebuild state, bitwise)
    OOM (resident place)    residency ``full -> stream``
    OOM (streamed chunk)    chunk budget halved + replan (cached)
    transient transfer      retry with seeded backoff
    exchange (dist)         ``collective_permute -> all_gather``
                            (bitwise by the exchange parity test)
    device lost (dist)      mesh shrink: re-plan + re-shard on the
                            survivors, roll back to latest snapshot
    transient dist dispatch retry with seeded backoff
    ======================  =======================================

    Every transition is a ``resilience_degradations``/
    ``resilience_retries`` counter + span — degradations are
    observable, never silent. ``REPRO_LADDER=...`` installs an ambient
    policy from the environment (``ladder.from_env``), picked up by
    every ``ladder=None`` call site.

:mod:`~repro.resilience.chaos`
    Deterministic seeded fault injectors (upload failure, OOM at chunk
    k, resident-placement OOM, compile failure per backend, NaN burst,
    SIGKILL at sweep k, torn cache blob; distributed: exchange failure,
    device loss, transient dist dispatch) threaded through the
    stream/factory/plancache/dispatch hooks — ``engine.dist`` included.
    ``REPRO_CHAOS=...`` installs a spec from the environment
    (subprocess / CI scenarios); every fired fault ticks
    ``chaos_injections`` so :func:`repro.obs.report.resilience_report`
    can pair faults with the resilience events that answered them.

:mod:`~repro.resilience.guard`
    Per-sweep NaN/Inf detection; on a burst the sweep is rolled back and
    replayed under a stronger ridge (``cp_als``'s recovery fold).

The :class:`~repro.core.plancache.PlanCache` disk tier uses the same
digest (:func:`snapshot.payload_digest`) to checksum-verify every blob
load, quarantining corrupt files (``*.corrupt``) and rebuilding cold —
counted as ``disk_corrupt`` in ``PlanCache.stats()``.
"""
from . import chaos, ladder
from .chaos import (Chaos, ChaosCompileError, ChaosDeviceLost, ChaosError,
                    ChaosExchangeError, ChaosOOM, ChaosSpec, ChaosUploadError,
                    active, from_env, install, uninstall)
from .snapshot import (Snapshot, SnapshotStore, as_store, factor_shards,
                       fingerprint, mesh_fingerprint, payload_digest)
from .ladder import (DEFAULT_POLICY, LadderPolicy, ambient, backoff_delay,
                     classify, install_ambient, next_backend,
                     record_degradation, record_retry, resolve_policy,
                     uninstall_ambient)
from .guard import all_finite, record_recovery

# NOTE: package-level ``from_env`` is *chaos*'s (REPRO_CHAOS); the ladder's
# REPRO_LADDER parser stays module-scoped as ``ladder.from_env``.
__all__ = [
    "chaos", "ladder", "Chaos", "ChaosSpec", "ChaosError",
    "ChaosUploadError", "ChaosOOM", "ChaosCompileError",
    "ChaosExchangeError", "ChaosDeviceLost", "install", "uninstall",
    "active", "from_env",
    "Snapshot", "SnapshotStore", "as_store", "fingerprint",
    "payload_digest", "mesh_fingerprint", "factor_shards",
    "LadderPolicy", "DEFAULT_POLICY", "classify", "next_backend",
    "backoff_delay", "record_degradation", "record_retry",
    "resolve_policy", "ambient", "install_ambient", "uninstall_ambient",
    "all_finite", "record_recovery",
]
