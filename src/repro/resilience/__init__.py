"""repro.resilience — fault injection, checkpoint/resume, degradation
ladder, numerical guardrails.

The scaling tiers (streamed chunk ring, distributed exchange, plan
cache) assume hour-long runs on preemptible hardware; this package is
what lets those runs *finish*:

:mod:`~repro.resilience.snapshot`
    Atomic, content-addressed sweep snapshots. ``cp_als`` /
    ``cp_als_stream`` write one per ``checkpoint_every`` sweeps (tmp +
    ``os.replace``, payload digest in the filename); ``resume=True``
    loads the newest intact snapshot *for the same problem fingerprint*
    and replays the remaining sweeps — bitwise-identical final factors
    vs an uninterrupted run, because at a sweep boundary ``(factors,
    lam)`` are the complete dynamic state (the layout has rotated back
    to its start arrangement).

:mod:`~repro.resilience.ladder`
    Policy-driven retry/fallback chain: compile/lowering failures step
    the backend down ``pallas_fused -> pallas -> xla -> ref``; OOM steps
    residency ``full -> stream`` or halves the streamed chunk budget and
    replans; transient upload failures retry with bounded exponential
    backoff and seeded jitter. Every transition is a
    ``resilience_degradations``/``resilience_retries`` counter + span —
    degradations are observable, never silent.

:mod:`~repro.resilience.chaos`
    Deterministic seeded fault injectors (upload failure, OOM at chunk
    k, resident-placement OOM, compile failure per backend, NaN burst,
    SIGKILL at sweep k, torn cache blob) threaded through the
    stream/factory/plancache/dispatch hooks. ``REPRO_CHAOS=...``
    installs a spec from the environment (subprocess / CI scenarios);
    every fired fault ticks ``chaos_injections`` so
    :func:`repro.obs.report.resilience_report` can pair faults with the
    resilience events that answered them.

:mod:`~repro.resilience.guard`
    Per-sweep NaN/Inf detection; on a burst the sweep is rolled back and
    replayed under a stronger ridge (``cp_als``'s recovery fold).

The :class:`~repro.core.plancache.PlanCache` disk tier uses the same
digest (:func:`snapshot.payload_digest`) to checksum-verify every blob
load, quarantining corrupt files (``*.corrupt``) and rebuilding cold —
counted as ``disk_corrupt`` in ``PlanCache.stats()``.
"""
from . import chaos
from .chaos import (Chaos, ChaosCompileError, ChaosError, ChaosOOM,
                    ChaosSpec, ChaosUploadError, active, from_env, install,
                    uninstall)
from .snapshot import (Snapshot, SnapshotStore, as_store, fingerprint,
                       payload_digest)
from .ladder import (DEFAULT_POLICY, LadderPolicy, backoff_delay, classify,
                     next_backend, record_degradation, record_retry,
                     resolve_policy)
from .guard import all_finite, record_recovery

__all__ = [
    "chaos", "Chaos", "ChaosSpec", "ChaosError", "ChaosUploadError",
    "ChaosOOM", "ChaosCompileError", "install", "uninstall", "active",
    "from_env",
    "Snapshot", "SnapshotStore", "as_store", "fingerprint",
    "payload_digest",
    "LadderPolicy", "DEFAULT_POLICY", "classify", "next_backend",
    "backoff_delay", "record_degradation", "record_retry",
    "resolve_policy",
    "all_finite", "record_recovery",
]
