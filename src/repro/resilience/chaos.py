"""Deterministic fault injection (``resilience.chaos``).

Long-running decompositions die in boring, reproducible ways: a host→
device upload fails transiently, the device OOMs on chunk ``k``, a cached
plan blob is torn mid-write, a factor matrix picks up a NaN burst, the
whole process is SIGKILLed between sweeps. This module injects exactly
those faults, *deterministically*, through hooks the production paths
already call — so the degradation ladder, the checkpoint/resume path and
the cache guardrails are exercised by tests and the CI ``chaos-smoke``
job instead of being dead code until the first real outage.

Design rules:

* **Seeded and ordinal-addressed.** Every injector fires at a fixed
  ordinal of its site (``upload_fail=2`` fails the third distinct chunk
  upload) a fixed number of times, then never again — retries and
  fallbacks therefore *succeed* deterministically, which is what lets
  chaos runs gate bitwise parity against clean runs.
* **Observable.** Every fired injection increments the
  ``chaos_injections`` counter (by site), so the ``no silent
  degradation`` gate can pair each fault with the resilience event that
  answered it (:func:`repro.obs.report.resilience_report`).
* **Off by default, env-installable.** Production code pays one
  ``is None`` test per hook site (the ``repro.obs.trace`` pattern).
  ``REPRO_CHAOS="upload_fail=1,oom_chunk=3,seed=7"`` installs a spec at
  import time for subprocess/CI scenarios.

Fault model (``ChaosSpec`` fields):

  ``upload_fail``    fail the Nth distinct chunk upload (0-based) for
                     ``upload_fail_times`` consecutive attempts
                     (transient — answered by retry-with-backoff)
  ``oom_chunk``      raise :class:`ChaosOOM` on the Nth chunk compute,
                     once (answered by chunk-budget halving + replan)
  ``oom_resident``   raise :class:`ChaosOOM` once while placing the
                     full-residency layout (answered by the
                     ``residency full -> stream`` ladder rung)
  ``compile_fail``   backends whose every dispatch raises
                     :class:`ChaosCompileError` (answered by the
                     backend ladder ``pallas_fused -> pallas -> xla ->
                     ref``)
  ``nan_sweep``      overwrite one factor entry with NaN after sweep N
                     (answered by rollback + ridge-recovery re-sweep)
  ``kill_sweep``     SIGKILL the process at the *start* of sweep N
                     (answered by checkpoint/resume — works under a mesh
                     too: ``cp_als(mesh=...)`` calls the same hook)
  ``corrupt_blob``   truncate the next ``PlanCache`` disk blob after it
                     lands (answered by checksum quarantine + rebuild)

Distributed fault model (hook site: ``engine.dist`` dispatch):

  ``exchange_fail``  raise :class:`ChaosExchangeError` at the Nth dist
                     dispatch running the ``collective_permute``
                     exchange, once (answered by the ``permute ->
                     all_gather`` ladder rung — bitwise-identical by the
                     exchange parity guarantee)
  ``device_lost``    raise :class:`ChaosDeviceLost` at the Nth dist
                     dispatch, once; ``device_lost_n`` devices die
                     (answered by mesh-shrink: re-plan + re-shard on the
                     survivors from the latest snapshot)
  ``dist_transient`` fail the Nth dist dispatch transiently for
                     ``dist_transient_times`` attempts (answered by the
                     same retry-with-backoff path stream uploads have)
"""
from __future__ import annotations

import dataclasses
import os
import signal

from repro.obs.metrics import counter as _counter
from repro.obs.trace import span as _span

__all__ = ["ChaosError", "ChaosUploadError", "ChaosOOM",
           "ChaosCompileError", "ChaosExchangeError", "ChaosDeviceLost",
           "ChaosSpec", "Chaos", "install", "uninstall", "active",
           "from_env", "ENV_VAR"]

ENV_VAR = "REPRO_CHAOS"


class ChaosError(RuntimeError):
    """Base class for injected faults."""


class ChaosUploadError(ChaosError):
    """Injected transient host->device transfer failure."""


class ChaosOOM(ChaosError):
    """Injected device allocation failure (classified as OOM)."""


class ChaosCompileError(ChaosError):
    """Injected kernel compile/lowering failure."""


class ChaosExchangeError(ChaosError):
    """Injected collective-exchange (``collective_permute``) failure."""


class ChaosDeviceLost(ChaosError):
    """Injected device loss; ``lost`` carries how many devices died."""

    def __init__(self, msg: str, lost: int = 1):
        super().__init__(msg)
        self.lost = lost


@dataclasses.dataclass(frozen=True)
class ChaosSpec:
    """Declarative, seeded fault plan (see module docstring)."""

    seed: int = 0
    upload_fail: int | None = None
    upload_fail_times: int = 1
    oom_chunk: int | None = None
    oom_resident: bool = False
    compile_fail: tuple = ()
    nan_sweep: int | None = None
    kill_sweep: int | None = None
    corrupt_blob: bool = False
    exchange_fail: int | None = None
    device_lost: int | None = None
    device_lost_n: int = 1
    dist_transient: int | None = None
    dist_transient_times: int = 1

    def __post_init__(self):
        if self.upload_fail_times < 1:
            raise ValueError("upload_fail_times must be >= 1")
        if self.dist_transient_times < 1:
            raise ValueError("dist_transient_times must be >= 1")
        if self.device_lost_n < 1:
            raise ValueError("device_lost_n must be >= 1")


class Chaos:
    """Live injector: a :class:`ChaosSpec` plus the ordinal counters that
    make every fault fire at exactly one deterministic point."""

    def __init__(self, spec: ChaosSpec):
        self.spec = spec
        self._upload_ordinal: dict = {}      # (mode, chunk) -> ordinal
        self._upload_attempts: dict = {}     # (mode, chunk) -> failed tries
        self._compute_calls = 0
        self._dist_calls = 0                 # distinct dist dispatches
        self._exchange_calls = 0             # ... of which run permute
        self._dist_attempts = 0              # transient tries at target
        self._fired: set[str] = set()

    # ------------------------------------------------------------- recording
    def _record(self, site: str, **attrs) -> None:
        _counter("chaos_injections",
                 "injected faults by site (resilience.chaos)").inc(site)
        with _span("chaos.inject", site=site, **attrs):
            pass

    def fired(self, site: str) -> bool:
        return site in self._fired

    # ----------------------------------------------------------- hook sites
    def on_upload(self, mode: int, chunk: int, attempt: int) -> None:
        """Called per upload attempt; raises ChaosUploadError while the
        targeted distinct upload has failures left."""
        fail_at = self.spec.upload_fail
        if fail_at is None:
            return
        key = (mode, chunk)
        ordinal = self._upload_ordinal.setdefault(
            key, len(self._upload_ordinal))
        if ordinal != fail_at:
            return
        tries = self._upload_attempts.get(key, 0)
        if tries >= self.spec.upload_fail_times:
            return
        self._upload_attempts[key] = tries + 1
        self._fired.add("upload_fail")
        self._record("upload_fail", mode=mode, chunk=chunk, attempt=attempt)
        raise ChaosUploadError(
            f"injected upload failure (mode {mode}, chunk {chunk}, "
            f"attempt {attempt})")

    def on_chunk_compute(self, mode: int, chunk: int) -> None:
        """Called before each streamed chunk compute; raises ChaosOOM once
        at the configured call ordinal."""
        at = self.spec.oom_chunk
        ordinal = self._compute_calls
        self._compute_calls += 1
        if at is None or "oom_chunk" in self._fired or ordinal != at:
            return
        self._fired.add("oom_chunk")
        self._record("oom_chunk", mode=mode, chunk=chunk)
        raise ChaosOOM(
            f"injected RESOURCE_EXHAUSTED at chunk compute {ordinal} "
            f"(mode {mode}, chunk {chunk})")

    def on_resident_init(self) -> None:
        """Called before the full-residency device placement; raises
        ChaosOOM once when ``oom_resident`` is set."""
        if not self.spec.oom_resident or "oom_resident" in self._fired:
            return
        self._fired.add("oom_resident")
        self._record("oom_resident")
        raise ChaosOOM("injected RESOURCE_EXHAUSTED placing resident layout")

    def on_dispatch(self, backend: str) -> None:
        """Called before jitted dispatch; every dispatch of a backend in
        ``compile_fail`` raises (deterministic ladder ordering)."""
        if backend in self.spec.compile_fail:
            self._fired.add("compile_fail")
            self._record("compile_fail", backend=backend)
            raise ChaosCompileError(
                f"injected Mosaic lowering failure for backend "
                f"{backend!r}")

    def on_dist_dispatch(self, backend: str, *, exchange: str, n_dev: int,
                         attempt: int = 0) -> None:
        """Called before each distributed (``engine.dist``) dispatch.

        Ordinals advance once per *distinct* dispatch (``attempt == 0``)
        so a retried dispatch stays addressed by the same ordinal. Order
        of checks: compile (shares ``compile_fail`` with the resident
        path) -> device loss -> exchange failure -> transient.
        """
        self.on_dispatch(backend)
        if attempt == 0:
            ordinal = self._dist_calls
            self._dist_calls += 1
            exchange_ordinal = self._exchange_calls
            if exchange == "permute":
                self._exchange_calls += 1
        else:
            ordinal = self._dist_calls - 1
            exchange_ordinal = self._exchange_calls - 1
        at = self.spec.device_lost
        if at is not None and ordinal == at \
                and "device_lost" not in self._fired:
            lost = self.spec.device_lost_n
            self._fired.add("device_lost")
            self._record("device_lost", ordinal=ordinal, lost=lost,
                         n_dev=n_dev)
            raise ChaosDeviceLost(
                f"injected loss of {lost} device(s) at dist dispatch "
                f"{ordinal} (mesh had {n_dev})", lost=lost)
        at = self.spec.exchange_fail
        if at is not None and exchange == "permute" \
                and exchange_ordinal == at \
                and "exchange_fail" not in self._fired:
            self._fired.add("exchange_fail")
            self._record("exchange_fail", ordinal=exchange_ordinal)
            raise ChaosExchangeError(
                f"injected collective_permute failure at dist dispatch "
                f"{exchange_ordinal}")
        at = self.spec.dist_transient
        if at is not None and ordinal == at \
                and self._dist_attempts < self.spec.dist_transient_times:
            self._dist_attempts += 1
            self._fired.add("dist_transient")
            self._record("dist_transient", ordinal=ordinal,
                         attempt=attempt)
            raise ChaosUploadError(
                f"injected transient dist dispatch failure at ordinal "
                f"{ordinal} (attempt {attempt})")

    def mangle_factors(self, sweep: int, factors):
        """Called after each ALS sweep; injects one NaN into factor 0 at
        the configured sweep (once). Returns the (possibly mangled)
        factors."""
        if self.spec.nan_sweep is None or sweep != self.spec.nan_sweep \
                or "nan_burst" in self._fired:
            return factors
        self._fired.add("nan_burst")
        self._record("nan_burst", sweep=sweep)
        import jax.numpy as jnp

        factors = list(factors)
        factors[0] = factors[0].at[0, 0].set(jnp.nan)
        return tuple(factors)

    def maybe_kill(self, sweep: int) -> None:
        """Called at the start of each ALS sweep; SIGKILLs the process at
        the configured sweep — the preemption scenario."""
        if self.spec.kill_sweep is None or sweep != self.spec.kill_sweep:
            return
        self._record("kill_sweep", sweep=sweep)
        os.kill(os.getpid(), signal.SIGKILL)

    def on_disk_save(self, path: str) -> None:
        """Called after a ``PlanCache`` blob lands on disk; truncates it
        once to simulate a torn write when ``corrupt_blob`` is set."""
        if not self.spec.corrupt_blob or "corrupt_blob" in self._fired:
            return
        self._fired.add("corrupt_blob")
        self._record("corrupt_blob", path=os.path.basename(path))
        size = os.path.getsize(path)
        with open(path, "r+b") as f:
            f.truncate(max(size // 2, 1))


# --------------------------------------------------------------------------
# Global installer + env opt-in (the repro.obs.trace pattern).
# --------------------------------------------------------------------------
_ACTIVE: Chaos | None = None


def install(spec: ChaosSpec | Chaos) -> Chaos:
    """Install ``spec`` as the process-global injector; returns it."""
    global _ACTIVE
    _ACTIVE = spec if isinstance(spec, Chaos) else Chaos(spec)
    return _ACTIVE


def uninstall() -> Chaos | None:
    """Remove the global injector (hooks become no-ops); returns it."""
    global _ACTIVE
    prev, _ACTIVE = _ACTIVE, None
    return prev


def active() -> Chaos | None:
    """The global injector, or ``None`` while chaos is off (the hook
    fast path: one global load + one ``is None`` test)."""
    return _ACTIVE


def from_env(value: str) -> ChaosSpec:
    """Parse a ``REPRO_CHAOS`` spec string.

    Comma-separated ``key=value`` items mirroring :class:`ChaosSpec`
    fields; ``compile_fail`` takes ``|``-separated backend names; bare
    flags (``corrupt_blob``/``oom_resident``) mean ``True``::

        REPRO_CHAOS="upload_fail=1,oom_chunk=3,kill_sweep=2,seed=7"
        REPRO_CHAOS="compile_fail=pallas_fused|pallas,corrupt_blob"
    """
    kwargs: dict = {}
    for item in value.split(","):
        item = item.strip()
        if not item:
            continue
        key, _, raw = item.partition("=")
        key = key.strip()
        raw = raw.strip()
        if key in ("corrupt_blob", "oom_resident"):
            kwargs[key] = raw.lower() not in ("0", "false") if raw else True
        elif key == "compile_fail":
            kwargs[key] = tuple(b for b in raw.split("|") if b)
        elif key in ("seed", "upload_fail", "upload_fail_times",
                     "oom_chunk", "nan_sweep", "kill_sweep",
                     "exchange_fail", "device_lost", "device_lost_n",
                     "dist_transient", "dist_transient_times"):
            kwargs[key] = int(raw)
        else:
            raise ValueError(f"unknown {ENV_VAR} key {key!r}")
    return ChaosSpec(**kwargs)


def _init_from_env() -> None:
    value = os.environ.get(ENV_VAR, "").strip()
    if not value or value.lower() in ("0", "false", "off"):
        return
    install(from_env(value))


_init_from_env()
