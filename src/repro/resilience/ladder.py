"""Policy-driven degradation ladder: classify failures, step down, retry.

The production stance is *degrade, never die silently*: a Mosaic compile
or lowering failure steps the backend down the fixed ladder
``pallas_fused -> pallas -> xla -> ref`` (``engine.config.BACKEND_LADDER``
— each rung strictly more portable, bitwise-identical output); a device
OOM steps residency ``full -> stream`` (``engine.factory.make_engine``)
or halves the streamed chunk budget and replans
(``engine.stream.stream_mttkrp``); a transient transfer failure retries
with bounded exponential backoff and *deterministic seeded jitter*, so
chaos runs replay identically. Every transition is recorded as a
``resilience_degradations`` counter label plus a ``resilience.degrade``
span — a fallback that leaves no metric is a bug the CI ``chaos-smoke``
job catches.

The distributed tier adds two rungs of its own: an exchange failure
steps ``collective_permute -> all_gather`` (bitwise-identical by the
exchange parity guarantee, ``engine.dist``), and a lost device shrinks
the mesh — ``DistState`` is re-planned and re-sharded on the survivors
from the latest snapshot (``core.cpd.cp_als``). Distributed dispatch
gets the same transient retry-with-backoff path stream uploads have.

This module owns the shared pieces (classification, policy, backoff,
recording); the *application* sites live where the failures happen —
``core.cpd.cp_als`` (backend + dist rungs per sweep), ``engine.stream``
(chunk-budget rungs + upload retries), ``engine.factory`` (residency
rung), ``engine.dist`` (dispatch retries).

Fleet defaults need no code changes: ``REPRO_LADDER=1`` (or a
``key=value`` spec mirroring :class:`LadderPolicy` fields, e.g.
``REPRO_LADDER="max_retries=5,backoff_cap_s=1.0"``) installs an
*ambient* policy at import time — any ``ladder=None`` call site picks it
up through :func:`resolve_policy`; ``ladder=False`` still opts out
explicitly.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import time

from repro.obs.metrics import counter as _counter
from repro.obs.trace import span as _span

from .chaos import (ChaosCompileError, ChaosDeviceLost,
                    ChaosExchangeError, ChaosOOM, ChaosUploadError)

__all__ = ["LadderPolicy", "DEFAULT_POLICY", "classify", "next_backend",
           "backoff_delay", "record_degradation", "record_retry",
           "resolve_policy", "from_env", "install_ambient",
           "uninstall_ambient", "ambient", "ENV_VAR"]

ENV_VAR = "REPRO_LADDER"

# Substrings identifying real JAX/XLA failure flavors without importing
# backend-specific exception types (which vary across jax versions).
_OOM_MARKERS = ("resource_exhausted", "out of memory", "oom")
_COMPILE_MARKERS = ("mosaic", "lowering", "unsupported", "unimplemented",
                    "compilation failure", "failed to compile",
                    "triton")
_TRANSIENT_MARKERS = ("unavailable", "deadline_exceeded",
                      "connection reset", "transfer failed")
_DEVICE_LOST_MARKERS = ("device lost", "device is lost",
                        "failed to query device")
_EXCHANGE_MARKERS = ("collective_permute", "ppermute",
                     "collective timed out")


@dataclasses.dataclass(frozen=True)
class LadderPolicy:
    """Knobs of the retry/fallback chain (frozen — safely shareable).

    Attributes:
      max_retries: attempts beyond the first for *transient* failures
        (upload retry-with-backoff).
      backoff_base_s / backoff_cap_s: bounded exponential backoff —
        attempt ``a`` sleeps ``min(base * 2**a, cap)`` scaled by jitter.
      jitter: fraction of the delay randomized (0 = none, 0.5 = delay in
        ``[0.5x, 1.0x]``); drawn from a *seeded* hash of (seed, token,
        attempt), so replays are deterministic.
      seed: jitter seed.
      max_budget_halvings: how many times the streamed chunk budget may
        halve on OOM before the failure is surfaced.
      max_backend_steps: how many backend rungs may be descended before
        the failure is surfaced (the full ladder by default).
    """

    max_retries: int = 3
    backoff_base_s: float = 0.005
    backoff_cap_s: float = 0.25
    jitter: float = 0.5
    seed: int = 0
    max_budget_halvings: int = 4
    max_backend_steps: int = 3

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if not 0.0 <= self.jitter <= 1.0:
            raise ValueError("jitter must be in [0, 1]")
        if self.backoff_base_s < 0 or self.backoff_cap_s < 0:
            raise ValueError("backoff times must be >= 0")


DEFAULT_POLICY = LadderPolicy()

_AMBIENT: LadderPolicy | None = None


def install_ambient(policy: LadderPolicy) -> LadderPolicy:
    """Install ``policy`` as the process-wide default picked up by every
    ``ladder=None`` call site (the ``chaos.install`` pattern)."""
    global _AMBIENT
    if not isinstance(policy, LadderPolicy):
        raise TypeError("install_ambient wants a LadderPolicy")
    _AMBIENT = policy
    return _AMBIENT


def uninstall_ambient() -> LadderPolicy | None:
    """Remove the ambient policy (``ladder=None`` means off again)."""
    global _AMBIENT
    prev, _AMBIENT = _AMBIENT, None
    return prev


def ambient() -> LadderPolicy | None:
    """The ambient (env/process-default) policy, or ``None``."""
    return _AMBIENT


def resolve_policy(ladder) -> LadderPolicy | None:
    """Normalize a user-facing ``ladder=`` argument: ``None`` -> the
    ambient policy (env default; off when none installed), ``False`` ->
    off, ``True`` -> :data:`DEFAULT_POLICY`, a policy -> itself."""
    if ladder is None:
        return _AMBIENT
    if ladder is False:
        return None
    if ladder is True:
        return DEFAULT_POLICY
    if isinstance(ladder, LadderPolicy):
        return ladder
    raise TypeError(f"ladder must be bool/None/LadderPolicy, "
                    f"got {type(ladder).__name__}")


def from_env(value: str) -> LadderPolicy:
    """Parse a ``REPRO_LADDER`` policy string (mirrors ``chaos.from_env``).

    ``"1"``/``"true"``/``"default"`` mean :data:`DEFAULT_POLICY`;
    otherwise comma-separated ``key=value`` items naming
    :class:`LadderPolicy` fields::

        REPRO_LADDER="max_retries=5,backoff_cap_s=1.0,seed=7"
    """
    value = value.strip()
    if value.lower() in ("1", "true", "on", "default"):
        return DEFAULT_POLICY
    fields = {f.name: f.type for f in dataclasses.fields(LadderPolicy)}
    kwargs: dict = {}
    for item in value.split(","):
        item = item.strip()
        if not item:
            continue
        key, _, raw = item.partition("=")
        key, raw = key.strip(), raw.strip()
        if key not in fields:
            raise ValueError(f"unknown {ENV_VAR} key {key!r}")
        kwargs[key] = (float(raw) if "float" in str(fields[key])
                       else int(raw))
    return LadderPolicy(**kwargs)


def _init_from_env() -> None:
    value = os.environ.get(ENV_VAR, "").strip()
    if not value or value.lower() in ("0", "false", "off"):
        return
    install_ambient(from_env(value))


def classify(exc: BaseException) -> str:
    """Failure taxonomy: ``"oom" | "compile" | "transient" |
    "device_lost" | "exchange" | "fatal"``.

    Chaos-injected faults classify by type; real JAX/XLA failures by
    well-known message markers (jax wraps most of them in
    ``XlaRuntimeError`` whose *status* only lives in the message).
    Anything unrecognized is ``"fatal"`` — the ladder never swallows a
    failure it cannot name.
    """
    if isinstance(exc, ChaosOOM):
        return "oom"
    if isinstance(exc, ChaosCompileError):
        return "compile"
    if isinstance(exc, ChaosDeviceLost):
        return "device_lost"
    if isinstance(exc, ChaosExchangeError):
        return "exchange"
    if isinstance(exc, ChaosUploadError):
        return "transient"
    if isinstance(exc, MemoryError):
        return "oom"
    msg = f"{type(exc).__name__}: {exc}".lower()
    if any(m in msg for m in _OOM_MARKERS):
        return "oom"
    if any(m in msg for m in _COMPILE_MARKERS):
        return "compile"
    if any(m in msg for m in _DEVICE_LOST_MARKERS):
        return "device_lost"
    if any(m in msg for m in _EXCHANGE_MARKERS):
        return "exchange"
    if any(m in msg for m in _TRANSIENT_MARKERS):
        return "transient"
    return "fatal"


def next_backend(backend: str) -> str | None:
    """The next (more portable) rung under ``backend``, or ``None`` at
    the bottom / for backends outside the ladder."""
    from repro.engine.config import BACKEND_LADDER

    try:
        i = BACKEND_LADDER.index(backend)
    except ValueError:
        return None
    if i + 1 >= len(BACKEND_LADDER):
        return None
    return BACKEND_LADDER[i + 1]


def backoff_delay(policy: LadderPolicy, attempt: int, token="") -> float:
    """Bounded exponential backoff with deterministic seeded jitter.

    ``token`` names the retried operation (e.g. ``(mode, chunk)``) so two
    concurrent retriers don't share a jitter stream; the same
    (seed, token, attempt) always yields the same delay.
    """
    base = min(policy.backoff_base_s * (2.0 ** attempt),
               policy.backoff_cap_s)
    if policy.jitter <= 0.0:
        return base
    h = hashlib.sha256(
        repr((policy.seed, token, attempt)).encode()).digest()
    u = int.from_bytes(h[:8], "big") / float(1 << 64)   # [0, 1)
    return base * (1.0 - policy.jitter * u)


def record_degradation(kind: str, frm, to, **attrs) -> None:
    """Make one ladder transition observable: a
    ``resilience_degradations`` counter label ``kind:frm->to`` plus a
    ``resilience.degrade`` span. Never silent."""
    _counter("resilience_degradations",
             "degradation-ladder transitions (kind:from->to)").inc(
                 f"{kind}:{frm}->{to}")
    with _span("resilience.degrade", kind=kind, frm=str(frm), to=str(to),
               **attrs):
        pass


def record_retry(what: str, attempt: int, delay_s: float, **attrs) -> None:
    """Record one transient-failure retry (counter + span), then sleep
    the backoff delay."""
    _counter("resilience_retries",
             "transient-failure retries by site").inc(what)
    with _span("resilience.retry", what=what, attempt=attempt,
               delay_s=delay_s, **attrs):
        if delay_s > 0:
            time.sleep(delay_s)


_init_from_env()
