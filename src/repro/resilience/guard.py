"""Numerical guardrails for ALS sweeps: NaN/Inf detection + recovery
bookkeeping.

A single NaN produced mid-sweep (overflow in a gram product, a poisoned
input value, a flaky accumulator) silently corrupts every later factor
update — the run completes and the factors are garbage. ``cp_als`` /
``cp_als_stream`` therefore check factor finiteness after every sweep
(one host sync, same cost class as the per-sweep fit sync) and, on a
burst, roll back to the previous sweep's factors and replay the sweep
under a stronger ridge regularizer (``core.cpd._als_fold_recovery``).
This module owns the check and the observability around the recovery.
"""
from __future__ import annotations

from repro.obs.metrics import counter as _counter
from repro.obs.trace import span as _span

__all__ = ["all_finite", "record_recovery"]


def all_finite(factors, lam=None) -> bool:
    """Host-synced finiteness check over a factor tuple (+ lambda)."""
    import jax.numpy as jnp

    for f in factors:
        if not bool(jnp.all(jnp.isfinite(f))):
            return False
    if lam is not None and not bool(jnp.all(jnp.isfinite(lam))):
        return False
    return True


def record_recovery(what: str, **attrs) -> None:
    """Record one numerical recovery (e.g. ``nan_rollback``) as a
    ``resilience_recoveries`` counter label + ``resilience.recover``
    span."""
    _counter("resilience_recoveries",
             "numerical recoveries by kind").inc(what)
    with _span("resilience.recover", what=what, **attrs):
        pass
