"""Mesh/sharding context + parameter partitioning rules.

Axis convention (DESIGN.md §6):
  dp axes  — ("pod", "data") when present: batch / fsdp shards
  tp axis  — "model": heads, d_ff, experts, vocab shards

Models call ``shard(x, *dims)`` with logical dim tags; outside a mesh context
this is a no-op, so the same code runs in single-device tests and 512-chip
lowering. Tags: "dp" (batch), "tp" (model-parallel dim), None.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingCtx:
    mesh: Mesh
    dp_axes: tuple[str, ...]      # e.g. ("data",) or ("pod", "data")
    tp_axis: Optional[str]        # "model"
    fsdp: bool = True             # shard params/opt-state over dp too

    @property
    def data_axis(self) -> str:
        """Innermost dp axis name — the axis engine.dist shards slots and
        partitions over (``"data"`` when the mesh has no dp axis)."""
        return self.dp_axes[-1] if self.dp_axes else "data"

    def resolve(self, *tags) -> P:
        spec = []
        for t in tags:
            if t == "dp":
                spec.append(self.dp_axes if len(self.dp_axes) > 1
                            else self.dp_axes[0] if self.dp_axes else None)
            elif t == "tp":
                spec.append(self.tp_axis)
            else:
                spec.append(None)
        return P(*spec)

    def named(self, *tags) -> NamedSharding:
        return NamedSharding(self.mesh, self.resolve(*tags))


_CTX: contextvars.ContextVar[Optional[ShardingCtx]] = contextvars.ContextVar(
    "sharding_ctx", default=None)


def current() -> Optional[ShardingCtx]:
    return _CTX.get()


@contextlib.contextmanager
def use(ctx: Optional[ShardingCtx]):
    tok = _CTX.set(ctx)
    try:
        yield ctx
    finally:
        _CTX.reset(tok)


def make_ctx(mesh: Mesh, fsdp: bool = True) -> ShardingCtx:
    names = mesh.axis_names
    dp = tuple(n for n in names if n in ("pod", "data"))
    tp = "model" if "model" in names else None
    return ShardingCtx(mesh=mesh, dp_axes=dp, tp_axis=tp, fsdp=fsdp)


def shard(x, *tags):
    """Attach a sharding constraint if a mesh context is active.

    Tags on dims not divisible by their mesh extent are dropped (replicated)
    so the same model code serves any (arch x mesh) combination.
    """
    ctx = current()
    if ctx is None:
        return x
    if len(tags) != x.ndim:
        raise ValueError(f"{len(tags)} tags for rank-{x.ndim} array")
    fixed = []
    for d, t in enumerate(tags):
        if t is None:
            fixed.append(None)
            continue
        spec = ctx.resolve(t)[0]
        axes = spec if isinstance(spec, tuple) else (spec,)
        size = 1
        for a in axes:
            if a is not None:
                size *= ctx.mesh.shape[a]
        fixed.append(t if size and x.shape[d] % size == 0 else None)
    return jax.lax.with_sharding_constraint(x, ctx.named(*fixed))


# --------------------------------------------------------------------------
# Parameter partitioning rules (path-pattern -> dim tags).
# Params are stacked (L, ...) per stage; dim 0 of layer params is the scan
# axis (never sharded). "fsdp" tags shard over dp when ctx.fsdp is set.
# --------------------------------------------------------------------------
def param_tags(path: tuple[str, ...], shape: tuple[int, ...], ctx:
               ShardingCtx) -> tuple:
    """Heuristic rules keyed on leaf names; returns one tag per dim."""
    name = path[-1]
    stacked = path[0].startswith("stage") or path[0] in ("enc", "dec")
    lead = ("layer",) if stacked else ()
    body = shape[len(lead):]
    fsdp = "dp" if ctx.fsdp else None

    def tags(*t):
        return tuple([None] * len(lead)) + t

    # embed/head: single-dim sharding only — a 2D-sharded gather operand
    # triggers SPMD "involuntary full rematerialization" (table replication)
    if name in ("embed",):                      # (V, D)
        return ("tp", None)
    if name in ("head",):                       # (D, V)
        return (None, "tp")
    if name in ("wq", "wk", "wv"):              # (D, H, hd) or (D, KVH, hd)
        return tags(fsdp, "tp", None) if body[1] % _tp(ctx) == 0 \
            else tags(fsdp, None, None)
    if name == "wo":                            # (H, hd, D)
        return tags("tp", None, fsdp) if body[0] % _tp(ctx) == 0 \
            else tags(None, None, fsdp)
    if name in ("w_gate", "w_up"):              # (D, F) or (E, D, F)
        if len(body) == 3:
            return tags("tp", fsdp, None)       # experts over tp
        return tags(fsdp, "tp")
    if name == "w_down":                        # (F, D) or (E, F, D)
        if len(body) == 3:
            return tags("tp", None, fsdp)
        return tags("tp", fsdp)
    if name == "router":                        # (D, E)
        return tags(fsdp, None)
    if name in ("w_in_rec", "w_in_gate"):       # (D, W) rg-lru projections
        return tags(fsdp, "tp")
    if name == "w_out_rec":                     # (W, D)
        return tags("tp", fsdp)
    if name in ("wr", "wk_t", "wv_t", "wg", "w_out_t"):  # rwkv (D, D)
        return tags(fsdp, "tp") if name != "w_out_t" else tags("tp", fsdp)
    if name in ("wk_c", ):                      # rwkv channel (D, F)
        return tags(fsdp, "tp")
    if name in ("wv_c", ):                      # (F, D)
        return tags("tp", fsdp)
    # biases, norms, gates, small tables: replicate
    return tags(*([None] * len(body)))


def _tp(ctx: ShardingCtx) -> int:
    if ctx.tp_axis is None:
        return 1
    return ctx.mesh.shape[ctx.tp_axis]


def param_sharding_tree(params, ctx: ShardingCtx):
    """Map a params pytree to NamedShardings via param_tags."""
    def visit(path, leaf):
        keys = tuple(getattr(p, "key", getattr(p, "idx", None))
                     for p in path)
        keys = tuple(str(k) for k in keys)
        tags = param_tags(keys, leaf.shape, ctx)
        # guard: only shard dims divisible by the mesh extent
        fixed = []
        for d, t in enumerate(tags):
            if t is None:
                fixed.append(None)
                continue
            spec = ctx.resolve(t)[0]
            axes = spec if isinstance(spec, tuple) else (spec,)
            size = 1
            for a in axes:
                if a is not None:
                    size *= ctx.mesh.shape[a]
            fixed.append(t if leaf.shape[d] % size == 0 else None)
        return ctx.named(*fixed)

    return jax.tree_util.tree_map_with_path(visit, params)
