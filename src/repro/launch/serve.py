"""Batched serving driver (smoke scale on CPU; same path the decode dry-run
cells lower at production scale).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
      --batch 4 --prompt-len 16 --max-new 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config, smoke
from ..models import init_model
from ..serving.engine import Engine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = smoke(args.arch) if args.smoke else get_config(args.arch)
    key = jax.random.PRNGKey(0)
    params = init_model(cfg, key)
    enc = None
    if cfg.kind == "audio":
        enc = jax.random.normal(key, (args.batch, 64, cfg.d_model),
                                cfg.cdtype)
    eng = Engine(params, cfg,
                 ServeConfig(batch=args.batch, max_len=args.max_len,
                             temperature=args.temperature),
                 enc_embeds=enc)
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab)
    t0 = time.monotonic()
    out = eng.generate(prompt, args.max_new, key=key)
    dt = time.monotonic() - t0
    tps = args.batch * args.max_new / dt
    print(f"generated {out.shape} in {dt:.2f}s ({tps:.1f} tok/s)")
    print(out[0])


if __name__ == "__main__":
    main()
