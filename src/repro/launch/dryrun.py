import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: ``jax.jit(step, in_shardings=...).lower(**input_specs).compile()``
must succeed on the 16x16 single-pod mesh AND the 2x16x16 multi-pod mesh
for every assigned cell. Results (memory_analysis, cost_analysis,
per-collective bytes) are written to JSON for EXPERIMENTS.md and the
roofline module.

Usage:
  python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
  python -m repro.launch.dryrun --sweep [--multi-pod] [--variants]
"""
import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from .. import sharding as shlib              # noqa: E402
from ..analysis.hlo import collective_bytes   # noqa: E402
from ..configs import (SHAPES, applicable, cache_specs, get_config,  # noqa: E402
                       input_specs)
from ..configs.archs import ARCHS             # noqa: E402
from ..models import decode_step, forward     # noqa: E402
from ..training import OptimizerConfig, init_state, make_train_step  # noqa: E402
from . import specs as speclib                # noqa: E402
from .mesh import make_production_mesh        # noqa: E402

# HBM-driven overrides for the >=100B archs: bf16 optimizer moments
# (memory_analysis reports the result either way).
_OPT_OVERRIDES = {
    "command-r-plus-104b": {"state_dtype": "bfloat16"},
    "qwen3-moe-235b-a22b": {"state_dtype": "bfloat16"},
}

# Microbatching (gradient accumulation) for cells whose activations exceed
# HBM at one shot — the standard production knob; HLO cost scales exactly.
_ACCUM_OVERRIDES = {
    ("command-r-plus-104b", "train_4k"): 8,
    ("qwen3-moe-235b-a22b", "train_4k"): 8,
    ("whisper-large-v3", "train_4k"): 2,
    ("recurrentgemma-9b", "train_4k"): 4,
}

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _opt_cfg(arch: str) -> OptimizerConfig:
    return OptimizerConfig(**_OPT_OVERRIDES.get(arch, {}))


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               cfg=None, mesh=None, want_hlo: bool = False,
               cast_once: bool = False) -> dict:
    """Lower + compile one cell; return its dry-run record."""
    cfg = cfg or get_config(arch)
    shape = SHAPES[shape_name]
    mesh = mesh if mesh is not None else make_production_mesh(
        multi_pod=multi_pod)
    ctx = shlib.make_ctx(mesh)
    ocfg = _opt_cfg(arch)
    t0 = time.monotonic()

    with shlib.use(ctx):
        if shape.step == "train":
            state_shapes = jax.eval_shape(
                lambda k: init_state(cfg, ocfg, k), jax.random.PRNGKey(0))
            batch_shapes = input_specs(cfg, shape)
            st_sh = speclib.state_shardings(state_shapes, ctx)
            bt_sh = speclib.batch_shardings(cfg, batch_shapes, ctx)
            accum = _ACCUM_OVERRIDES.get((arch, shape_name), 1)
            step_fn = make_train_step(cfg, ocfg, grad_accum=accum,
                                      param_shardings=st_sh["params"],
                                      cast_params_once=cast_once)
            lowered = jax.jit(
                step_fn, in_shardings=(st_sh, bt_sh), donate_argnums=(0,)
            ).lower(state_shapes, batch_shapes)
        elif shape.step == "prefill":
            params_shapes = jax.eval_shape(
                lambda k: _init_params(cfg, k), jax.random.PRNGKey(0))
            batch_shapes = input_specs(cfg, shape)
            p_sh = shlib.param_sharding_tree(params_shapes, ctx)
            bt_sh = speclib.batch_shardings(cfg, batch_shapes, ctx)

            def prefill_fn(params, batch):
                kw = {}
                if cfg.kind == "vlm":
                    kw["embeds"] = batch["embeds"]
                if cfg.kind == "audio":
                    kw["enc_embeds"] = batch["enc_embeds"]
                return forward(params, cfg, tokens=batch["tokens"], **kw)

            lowered = jax.jit(prefill_fn, in_shardings=(p_sh, bt_sh)).lower(
                params_shapes, batch_shapes)
        else:  # decode
            params_shapes = jax.eval_shape(
                lambda k: _init_params(cfg, k), jax.random.PRNGKey(0))
            cache_shapes = cache_specs(cfg, shape)
            token_shapes = input_specs(cfg, shape)
            p_sh = shlib.param_sharding_tree(params_shapes, ctx)
            c_sh = speclib.cache_shardings(cache_shapes, ctx)
            t_sh = speclib.batch_shardings(cfg, token_shapes, ctx)

            def serve_fn(params, cache, batch):
                return decode_step(params, cache, cfg, batch["token"])

            lowered = jax.jit(
                serve_fn, in_shardings=(p_sh, c_sh, t_sh),
                donate_argnums=(1,)
            ).lower(params_shapes, cache_shapes, token_shapes)

        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # jax < 0.6 wraps the dict
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "pod2x16x16" if multi_pod else "16x16",
        "n_devices": mesh.size,
        "step": shape.step,
        "compile_s": round(time.monotonic() - t0, 2),
        "memory": {
            "argument_gb": mem.argument_size_in_bytes / 1e9,
            "output_gb": mem.output_size_in_bytes / 1e9,
            "temp_gb": mem.temp_size_in_bytes / 1e9,
            "alias_gb": mem.alias_size_in_bytes / 1e9,
            "peak_per_device_gb": (
                mem.argument_size_in_bytes + mem.temp_size_in_bytes
                + mem.output_size_in_bytes - mem.alias_size_in_bytes) / 1e9,
        },
        "cost": {
            "flops_per_device": cost.get("flops", 0.0),
            "bytes_per_device": cost.get("bytes accessed", 0.0),
            "transcendentals": cost.get("transcendentals", 0.0),
        },
        "collectives_per_device": coll,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "grad_accum": _ACCUM_OVERRIDES.get((arch, shape_name), 1),
        "cast_once": cast_once,
    }
    if want_hlo:
        rec["hlo"] = hlo
    return rec


def _init_params(cfg, key):
    from ..models import init_model
    return init_model(cfg, key)


# -------------------------------------------------------------- variants
def variant_configs(cfg):
    """Configs isolating each scan body for trip-count cost correction:
    'nonloop' (0 layers) + one single-cycle variant per stage (+ encoder).
    Returns [(tag, cfg, repetitions_in_full_model)]."""
    out = [("nonloop", dataclasses.replace(
        cfg, n_layers=0, n_enc_layers=0), 0)]
    for i, (pat, rep) in enumerate(cfg.stages()):
        out.append((f"stage{i}", dataclasses.replace(
            cfg, n_layers=len(pat), block_pattern=pat, n_enc_layers=0), rep))
    if cfg.n_enc_layers:
        out.append(("enc", dataclasses.replace(
            cfg, n_layers=0, n_enc_layers=1), cfg.n_enc_layers))
    return out


def lower_cell_with_variants(arch, shape_name, *, multi_pod=False,
                             cfg=None, cast_once=False):
    """Full compile (memory truth, scanned chunk loops) + cost-mode variant
    compiles (unrolled chunk loops, exact HLO cost). The roofline derives
    costs from the variants alone: nonloop + sum_s rep_s * body_s."""
    from ..models import layers as _layers

    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = cfg or get_config(arch)
    rec = lower_cell(arch, shape_name, multi_pod=multi_pod, cfg=cfg,
                     mesh=mesh, cast_once=cast_once)
    rec["variants"] = {}
    _layers.set_cost_mode(True)
    try:
        for tag, vcfg, rep in variant_configs(cfg):
            vrec = lower_cell(arch, shape_name, multi_pod=multi_pod,
                              cfg=vcfg, mesh=mesh, cast_once=cast_once)
            rec["variants"][tag] = {
                "rep": rep,
                "params": vcfg.param_count(),
                "flops_per_device": vrec["cost"]["flops_per_device"],
                "bytes_per_device": vrec["cost"]["bytes_per_device"],
                "collectives_per_device": vrec["collectives_per_device"],
            }
    finally:
        _layers.set_cost_mode(False)
    return rec


# ------------------------------------------------------------------ main
def run_sweep(multi_pod: bool, variants: bool, archs=None, shapes=None,
              out_dir=OUT_DIR):
    os.makedirs(out_dir, exist_ok=True)
    results = []
    for arch in (archs or list(ARCHS)):
        for shape_name in (shapes or list(SHAPES)):
            if not applicable(arch, shape_name):
                print(f"SKIP  {arch} x {shape_name} (documented: "
                      f"full-attention arch, 500k decode)")
                continue
            tag = f"{arch}__{shape_name}__" + (
                "pod2x16x16" if multi_pod else "16x16")
            path = os.path.join(out_dir, tag + ".json")
            if os.path.exists(path):
                print(f"CACHED {tag}")
                results.append(json.load(open(path)))
                continue
            try:
                fn = (lower_cell_with_variants if variants else lower_cell)
                rec = fn(arch, shape_name, multi_pod=multi_pod)
                rec["ok"] = True
                print(f"OK    {tag}: peak/dev "
                      f"{rec['memory']['peak_per_device_gb']:.2f} GB, "
                      f"{rec['compile_s']}s compile")
            except Exception as e:  # a failure here is a bug in the system
                rec = {"arch": arch, "shape": shape_name, "ok": False,
                       "error": f"{type(e).__name__}: {e}",
                       "trace": traceback.format_exc()}
                print(f"FAIL  {tag}: {rec['error']}")
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            results.append(rec)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--variants", action="store_true",
                    help="also lower 0-layer/1-cycle variants for roofline")
    ap.add_argument("--sweep", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()
    if args.sweep:
        res = run_sweep(args.multi_pod, args.variants,
                        archs=[args.arch] if args.arch else None,
                        shapes=[args.shape] if args.shape else None,
                        out_dir=args.out)
        bad = [r for r in res if not r.get("ok")]
        print(f"\n{len(res) - len(bad)}/{len(res)} cells OK")
        raise SystemExit(1 if bad else 0)
    assert args.arch and args.shape, "--arch and --shape (or --sweep)"
    fn = lower_cell_with_variants if args.variants else lower_cell
    rec = fn(args.arch, args.shape, multi_pod=args.multi_pod)
    print(json.dumps({k: v for k, v in rec.items() if k != "hlo"}, indent=2))


if __name__ == "__main__":
    main()
