"""Sharding specs for train/prefill/decode step inputs and state."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import sharding as shlib
from ..models.common import ModelConfig


def _dp(ctx):
    return ctx.dp_axes if len(ctx.dp_axes) > 1 else (
        ctx.dp_axes[0] if ctx.dp_axes else None)


def _dp_size(ctx) -> int:
    n = 1
    for a in ctx.dp_axes:
        n *= ctx.mesh.shape[a]
    return n


def state_shardings(state_shapes, ctx: shlib.ShardingCtx):
    """Shardings for {"params", "opt", "step"} trees."""
    params_sh = shlib.param_sharding_tree(state_shapes["params"], ctx)
    repl = NamedSharding(ctx.mesh, P())

    def opt_leaf(path, leaf):
        # mirror param sharding when shapes line up (m/v); replicate extras
        return None

    opt = state_shapes["opt"]
    out_opt = {}
    for k, v in opt.items():
        if k in ("m", "v"):
            out_opt[k] = params_sh
        elif k == "f":  # adafactor factored stats: replicate (small)
            out_opt[k] = jax.tree.map(lambda _: repl, v)
        else:
            out_opt[k] = jax.tree.map(lambda _: repl, v)
    return {"params": params_sh, "opt": out_opt, "step": repl}


def batch_shardings(cfg: ModelConfig, batch_shapes, ctx: shlib.ShardingCtx):
    dp = _dp(ctx)
    mesh = ctx.mesh

    def rule(path, leaf):
        b = leaf.shape[0]
        bdp = dp if b % _dp_size(ctx) == 0 else None
        return NamedSharding(mesh, P(bdp, *([None] * (leaf.ndim - 1))))

    return jax.tree_util.tree_map_with_path(rule, batch_shapes)


def cache_shardings(cache_shapes, ctx: shlib.ShardingCtx):
    """Decode-cache shardings: batch -> dp, KV sequence -> model axis.

    Cache leaves are stacked (rep, ...). Rules by leaf name; any dim not
    divisible by its mesh extent falls back to replication.
    """
    mesh = ctx.mesh
    dp = _dp(ctx)
    tp = ctx.tp_axis
    dp_n = _dp_size(ctx)
    tp_n = mesh.shape[tp] if tp else 1

    def rule(path, leaf):
        name = str(getattr(path[-1], "key", ""))
        shape = leaf.shape
        spec = [None] * leaf.ndim
        if name in ("k", "v") and leaf.ndim == 5:  # (rep, B, S, KV, hd)
            if shape[1] % dp_n == 0:
                spec[1] = dp
            if tp and shape[2] % tp_n == 0:
                spec[2] = tp  # sequence-sharded KV cache (flash-decode style)
        elif name in ("state", "conv", "last", "last_c", "h") \
                and leaf.ndim >= 2:
            if shape[1] % dp_n == 0:
                spec[1] = dp
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(rule, cache_shapes)
