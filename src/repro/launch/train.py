"""End-to-end training driver.

CPU-scale by default (smoke config + tiny mesh); the same code path lowers
the production meshes (see dryrun.py). Example:

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --smoke --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ck
"""
from __future__ import annotations

import argparse
import logging

import jax

from .. import sharding as shlib
from ..configs import get_config, smoke
from ..training import (ControllerConfig, OptimizerConfig, SyntheticLM,
                        TrainController)
from .mesh import make_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default=None,
                    help="e.g. '2,2' => (data=2, model=2) over local devices")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)

    cfg = smoke(args.arch) if args.smoke else get_config(args.arch)
    ocfg = OptimizerConfig(lr=args.lr, total_steps=args.steps,
                           warmup_steps=max(args.steps // 20, 1))
    ctrl = ControllerConfig(ckpt_dir=args.ckpt_dir,
                            ckpt_every=args.ckpt_every)
    data = SyntheticLM(cfg, batch=args.batch, seq=args.seq)

    ctx = None
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split(","))
        axes = ("data", "model")[:len(shape)]
        ctx = shlib.make_ctx(make_mesh(shape, axes))

    with shlib.use(ctx):
        tc = TrainController(cfg, ocfg, ctrl, data)
        state, metrics = tc.run(args.steps)
    print(f"done: step={int(state['step'])} "
          f"loss={float(metrics['loss']):.4f} "
          f"stragglers={tc.straggler_steps}")


if __name__ == "__main__":
    main()
