"""Production meshes (assignment spec).

Defined as functions — importing this module never touches jax device state.
Single pod: (data=16, model=16) = 256 chips; multi-pod adds a leading
pod axis: (pod=2, data=16, model=16) = 512 chips.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """Arbitrary mesh (tests/examples)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(AxisType.Auto,) * len(axes))
