"""Production meshes (assignment spec).

Defined as functions — importing this module never touches jax device state.
Single pod: (data=16, model=16) = 256 chips; multi-pod adds a leading
pod axis: (pod=2, data=16, model=16) = 512 chips.

``AxisType`` landed after jax 0.4; on older installs ``jax.make_mesh`` has
no ``axis_types`` kwarg and every axis is implicitly Auto, so the gate
below changes nothing semantically.
"""
from __future__ import annotations

import jax

try:
    from jax.sharding import AxisType

    def _mesh(shape, axes):
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
except ImportError:  # jax <= 0.4: no AxisType, no axis_types kwarg
    def _mesh(shape, axes):
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests/examples)."""
    return _mesh(tuple(shape), tuple(axes))
