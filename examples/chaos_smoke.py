"""Seeded fault-injection smoke on a tiny zipf tensor (CI chaos-smoke).

Every resilience path runs against a deterministic chaos plan and is
gated on the same two invariants the design promises:

* **Bitwise parity** wherever the ladder claims it — transient upload
  failures retried, a streamed-chunk OOM answered by budget halving, a
  compile failure answered by the backend ladder, and a SIGKILL mid-run
  answered by checkpoint/resume (subprocess, ``REPRO_CHAOS``) must all
  end in factors bitwise-identical to an undisturbed run.
* **No silent degradation** — ``obs.resilience_report()`` must pair every
  injected fault with the resilience event that answered it
  (``unanswered == []``).

Writes ``out/chaos_trace.json`` (Chrome trace of the whole run, chaos
injection spans included) and ``out/chaos_report.json`` (the pairing
report) for the CI artifact.

    PYTHONPATH=src python examples/chaos_smoke.py

``--mesh`` runs the distributed tier instead (requires >= 4 devices —
CI forces fake CPU devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=4``): the exchange
rung (``collective_permute -> all_gather``), device loss -> mesh shrink,
transient dist dispatch retry, and the elastic kill/resume scenario — a
4-device run SIGKILLed mid-sweep and resumed on 2 devices and on 1,
gated bitwise against an uninterrupted 4-device run. Writes
``out/dist_chaos_trace.json`` + ``out/dist_chaos_report.json``.

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
        PYTHONPATH=src python examples/chaos_smoke.py --mesh
"""
import argparse
import json
import os
import signal
import subprocess
import sys

import numpy as np

from repro import obs
from repro.core.cpd import cp_als
from repro.core.datasets import zipf_tensor
from repro.core.plancache import PlanCache
from repro.engine import ExecutionConfig, PlanSpec, make_engine
from repro.engine.stream import StreamState, cp_als_stream
from repro.resilience import (ChaosSpec, LadderPolicy, chaos, install,
                              uninstall)

DIMS, NNZ, SEED = (60, 50, 40), 3000, 7
RANK, ITERS = 4, 6
POLICY = LadderPolicy(backoff_base_s=1e-4, backoff_cap_s=1e-3)


def _tensor():
    return zipf_tensor(DIMS, NNZ, a=2.0, seed=SEED, rows_pp=8)


def _stream_config():
    return ExecutionConfig(rows_pp=8, chunk_nnz=1024, rank_hint=RANK)


def _bitwise(label, a, b):
    for i, (x, y) in enumerate(zip(a.factors, b.factors)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"{label}: factor {i}")
    np.testing.assert_array_equal(np.asarray(a.lam), np.asarray(b.lam),
                                  err_msg=f"{label}: lam")
    print(f"  [ok] {label}: bitwise parity")


# --------------------------------------------------------------------------
# Child entry: one ALS run in its own process (the kill/resume scenario).
# --------------------------------------------------------------------------
def child_run(ckpt_dir: str, out_npz: str, resume: bool) -> None:
    t = _tensor()
    r = cp_als(t, rank=RANK, iters=ITERS, checkpoint=ckpt_dir,
               resume=resume)
    np.savez(out_npz, *[np.asarray(f) for f in r.factors],
             lam=np.asarray(r.lam))


def _spawn(ckpt_dir, out_npz, *, resume=False, chaos_env=None):
    env = dict(os.environ)
    env.pop(chaos.ENV_VAR, None)
    if chaos_env:
        env[chaos.ENV_VAR] = chaos_env
    cmd = [sys.executable, os.path.abspath(__file__), "--child",
           ckpt_dir, out_npz] + (["--resume"] if resume else [])
    return subprocess.run(cmd, env=env, capture_output=True, text=True)


def scenario_kill_resume(out_dir: str) -> None:
    print("scenario: SIGKILL at sweep 3 -> resume from snapshot")
    ckpt = os.path.join(out_dir, "chaos_ckpt")
    clean = os.path.join(out_dir, "clean.npz")
    resumed = os.path.join(out_dir, "resumed.npz")
    r = _spawn(os.path.join(out_dir, "ckpt_unused"), clean)
    assert r.returncode == 0, r.stderr
    r = _spawn(ckpt, os.path.join(out_dir, "dead.npz"),
               chaos_env=f"kill_sweep=3,seed={SEED}")
    assert r.returncode == -signal.SIGKILL, (
        f"chaos child should die by SIGKILL, got {r.returncode}\n"
        f"{r.stderr}")
    assert os.listdir(ckpt), "no snapshot survived the kill"
    r = _spawn(ckpt, resumed, resume=True)
    assert r.returncode == 0, r.stderr
    with np.load(clean) as a, np.load(resumed) as b:
        for name in a.files:
            np.testing.assert_array_equal(
                a[name], b[name],
                err_msg=f"kill/resume: {name} diverged")
    print("  [ok] killed + resumed == uninterrupted (bitwise)")


# --------------------------------------------------------------------------
# In-process scenarios.
# --------------------------------------------------------------------------
def scenario_stream_faults(out_dir: str, clean) -> None:
    print("scenario: transient upload failure + chunk OOM (streamed)")
    t = _tensor()
    install(ChaosSpec(upload_fail=1, upload_fail_times=2, oom_chunk=3,
                      seed=SEED))
    res = cp_als_stream(t, rank=RANK, iters=ITERS,
                        config=_stream_config(), ladder=POLICY,
                        checkpoint=os.path.join(out_dir, "stream_ckpt"))
    uninstall()
    _bitwise("retry + budget-halving", clean, res)


def scenario_backend_ladder(clean_resident) -> None:
    print("scenario: compile failure -> backend ladder")
    t = _tensor()
    install(ChaosSpec(compile_fail=("pallas_fused", "pallas"), seed=SEED))
    res = cp_als(t, rank=RANK, iters=ITERS,
                 config=ExecutionConfig(backend="pallas_fused"),
                 ladder=True)
    uninstall()
    _bitwise("pallas_fused -> pallas -> xla", clean_resident, res)


def scenario_nan_recovery() -> None:
    print("scenario: NaN burst -> rollback + ridge recovery")
    t = _tensor()
    install(ChaosSpec(nan_sweep=1, seed=SEED))
    res = cp_als(t, rank=RANK, iters=ITERS, ladder=True)
    uninstall()
    assert all(np.isfinite(np.asarray(f)).all() for f in res.factors)
    assert np.isfinite(res.fits).all(), "fit never recovered from the burst"
    print(f"  [ok] recovered; final fit {res.fits[-1]:.4f}")


def scenario_corrupt_blob(out_dir: str) -> None:
    print("scenario: torn plan-cache blob -> quarantine + self-heal")
    cache_dir = os.path.join(out_dir, "chaos_plancache")
    t = _tensor()
    idx, val = np.asarray(t.indices), np.asarray(t.values)
    install(ChaosSpec(corrupt_blob=True, seed=SEED))
    PlanCache(path=cache_dir).get_tensor(idx, val, t.dims, rows_pp=8)
    uninstall()
    healer = PlanCache(path=cache_dir)
    healer.get_tensor(idx, val, t.dims, rows_pp=8)
    assert healer.stats()["disk_corrupt"] == 1, "torn blob not detected"
    reader = PlanCache(path=cache_dir)
    reader.get_tensor(idx, val, t.dims, rows_pp=8)
    assert reader.stats()["disk_loads"] == 1, "cache did not self-heal"
    print("  [ok] quarantined + rebuilt + re-persisted")


def scenario_resident_oom() -> None:
    print("scenario: resident placement OOM -> streaming fallback")
    t = _tensor()
    install(ChaosSpec(oom_resident=True, seed=SEED))
    state = make_engine(t, PlanSpec(chunk_nnz=1024, rank_hint=RANK),
                       ladder=True)
    uninstall()
    assert isinstance(state, StreamState), "factory did not fall back"
    print("  [ok] fell back to the out-of-core tier")


# --------------------------------------------------------------------------
# Distributed tier (--mesh): dist rungs + elastic kill/resume.
# --------------------------------------------------------------------------
def _dist_tensor():
    from repro.core.distributed import build_sharded_flycoo

    t = _tensor()
    return build_sharded_flycoo(np.asarray(t.indices),
                                np.asarray(t.values), t.dims, n_dev=4,
                                rows_pp=8, block_p=8)


def child_run_mesh(ckpt_dir: str, out_npz: str, n_dev: int,
                   resume: bool) -> None:
    from repro.launch.mesh import make_mesh

    t = _dist_tensor()
    r = cp_als(t, rank=RANK, iters=ITERS, mesh=make_mesh((n_dev,),
                                                         ("data",)),
               checkpoint=ckpt_dir, resume=resume)
    np.savez(out_npz, *[np.asarray(f) for f in r.factors],
             lam=np.asarray(r.lam))


def _spawn_mesh(ckpt_dir, out_npz, n_dev, *, resume=False, chaos_env=None):
    env = dict(os.environ)
    env.pop(chaos.ENV_VAR, None)
    if chaos_env:
        env[chaos.ENV_VAR] = chaos_env
    # each child picks its own device count BEFORE importing jax
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    cmd = [sys.executable, os.path.abspath(__file__), "--child-mesh",
           ckpt_dir, out_npz, str(n_dev)] + (["--resume"] if resume else [])
    return subprocess.run(cmd, env=env, capture_output=True, text=True)


def scenario_dist_exchange(clean) -> None:
    from repro.launch.mesh import make_mesh

    print("scenario: exchange failure -> permute -> all_gather rung")
    install(ChaosSpec(exchange_fail=1, seed=SEED))
    res = cp_als(_dist_tensor(), rank=RANK, iters=ITERS,
                 mesh=make_mesh((4,), ("data",)), ladder=POLICY)
    uninstall()
    _bitwise("permute -> all_gather", clean, res)


def scenario_dist_device_loss(out_dir: str, clean) -> None:
    from repro.launch.mesh import make_mesh

    print("scenario: device loss -> mesh shrink 4 -> 2 from snapshot")
    install(ChaosSpec(device_lost=2, device_lost_n=2, seed=SEED))
    res = cp_als(_dist_tensor(), rank=RANK, iters=ITERS,
                 mesh=make_mesh((4,), ("data",)), ladder=POLICY,
                 checkpoint=os.path.join(out_dir, "dist_ckpt"))
    uninstall()
    _bitwise("mesh shrink 4->2", clean, res)
    degr = obs.REGISTRY.metrics()["resilience_degradations"].as_dict()
    assert degr.get("device_lost:4->2", 0) >= 1, degr


def scenario_dist_transient(clean) -> None:
    from repro.launch.mesh import make_mesh

    print("scenario: transient dist dispatch -> retry with backoff")
    install(ChaosSpec(dist_transient=1, dist_transient_times=2, seed=SEED))
    res = cp_als(_dist_tensor(), rank=RANK, iters=ITERS,
                 mesh=make_mesh((4,), ("data",)), ladder=POLICY)
    uninstall()
    _bitwise("dist dispatch retry", clean, res)


def scenario_elastic_kill_resume(out_dir: str) -> None:
    import shutil

    print("scenario: SIGKILL a 4-device sweep -> resume on 2 and on 1")
    ckpt = os.path.join(out_dir, "elastic_ckpt")
    clean = os.path.join(out_dir, "dist_clean.npz")
    r = _spawn_mesh(os.path.join(out_dir, "elastic_unused"), clean, 4)
    assert r.returncode == 0, r.stderr
    r = _spawn_mesh(ckpt, os.path.join(out_dir, "dist_dead.npz"), 4,
                    chaos_env=f"kill_sweep=3,seed={SEED}")
    assert r.returncode == -signal.SIGKILL, (
        f"chaos child should die by SIGKILL, got {r.returncode}\n"
        f"{r.stderr}")
    assert os.listdir(ckpt), "no sharded snapshot survived the kill"
    for n_dev in (2, 1):
        ckpt_n = os.path.join(out_dir, f"elastic_ckpt{n_dev}")
        shutil.rmtree(ckpt_n, ignore_errors=True)
        shutil.copytree(ckpt, ckpt_n)
        resumed = os.path.join(out_dir, f"dist_resumed{n_dev}.npz")
        r = _spawn_mesh(ckpt_n, resumed, n_dev, resume=True)
        assert r.returncode == 0, r.stderr
        with np.load(clean) as a, np.load(resumed) as b:
            for name in a.files:
                np.testing.assert_array_equal(
                    a[name], b[name],
                    err_msg=f"elastic resume on {n_dev} dev: {name}")
        print(f"  [ok] resumed on {n_dev} device(s) == uninterrupted "
              "4-device run (bitwise)")


def main_mesh(out_dir: str) -> None:
    import jax

    from repro.launch.mesh import make_mesh

    n = len(jax.devices())
    assert n >= 4, (
        f"--mesh needs >= 4 devices, found {n}; run under "
        "XLA_FLAGS=--xla_force_host_platform_device_count=4")
    obs.enable()
    uninstall()

    t = _dist_tensor()
    print(f"zipf tensor dims={DIMS} nnz={t.values.size} (4-device build)")
    clean = cp_als(t, rank=RANK, iters=ITERS,
                   mesh=make_mesh((4,), ("data",)))

    scenario_dist_exchange(clean)
    scenario_dist_device_loss(out_dir, clean)
    scenario_dist_transient(clean)
    scenario_elastic_kill_resume(out_dir)

    report = obs.resilience_report()
    with open(os.path.join(out_dir, "dist_chaos_report.json"), "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    obs.write_chrome_trace(os.path.join(out_dir, "dist_chaos_trace.json"))
    print("\nresilience pairing (dist):")
    for site in sorted(report["injections"]):
        mark = "answered" if site in report["answered"] else "UNANSWERED"
        print(f"  {site:<14} x{report['injections'][site]:<3} {mark}")
    assert report["unanswered"] == [], (
        f"silent degradation: {report['unanswered']}")
    print("\nall dist chaos scenarios answered; wrote "
          f"{out_dir}/dist_chaos_trace.json + "
          f"{out_dir}/dist_chaos_report.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--child", nargs=2, metavar=("CKPT", "OUT"),
                    help="internal: run one ALS child process")
    ap.add_argument("--child-mesh", nargs=3, metavar=("CKPT", "OUT", "NDEV"),
                    help="internal: run one distributed ALS child process")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--mesh", action="store_true",
                    help="run the distributed chaos scenarios")
    ap.add_argument("--out", default="out")
    args = ap.parse_args()
    if args.child:
        child_run(args.child[0], args.child[1], args.resume)
        return
    if args.child_mesh:
        child_run_mesh(args.child_mesh[0], args.child_mesh[1],
                       int(args.child_mesh[2]), args.resume)
        return
    if args.mesh:
        os.makedirs(args.out, exist_ok=True)
        main_mesh(args.out)
        return

    os.makedirs(args.out, exist_ok=True)
    obs.enable()
    uninstall()                      # a stray REPRO_CHAOS must not leak in

    t = _tensor()
    print(f"zipf tensor dims={DIMS} nnz={t.values.size}")
    clean_stream = cp_als_stream(t, rank=RANK, iters=ITERS,
                                 config=_stream_config())
    clean_resident = cp_als(t, rank=RANK, iters=ITERS,
                            config=ExecutionConfig(backend="xla"))

    scenario_stream_faults(args.out, clean_stream)
    scenario_backend_ladder(clean_resident)
    scenario_nan_recovery()
    scenario_corrupt_blob(args.out)
    scenario_resident_oom()
    scenario_kill_resume(args.out)

    report = obs.resilience_report()
    with open(os.path.join(args.out, "chaos_report.json"), "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    obs.write_chrome_trace(os.path.join(args.out, "chaos_trace.json"))
    print("\nresilience pairing:")
    for site in sorted(report["injections"]):
        mark = "answered" if site in report["answered"] else "UNANSWERED"
        print(f"  {site:<14} x{report['injections'][site]:<3} {mark}")
    assert report["unanswered"] == [], (
        f"silent degradation: {report['unanswered']}")
    print("\nall chaos scenarios answered; wrote "
          f"{args.out}/chaos_trace.json + {args.out}/chaos_report.json")


if __name__ == "__main__":
    main()
