"""Serve a small model with batched requests through the decode engine.

    PYTHONPATH=src python examples/serve_lm.py --arch recurrentgemma-9b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import init_model
from repro.serving import Engine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b",
                    choices=list(configs.ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    cfg = configs.smoke(args.arch)
    key = jax.random.PRNGKey(0)
    params = init_model(cfg, key)
    enc = None
    if cfg.kind == "audio":
        enc = jax.random.normal(key, (args.batch, 64, cfg.d_model),
                                cfg.cdtype)
    eng = Engine(params, cfg,
                 ServeConfig(batch=args.batch, max_len=256, temperature=0.8),
                 enc_embeds=enc)
    prompts = jax.random.randint(key, (args.batch, 12), 0, cfg.vocab)
    t0 = time.monotonic()
    out = eng.generate(prompts, args.max_new, key=key)
    dt = time.monotonic() - t0
    print(f"arch={args.arch} generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.max_new / dt:.1f} tok/s)")
    for i in range(min(2, args.batch)):
        print(f"  req {i}: {list(map(int, out[i]))}")


if __name__ == "__main__":
    main()
