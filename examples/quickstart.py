"""Quickstart: FLYCOO spMTTKRP + CPD-ALS on a synthetic FROSTT-like tensor.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro import engine
from repro.core import cp_als, datasets, init_factors, mttkrp_ref
from repro.engine import ExecutionConfig


def main():
    # 1. Load a scaled synthetic of the paper's Nell-1 (Table 3 family).
    tensor = datasets.load("nell1", scale=3e-4, max_nnz=40_000)
    print(f"tensor dims={tensor.dims} nnz={tensor.nnz} "
          f"bits/elem={tensor.memory_bits_per_element():.1f}")
    for d, bal in enumerate(tensor.load_balance()):
        # imbalance is vs the Graham bound OPT >= max(mean, max degree)
        print(f"  mode {d}: max/mean = {bal['max']:.0f}/{bal['mean']:.1f} "
              f"nnz per partition; vs OPT lower bound "
              f"{bal['imbalance']:.3f} "
              f"(4/3 bound holds: {bal['imbalance'] <= 4 / 3 + 0.01})")

    # 2. spMTTKRP along all modes with dynamic remapping (paper Alg. 5):
    #    one engine state, one jitted lax.scan over the mode rotation.
    rank = 32
    factors = init_factors(jax.random.PRNGKey(0), tensor.dims, rank)
    config = ExecutionConfig()            # backend="pallas" on TPU
    state = engine.init(tensor, config)
    outs, state = engine.all_modes(state, tuple(factors))
    ref = mttkrp_ref(tensor.indices, tensor.values, factors, 0,
                     tensor.dims[0])
    err = float(np.max(np.abs(np.asarray(outs[0]) - np.asarray(ref))))
    print(f"mode-0 max |FLYCOO - COO oracle| = {err:.2e} "
          f"({engine.DISPATCH_COUNTS['all_modes']} dispatch for "
          f"{tensor.nmodes} modes)")

    # 3. Full CPD via ALS (each sweep is a single traced program).
    res = cp_als(tensor, rank=8, iters=5, config=config)
    print("CPD-ALS fits:", [round(f, 4) for f in res.fits])


if __name__ == "__main__":
    main()
