"""Train with a CPD-factorized embedding (the paper's kernel inside an LM).

The (V, D) table is a rank-R CPD; its gradient for each batch is an
spMTTKRP (DESIGN.md §4). Compares param counts and shows the loss trains.

    PYTHONPATH=src python examples/cpd_embedding_lm.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.tensorized import cpd_embed, cpd_logits, init_cpd_embedding


def main():
    vocab, d_model, rank, steps = 8192, 256, 64, 200
    key = jax.random.PRNGKey(0)
    params = init_cpd_embedding(key, vocab, d_model, rank)
    dense_params = vocab * d_model
    cpd_params = sum(p.size for p in params.values())
    print(f"dense table: {dense_params / 1e6:.2f}M params; "
          f"CPD rank-{rank}: {cpd_params / 1e6:.3f}M "
          f"({dense_params / cpd_params:.0f}x smaller)")

    # toy task: next-token prediction on a zipf stream through the CPD
    # embedding + tied CPD head only (isolates the paper's kernel).
    def loss_fn(p, tokens, targets):
        x = cpd_embed(p, tokens)                 # bwd = spMTTKRP
        logits = cpd_logits(p, x)
        lf = logits.astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(lf, axis=-1)
        picked = jnp.take_along_axis(
            lf, targets[..., None], axis=-1)[..., 0]
        return jnp.mean(lse - picked)

    @jax.jit
    def step(p, tokens, targets, lr=0.3):
        loss, g = jax.value_and_grad(loss_fn)(p, tokens, targets)
        return jax.tree.map(lambda w, gw: w - lr * gw, p, g), loss

    rng = np.random.default_rng(0)
    losses = []
    for i in range(steps):
        toks = (rng.zipf(1.5, (8, 33)) % vocab).astype(np.int32)
        params, loss = step(params, jnp.asarray(toks[:, :-1]),
                            jnp.asarray(toks[:, 1:]))
        losses.append(float(loss))
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} over {steps} steps")
    assert losses[-1] < losses[0]


if __name__ == "__main__":
    main()
