"""End-to-end driver: train a ~100M-param llama-family model for a few
hundred steps on CPU with checkpointing + auto-resume.

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse
import dataclasses
import time

import jax

from repro import configs
from repro.training import (ControllerConfig, OptimizerConfig, SyntheticLM,
                            TrainController)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_example_ckpt")
    args = ap.parse_args()

    # ~100M params: tinyllama family, narrowed
    cfg = dataclasses.replace(
        configs.get_config("tinyllama-1.1b"),
        n_layers=8, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
        d_ff=2048, vocab=32000, remat="none",
    )
    n = cfg.param_count()
    print(f"model: {n / 1e6:.1f}M params")

    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    ctrl = ControllerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=100)
    data = SyntheticLM(cfg, batch=args.batch, seq=args.seq, seed=0)
    tc = TrainController(cfg, ocfg, ctrl, data)

    t0 = time.monotonic()
    state, metrics = tc.run(args.steps)
    dt = time.monotonic() - t0
    toks = args.steps * args.batch * args.seq
    print(f"step={int(state['step'])} loss={float(metrics['loss']):.4f} "
          f"({toks / dt:.0f} tok/s, stragglers={tc.straggler_steps})")


if __name__ == "__main__":
    main()
