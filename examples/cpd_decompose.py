"""Recover a planted low-rank CPD from sparse observations — end to end.

Plants a rank-4 tensor, samples ~half the entries, runs ALS with the
FLYCOO executor, and reports fit per sweep (paper's CPD use-case).

    PYTHONPATH=src python examples/cpd_decompose.py [--pallas]
    PYTHONPATH=src python examples/cpd_decompose.py --stream
    PYTHONPATH=src python examples/cpd_decompose.py --stream --trace out.json

``--stream`` reruns the same decomposition as if the tensor were bigger
than the device: a deliberately tiny ``device_budget_bytes`` forces the
out-of-core tier (``repro.engine.stream``), which keeps the element list
host-side and streams it through a double-buffered ring of
partition-aligned chunks — same fits, bitwise-identical MTTKRPs.

``--trace PATH`` turns on ``repro.obs`` tracing for the whole run and
writes a Perfetto-loadable Chrome trace (plan/init/sweep/upload/compute
spans + the metrics snapshot) to PATH, then prints the run report.
"""
import argparse

import jax
import numpy as np

from repro import obs
from repro.core import build_flycoo, cp_als
from repro.engine import ExecutionConfig
from repro.engine.stream import cp_als_stream, resident_bytes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pallas", action="store_true",
                    help="use the Pallas kernel path (interpret on CPU)")
    ap.add_argument("--rank", type=int, default=8)
    ap.add_argument("--iters", type=int, default=25)
    ap.add_argument("--stream", action="store_true",
                    help="also decompose out-of-core under a tiny device "
                         "budget (tensors bigger than your device)")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="enable repro.obs tracing and write a Chrome "
                         "trace (load at ui.perfetto.dev) to PATH")
    args = ap.parse_args()
    if args.trace:
        obs.enable()

    rng = np.random.default_rng(0)
    dims, true_rank = (40, 30, 20), 4
    planted = [rng.standard_normal((d, true_rank)) for d in dims]
    full = np.einsum("ir,jr,kr->ijk", *planted)
    # sparse CPD semantics: the COO entries ARE the tensor (zeros are real
    # zeros), so plant a fully-observed rank-4 tensor in COO form
    idx = np.argwhere(np.ones(dims, bool)).astype(np.int32)
    val = full.reshape(-1).astype(np.float32)
    tensor = build_flycoo(idx, val, dims, rows_pp=16, block_p=32)
    print(f"planted rank-{true_rank} tensor as {val.size}-entry COO")

    config = ExecutionConfig(backend="pallas" if args.pallas else "xla",
                             interpret=True)
    res = cp_als(tensor, rank=args.rank, iters=args.iters,
                 key=jax.random.PRNGKey(1), config=config)
    for i, f in enumerate(res.fits):
        print(f"  sweep {i:2d}: fit = {f:.4f}")
    assert res.fits[-1] > 0.95, "ALS should recover the planted CPD"
    print("recovered.")

    if args.stream:
        # Tensors bigger than your device: pretend the device only holds
        # a quarter of the resident footprint. make_engine/cp_als_stream
        # slice each mode's block schedule into budget-sized chunks and
        # prefetch chunk k+1 while chunk k computes — the factors come
        # out the same because every chunk runs the unchanged backend.
        budget = resident_bytes(tensor, config) // 4
        print(f"\nstreaming under device_budget_bytes={budget} "
              f"(~4x oversubscribed)")
        sconfig = ExecutionConfig(backend=config.backend,
                                  interpret=config.interpret,
                                  device_budget_bytes=budget,
                                  rank_hint=args.rank)
        sres = cp_als_stream(tensor, rank=args.rank, iters=args.iters,
                             key=jax.random.PRNGKey(1), config=sconfig)
        print(f"  streamed fit = {sres.fits[-1]:.4f} "
              f"(resident fit = {res.fits[-1]:.4f})")
        assert abs(sres.fits[-1] - res.fits[-1]) < 1e-4, \
            "streamed ALS must match the resident engine"
        print("streamed decomposition matches.")

    if args.trace:
        obs.write_chrome_trace(args.trace)
        print(f"\nwrote Chrome trace to {args.trace} "
              f"(load at ui.perfetto.dev)")
        print(obs.render_report())


if __name__ == "__main__":
    main()
