"""Training substrate: optimizer, checkpointing, fault tolerance, data."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.training import (CheckpointManager, ControllerConfig,
                            OptimizerConfig, SyntheticLM, TrainController,
                            init_state, make_train_step)
from repro.training import optimizer as opt_lib


def test_adamw_minimizes_quadratic():
    ocfg = OptimizerConfig(lr=0.1, warmup_steps=1, total_steps=200,
                           weight_decay=0.0, grad_clip=100.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt_lib.init(params, ocfg)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt_lib.update(grads, state, params, ocfg)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_adafactor_minimizes_quadratic():
    ocfg = OptimizerConfig(name="adafactor", lr=0.1, warmup_steps=1,
                           total_steps=300, weight_decay=0.0)
    params = {"w": jnp.ones((4, 3)) * 2.0}
    state = opt_lib.init(params, ocfg)
    for _ in range(250):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt_lib.update(grads, state, params, ocfg)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_grad_clip():
    grads = {"a": jnp.full((10,), 100.0)}
    clipped, norm = opt_lib.clip_by_global_norm(grads, 1.0)
    assert float(norm) > 100
    assert float(opt_lib.global_norm(clipped)) == pytest.approx(1.0, 1e-3)


def test_loss_decreases_smoke():
    cfg = configs.smoke("tinyllama-1.1b")
    ocfg = OptimizerConfig(lr=3e-3, warmup_steps=2, total_steps=40)
    state = init_state(cfg, ocfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, ocfg))
    data = SyntheticLM(cfg, batch=4, seq=64, seed=0)
    losses = []
    for _ in range(30):
        state, m = step(state, data.next())
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


def test_grad_accum_matches_full_batch():
    cfg = configs.smoke("olmo-1b")
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    key = jax.random.PRNGKey(0)
    s1 = init_state(cfg, ocfg, key)
    s2 = jax.tree.map(jnp.copy, s1)
    batch = SyntheticLM(cfg, batch=4, seq=32, seed=0).next()
    st1, m1 = jax.jit(make_train_step(cfg, ocfg, grad_accum=1))(s1, batch)
    st2, m2 = jax.jit(make_train_step(cfg, ocfg, grad_accum=2))(s2, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), abs=2e-2)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))),
        st1["params"], st2["params"])
    assert max(jax.tree.leaves(d)) < 5e-2


def test_checkpoint_roundtrip(tmp_path):
    cfg = configs.smoke("tinyllama-1.1b")
    ocfg = OptimizerConfig()
    state = init_state(cfg, ocfg, jax.random.PRNGKey(0))
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    mgr.save(state, {"step": 0})
    restored, data_state = mgr.restore_latest(like=state)
    ok = jax.tree.map(lambda a, b: bool(jnp.all(a == b)), state, restored)
    assert all(jax.tree.leaves(ok))


def test_checkpoint_retention_and_atomicity(tmp_path):
    cfg = configs.smoke("olmo-1b")
    ocfg = OptimizerConfig()
    state = init_state(cfg, ocfg, jax.random.PRNGKey(0))
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        state = {**state, "step": jnp.asarray(s, jnp.int32)}
        mgr.save(state, {})
    assert mgr.all_steps() == [3, 4]
    assert not [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]


def test_preemption_resume(tmp_path):
    cfg = configs.smoke("tinyllama-1.1b")
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=20)
    ctrl = ControllerConfig(ckpt_dir=str(tmp_path), ckpt_every=4, keep=2,
                            async_save=False)
    tc = TrainController(cfg, ocfg, ctrl, SyntheticLM(cfg, 2, 32, seed=0))
    with pytest.raises(InterruptedError):
        tc.run(16, fail_at=10)
    # restart-the-binary semantics: a fresh controller resumes
    tc2 = TrainController(cfg, ocfg, ctrl, SyntheticLM(cfg, 2, 32, seed=0))
    assert int(tc2.state["step"]) == 8
    assert tc2.data.step == 8  # data cursor restored with the state
    state, metrics = tc2.run(16)
    assert int(state["step"]) == 16


def test_straggler_watchdog():
    cfg = configs.smoke("olmo-1b")
    ocfg = OptimizerConfig()
    ctrl = ControllerConfig(ckpt_dir="/tmp/_watchdog_unused",
                            straggler_factor=3.0)
    tc = TrainController.__new__(TrainController)
    tc.ctrl = ctrl
    tc.durations, tc.straggler_steps = [], []
    for i in range(10):
        tc._watch(i, 0.1)
    tc._watch(10, 1.0)   # 10x median => flagged
    assert tc.straggler_steps == [10]


def test_data_pipeline_determinism_and_resume():
    cfg = configs.smoke("qwen2.5-3b")
    d1 = SyntheticLM(cfg, 2, 16, seed=7)
    b0, b1 = d1.next(), d1.next()
    d2 = SyntheticLM(cfg, 2, 16, seed=7)
    d2.set_state({"step": 1, "seed": 7})
    b1b = d2.next()
    np.testing.assert_array_equal(b1["tokens"], b1b["tokens"])
    assert not np.array_equal(b0["tokens"], b1["tokens"])
