"""Out-of-core streaming engine tests (ISSUE 7 acceptance surface).

Streamed-vs-resident BITWISE parity on all four backends, nmodes 3-6, any
start mode, chunk-boundary properties (one-partition chunks, exactly-S
chunks, non-divisor sizes), full ``cp_als_stream`` sweeps, budget-derived
chunk sizing with measured ring residency under budget, factory
auto-residency, the autotuner's transfer-bytes term, and the PlanCache
disk persistence satellite.

Tensors are deliberately tiny — the chunk machinery is shape-generic and
CI runs every backend through Pallas interpret mode on CPU.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.engine as engine
from repro.core.flycoo import build_flycoo
from repro.engine import ExecutionConfig, PlanSpec, make_engine
from repro.engine.stream import (StreamState, cp_als_stream, plan_stream,
                                 resident_bytes, resolve_chunk_slots,
                                 stream_all_modes, stream_init,
                                 stream_transfer_model)

BACKENDS = ("xla", "ref", "pallas", "pallas_fused")


def _coo(nmodes=3, nnz=300, seed=0):
    dims = (29, 23, 19, 13, 11, 7)[:nmodes]
    rng = np.random.default_rng(seed)
    idx = np.unique(
        np.stack([rng.integers(0, d, nnz) for d in dims], 1)
        .astype(np.int64), axis=0)
    return idx, rng.standard_normal(len(idx)).astype(np.float32), dims


def _factors(dims, rank=5, seed=1):
    key = jax.random.PRNGKey(seed)
    return tuple(
        jax.random.normal(jax.random.fold_in(key, d), (dims[d], rank),
                          jnp.float32) for d in range(len(dims)))


def _assert_stream_matches_resident(config, t, factors, start_mode=0):
    st = engine.init(t, config, start_mode=start_mode)
    outs_res, _ = engine.all_modes(st, factors)
    ss = stream_init(t, config, start_mode=start_mode)
    outs_s, ss = stream_all_modes(ss, factors)
    for d in range(t.nmodes):
        np.testing.assert_array_equal(np.asarray(outs_res[d]),
                                      np.asarray(outs_s[d]),
                                      err_msg=f"mode {d}")
    return ss


# --------------------------------------------------------------------------
# Bitwise parity: backends x schedules x nmodes x start modes.
# --------------------------------------------------------------------------
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("schedule", ["compact", "rect"])
def test_stream_bitwise_all_backends(backend, schedule):
    idx, val, dims = _coo()
    t = build_flycoo(idx, val, dims, rows_pp=8, schedule=schedule)
    config = ExecutionConfig(backend=backend, rows_pp=8, chunk_nnz=300,
                             schedule=schedule)
    _assert_stream_matches_resident(config, t, _factors(dims))


@pytest.mark.parametrize("nmodes", [3, 4, 5, 6])
def test_stream_bitwise_nmodes(nmodes):
    idx, val, dims = _coo(nmodes=nmodes, nnz=250)
    t = build_flycoo(idx, val, dims, rows_pp=4)
    config = ExecutionConfig(backend="pallas_fused", rows_pp=4,
                             chunk_nnz=256)
    _assert_stream_matches_resident(config, t, _factors(dims))


@pytest.mark.parametrize("start_mode", [0, 1, 2, 3])
def test_stream_any_start_mode(start_mode):
    idx, val, dims = _coo(nmodes=4, nnz=250)
    t = build_flycoo(idx, val, dims, rows_pp=4)
    config = ExecutionConfig(backend="xla", rows_pp=4, chunk_nnz=256)
    _assert_stream_matches_resident(config, t, _factors(dims),
                                    start_mode=start_mode)


# --------------------------------------------------------------------------
# Chunk-boundary properties: every chunking is bitwise-equal.
# --------------------------------------------------------------------------
def test_chunk_boundaries_bitwise_equal():
    """One-partition chunks, exactly-S (single chunk), and non-divisor
    chunk sizes all produce bitwise-identical results — chunking is
    partition-aligned, so no boundary can split an accumulation."""
    idx, val, dims = _coo(nnz=500)
    t = build_flycoo(idx, val, dims, rows_pp=8)
    factors = _factors(dims)
    smax = max(p.padded_nnz for p in t.plans)
    one_partition = max(p.padded_nnz // p.kappa for p in t.plans)
    for chunk_nnz in (1, one_partition, smax, smax + 1, 137, 384):
        config = ExecutionConfig(backend="xla", rows_pp=8,
                                 chunk_nnz=chunk_nnz)
        ss = _assert_stream_matches_resident(config, t, factors)
        assert ss.stats.chunks_streamed == sum(
            cs.nchunks for cs in ss.plan.chunks)


def test_single_chunk_covers_whole_mode():
    """chunk_nnz >= S collapses to one chunk per mode (the degenerate
    resident case, still through the streaming path)."""
    idx, val, dims = _coo()
    t = build_flycoo(idx, val, dims, rows_pp=8)
    config = ExecutionConfig(backend="xla", rows_pp=8, chunk_nnz=1 << 20)
    plan = plan_stream(t, config)
    assert all(cs.nchunks == 1 for cs in plan.chunks)
    _assert_stream_matches_resident(config, t, _factors(dims))


# --------------------------------------------------------------------------
# Full ALS sweeps.
# --------------------------------------------------------------------------
def test_cp_als_stream_matches_resident():
    idx, val, dims = _coo(nnz=400)
    t = build_flycoo(idx, val, dims, rows_pp=8)
    config = ExecutionConfig(backend="xla", rows_pp=8, chunk_nnz=300)
    from repro.core.cpd import cp_als

    key = jax.random.PRNGKey(3)
    res = cp_als(t, rank=4, iters=3, key=key, config=config)
    res_s = cp_als_stream(t, rank=4, iters=3, key=key, config=config)
    for d in range(len(dims)):
        np.testing.assert_allclose(np.asarray(res.factors[d]),
                                   np.asarray(res_s.factors[d]),
                                   rtol=2e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(res.fits),
                               np.asarray(res_s.fits), atol=1e-6)


# --------------------------------------------------------------------------
# Budget model: ring residency, sizing, auto-residency, transfer term.
# --------------------------------------------------------------------------
def test_budget_sizes_ring_under_budget():
    """An achievable ``device_budget_bytes`` bounds the measured chunk
    ring; the tensor oversubscribes the budget yet streams bitwise."""
    idx, val, dims = _coo(nnz=600)
    t = build_flycoo(idx, val, dims, rows_pp=8)
    budget = 24 * 1024
    config = ExecutionConfig(backend="xla", rows_pp=8, rank_hint=5,
                             device_budget_bytes=budget)
    assert resident_bytes(t, config) > budget  # oversubscribed
    ss = _assert_stream_matches_resident(config, t, _factors(dims))
    assert 0 < ss.stats.peak_ring_bytes <= budget
    assert ss.stats.peak_ring_chunks <= config.stream_ring
    assert ss.stats.h2d_bytes > 0 and ss.stats.fragment_bytes > 0
    # double buffering: every upload but each mode's first is prefetched
    assert ss.stats.overlap_efficiency == pytest.approx(
        1 - t.nmodes / ss.stats.uploads)


def test_resolve_chunk_slots_priority():
    config = ExecutionConfig(chunk_nnz=999)
    assert resolve_chunk_slots(config, (64, 64, 64)) == 999
    from repro.engine.stream import DEFAULT_CHUNK_SLOTS

    assert resolve_chunk_slots(ExecutionConfig(),
                               (64, 64, 64)) == DEFAULT_CHUNK_SLOTS
    tight = resolve_chunk_slots(
        ExecutionConfig(device_budget_bytes=1 << 20, rows_pp=8),
        (64, 64, 64))
    loose = resolve_chunk_slots(
        ExecutionConfig(device_budget_bytes=1 << 24, rows_pp=8),
        (64, 64, 64))
    assert tight < loose  # bigger budget -> bigger chunks


def test_make_engine_auto_residency():
    idx, val, dims = _coo()
    big = make_engine((idx, val, dims),
                      PlanSpec(rows_pp=8, device_budget_bytes=1 << 30),
                      cache=False)
    assert isinstance(big, engine.EngineState)
    small = make_engine((idx, val, dims),
                        PlanSpec(rows_pp=8, rank_hint=5,
                                 device_budget_bytes=16_000),
                        cache=False)
    assert isinstance(small, StreamState)
    forced = make_engine((idx, val, dims),
                         PlanSpec(rows_pp=8, residency="stream",
                                  chunk_nnz=256), cache=False)
    assert isinstance(forced, StreamState)


def test_planspec_canonical_threads_one_budget():
    spec = PlanSpec(device_budget_bytes=1 << 23).canonical()
    from repro.engine import derive_vmem_budget

    assert spec.vmem_budget_bytes == derive_vmem_budget(1 << 23)
    assert PlanSpec().canonical().residency == "full"  # auto, no budget
    with pytest.raises(ValueError):  # contradictory budgets refused
        ExecutionConfig(vmem_budget_bytes=1 << 20,
                        device_budget_bytes=1 << 10)


def test_autotune_prices_streaming_transfer():
    idx, val, dims = _coo(nnz=500)
    t = build_flycoo(idx, val, dims, rows_pp=8)
    from repro.engine.autotune import analytic_cost, modeled_cost

    full = PlanSpec(backend="xla", rows_pp=8)
    streamed = PlanSpec(backend="xla", rows_pp=8, residency="stream")
    assert modeled_cost(t, streamed) > modeled_cost(t, full)
    degrees = [np.bincount(idx[:, d], minlength=dims[d])
               for d in range(len(dims))]
    assert analytic_cost(degrees, dims, len(idx), streamed) > \
        analytic_cost(degrees, dims, len(idx), full)
    model = stream_transfer_model(t, streamed.to_config())
    assert model["h2d_bytes"] > 0 and model["total_chunks"] >= t.nmodes


# --------------------------------------------------------------------------
# Satellite: PlanCache disk persistence.
# --------------------------------------------------------------------------
def test_plancache_disk_roundtrip(tmp_path):
    from repro.core.plancache import PlanCache

    idx, val, dims = _coo(nnz=400)
    c1 = PlanCache(path=tmp_path)
    t0 = c1.get_tensor(idx, val, dims, rows_pp=8)
    assert c1.last_outcome == "miss" and c1.disk_saves == 1

    # a fresh cache (new process analogue) loads the blob: identity hit
    c2 = PlanCache(path=tmp_path)
    t1 = c2.get_tensor(idx.copy(), val, dims, rows_pp=8)
    assert c2.last_outcome == "hit" and c2.disk_loads == 1
    assert c2.misses == 0
    for a, b in zip(t0.plans, t1.plans):
        np.testing.assert_array_equal(a.row_relabel, b.row_relabel)
        np.testing.assert_array_equal(a.slot_of_elem, b.slot_of_elem)
        np.testing.assert_array_equal(a.block_part, b.block_part)

    # permuted order: structural reuse from disk, bitwise vs cold plan
    rng = np.random.default_rng(7)
    perm = rng.permutation(len(idx))
    c3 = PlanCache(path=tmp_path)
    t2 = c3.get_tensor(idx[perm], val[perm], dims, rows_pp=8)
    assert c3.last_outcome == "structural" and c3.disk_loads == 1
    cold = build_flycoo(idx[perm], val[perm], dims, rows_pp=8)
    for a, b in zip(t2.plans, cold.plans):
        np.testing.assert_array_equal(a.row_relabel, b.row_relabel)
        np.testing.assert_array_equal(a.slot_of_elem, b.slot_of_elem)

    # different knobs address a different blob; memory path still serves
    c3.get_tensor(idx[perm], val[perm], dims, rows_pp=8)
    assert c3.last_outcome == "hit" and c3.disk_loads == 1


def test_plancache_disk_streamed_engine_parity(tmp_path):
    """A streamed engine built through a disk-persisted cache is bitwise-
    identical to one built cold — plans can never change numerics."""
    from repro.core.plancache import PlanCache

    idx, val, dims = _coo(nnz=400)
    factors = _factors(dims)
    spec = PlanSpec(backend="xla", rows_pp=8, residency="stream",
                    chunk_nnz=300)
    outs_cold, _ = stream_all_modes(
        make_engine((idx, val, dims), spec, cache=False), factors)
    PlanCache(path=tmp_path).get_tensor(idx, val, dims, rows_pp=8)
    warm_cache = PlanCache(path=tmp_path)
    outs_disk, _ = stream_all_modes(
        make_engine((idx, val, dims), spec, cache=warm_cache), factors)
    assert warm_cache.disk_loads == 1
    for d in range(len(dims)):
        np.testing.assert_array_equal(np.asarray(outs_cold[d]),
                                      np.asarray(outs_disk[d]))
