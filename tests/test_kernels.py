"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("kappa,rows_pp,blocks_pp,p", [
    (2, 8, 1, 8), (4, 16, 3, 16), (8, 4, 2, 32), (3, 128, 2, 128),
])
@pytest.mark.parametrize("nm1,r", [(2, 8), (3, 32), (4, 16), (2, 128)])
def test_mttkrp_fused_shapes(kappa, rows_pp, blocks_pp, p, nm1, r):
    rng = np.random.default_rng(kappa * 1000 + nm1)
    s = kappa * blocks_pp * p
    g = rng.standard_normal((s, nm1, r)).astype(np.float32)
    val = rng.standard_normal(s).astype(np.float32)
    lrow = rng.integers(-1, rows_pp, s).astype(np.int32)
    val[lrow < 0] = 0.0
    args = (jnp.asarray(g), jnp.asarray(val), jnp.asarray(lrow))
    kw = dict(kappa=kappa, rows_pp=rows_pp, blocks_pp=blocks_pp, block_p=p)
    out = ops.mttkrp_fused(*args, **kw, interpret=True)
    exp = ref.mttkrp_fused_ref(*args, **kw)
    np.testing.assert_allclose(out, exp, rtol=1e-4, atol=1e-4)


def _gather_case(seed, kappa, rows_pp, blocks_pp, p, nm1, r):
    """Random fused-gather kernel inputs + the composed oracle target."""
    rng = np.random.default_rng(seed)
    s = kappa * blocks_pp * p
    dims_in = [int(rng.integers(8, 40)) for _ in range(nm1)]
    facs = tuple(jnp.asarray(rng.standard_normal((d, r)).astype(np.float32))
                 for d in dims_in)
    lidx = np.stack([rng.integers(0, d, s) for d in dims_in]).astype(np.int32)
    val = rng.standard_normal(s).astype(np.float32)
    lrow = rng.integers(-1, rows_pp, s).astype(np.int32)
    val[lrow < 0] = 0.0
    gathered = jnp.stack([facs[w][lidx[w]] for w in range(nm1)], axis=1)
    exp = ref.mttkrp_fused_ref(gathered, jnp.asarray(val), jnp.asarray(lrow),
                               kappa=kappa, rows_pp=rows_pp,
                               blocks_pp=blocks_pp, block_p=p)
    return facs, jnp.asarray(lidx), jnp.asarray(val), jnp.asarray(lrow), exp


@pytest.mark.parametrize("kappa,rows_pp,blocks_pp,p", [
    (2, 8, 1, 8), (4, 16, 3, 16), (3, 4, 2, 32),
])
@pytest.mark.parametrize("nm1,r", [(2, 8), (3, 32), (5, 16)])
def test_mttkrp_fused_gather_shapes(kappa, rows_pp, blocks_pp, p, nm1, r):
    """In-kernel gather == XLA gather + baseline kernel oracle."""
    facs, lidx, val, lrow, exp = _gather_case(
        kappa * 100 + nm1, kappa, rows_pp, blocks_pp, p, nm1, r)
    out = ops.mttkrp_fused_gather(val, lrow, lidx, facs, kappa=kappa,
                                  rows_pp=rows_pp, blocks_pp=blocks_pp,
                                  block_p=p, interpret=True)
    np.testing.assert_allclose(out, exp, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("kappa,rows_pp,blocks_pp,p,nm1,r", [
    (2, 8, 1, 8, 2, 8), (3, 4, 2, 16, 3, 32),
])
def test_mttkrp_fused_remap_scatters_next_layout(kappa, rows_pp, blocks_pp,
                                                 p, nm1, r):
    """The remap variant returns the EC result AND the mode-(d+1) layout
    (val/idx/alpha scattered to alpha[:, next]; empty slots = pad pattern),
    matching the XLA scatter the scan step used to issue."""
    facs, lidx, val, lrow, exp = _gather_case(
        7 * kappa + p, kappa, rows_pp, blocks_pp, p, nm1, r)
    rng = np.random.default_rng(p + nm1)
    s = val.shape[0]
    n = nm1 + 1
    smax = s + 24
    alive = np.asarray(lrow) >= 0
    idx = rng.integers(0, 50, (s, n)).astype(np.int32)
    alpha = np.full((s, n), -1, np.int32)
    alpha[alive] = rng.integers(0, smax, (int(alive.sum()), n))
    alpha[alive, 1] = rng.permutation(smax)[: int(alive.sum())]
    dst = alpha[:, 1]

    out, nval, nidx, nalpha = ops.mttkrp_fused_remap(
        val, jnp.asarray(idx), jnp.asarray(alpha), lrow, lidx, facs,
        kappa=kappa, rows_pp=rows_pp, blocks_pp=blocks_pp, block_p=p,
        smax=smax, next_mode=1, interpret=True)
    np.testing.assert_allclose(out, exp, rtol=1e-4, atol=1e-4)

    eval_ = np.zeros(smax, np.float32)
    eidx = np.zeros((smax, n), np.int32)
    ealpha = np.full((smax, n), -1, np.int32)
    eval_[dst[alive]] = np.asarray(val)[alive]
    eidx[dst[alive]] = idx[alive]
    ealpha[dst[alive]] = alpha[alive]
    np.testing.assert_allclose(np.asarray(nval), eval_)
    np.testing.assert_array_equal(np.asarray(nidx), eidx)
    np.testing.assert_array_equal(np.asarray(nalpha), ealpha)


# --------------------------------------------------------------------------
# Compact (descriptor-driven) kernels with in-block row dedup.
# --------------------------------------------------------------------------
def _compact_case(seed, kappa, part_blocks, p, nm1, r, hot_rows=4):
    """Random compact-schedule inputs: a descriptor with the given per-
    partition block counts, Zipf-ish factor rows (few hot rows so blocks
    dedup), dedup tables from the shared host-side builder, and the
    composed descriptor-aware oracle."""
    from repro.core.flycoo import _ROW_SENTINEL, dedup_tables_from_rows

    rng = np.random.default_rng(seed)
    assert len(part_blocks) == kappa
    nblocks = sum(part_blocks)
    s = nblocks * p
    bpart = np.repeat(np.arange(kappa), part_blocks).astype(np.int32)
    rows_pp = 8
    dims_in = [int(rng.integers(8, 40)) for _ in range(nm1)]
    facs = tuple(jnp.asarray(rng.standard_normal((d, r)).astype(np.float32))
                 for d in dims_in)
    # skewed row choices: sample from a few hot rows most of the time
    lidx = np.stack([
        np.where(rng.random(s) < 0.7,
                 rng.integers(0, min(hot_rows, d), s),
                 rng.integers(0, d, s))
        for d in dims_in]).astype(np.int64)
    lrow = rng.integers(-1, rows_pp, s).astype(np.int32)
    val = rng.standard_normal(s).astype(np.float32)
    val[lrow < 0] = 0.0
    uidx, upos, nuniq = [], [], []
    for w in range(nm1):
        rows = np.where(lrow < 0, _ROW_SENTINEL, lidx[w])
        u, pos, nun = dedup_tables_from_rows(rows, nblocks, p)
        uidx.append(u)
        upos.append(pos)
        nuniq.append(nun)
    uidx, upos, nuniq = (np.stack(uidx), np.stack(upos, axis=1),
                         np.stack(nuniq))
    gathered = jnp.stack([facs[w][lidx[w]] for w in range(nm1)], axis=1)
    exp = ref.mttkrp_fused_compact_ref(
        gathered, jnp.asarray(val), jnp.asarray(lrow), jnp.asarray(bpart),
        kappa=kappa, rows_pp=rows_pp, block_p=p)
    return dict(facs=facs, bpart=jnp.asarray(bpart),
                uidx=jnp.asarray(uidx), upos=jnp.asarray(upos),
                nuniq=jnp.asarray(nuniq), gathered=gathered,
                val=jnp.asarray(val), lrow=jnp.asarray(lrow), exp=exp,
                kappa=kappa, rows_pp=rows_pp, nblocks=nblocks, p=p,
                nm1=nm1, nuniq_np=nuniq, lidx=lidx)


@pytest.mark.parametrize("kappa,part_blocks,p", [
    (2, (3, 1), 8), (4, (1, 4, 2, 1), 16), (3, (2, 1, 5), 32),
])
@pytest.mark.parametrize("nm1,r", [(2, 8), (3, 32), (5, 16)])
def test_mttkrp_fused_compact_shapes(kappa, part_blocks, p, nm1, r):
    """Descriptor-driven 1-D grid == descriptor-aware oracle on skewed,
    deliberately unbalanced per-partition block counts."""
    c = _compact_case(kappa * 10 + p, kappa, part_blocks, p, nm1, r)
    out = ops.mttkrp_fused_compact(
        c["gathered"], c["val"], c["lrow"], c["bpart"], kappa=c["kappa"],
        rows_pp=c["rows_pp"], nblocks=c["nblocks"], block_p=c["p"],
        interpret=True)
    np.testing.assert_allclose(out, c["exp"], rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("kappa,part_blocks,p", [
    (2, (3, 1), 8), (4, (1, 4, 2, 1), 16), (3, (2, 1, 5), 32),
])
@pytest.mark.parametrize("nm1,r", [(2, 8), (3, 32), (5, 16)])
def test_mttkrp_fused_gather_compact_dedup(kappa, part_blocks, p, nm1, r):
    """In-kernel dedup gather (U <= P row DMAs + one-hot stage select)
    == XLA gather + oracle; the dedup tables actually dedup (hot rows)."""
    c = _compact_case(kappa * 7 + nm1, kappa, part_blocks, p, nm1, r)
    assert int(c["nuniq_np"].sum()) < c["nblocks"] * c["p"] * c["nm1"]
    out = ops.mttkrp_fused_gather_compact(
        c["val"], c["lrow"], c["upos"], c["bpart"], c["uidx"], c["nuniq"],
        c["facs"], kappa=c["kappa"], rows_pp=c["rows_pp"],
        nblocks=c["nblocks"], block_p=c["p"], interpret=True)
    np.testing.assert_allclose(out, c["exp"], rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("kappa,part_blocks,p,nm1,r", [
    (2, (3, 1), 8, 2, 8), (3, (2, 1, 3), 16, 3, 32),
])
def test_mttkrp_fused_remap_compact_scatters_next_layout(kappa, part_blocks,
                                                         p, nm1, r):
    """The compact remap variant returns the EC result AND the mode-(d+1)
    layout, matching the XLA scatter the scan step would issue."""
    c = _compact_case(13 * kappa + p, kappa, part_blocks, p, nm1, r)
    rng = np.random.default_rng(p + nm1)
    s = c["nblocks"] * c["p"]
    n = nm1 + 1
    smax = s + 24
    lrow = np.asarray(c["lrow"])
    alive = lrow >= 0
    idx = rng.integers(0, 50, (s, n)).astype(np.int32)
    alpha = np.full((s, n), -1, np.int32)
    alpha[alive] = rng.integers(0, smax, (int(alive.sum()), n))
    alpha[alive, 1] = rng.permutation(smax)[: int(alive.sum())]
    dst = alpha[:, 1]

    out, nval, nidx, nalpha = ops.mttkrp_fused_remap_compact(
        c["val"], jnp.asarray(idx), jnp.asarray(alpha), c["lrow"],
        c["upos"], c["bpart"], c["uidx"], c["nuniq"], c["facs"],
        kappa=c["kappa"], rows_pp=c["rows_pp"], nblocks=c["nblocks"],
        block_p=c["p"], smax=smax, next_mode=1, interpret=True)
    np.testing.assert_allclose(out, c["exp"], rtol=1e-4, atol=1e-4)

    eval_ = np.zeros(smax, np.float32)
    eidx = np.zeros((smax, n), np.int32)
    ealpha = np.full((smax, n), -1, np.int32)
    eval_[dst[alive]] = np.asarray(c["val"])[alive]
    eidx[dst[alive]] = idx[alive]
    ealpha[dst[alive]] = alpha[alive]
    np.testing.assert_allclose(np.asarray(nval), eval_)
    np.testing.assert_array_equal(np.asarray(nidx), eidx)
    np.testing.assert_array_equal(np.asarray(nalpha), ealpha)


@pytest.mark.parametrize("b,t,d,chunk", [
    (1, 32, 8, 8), (2, 64, 16, 16), (3, 128, 32, 32), (2, 64, 128, 64),
])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_lru_scan_shapes(b, t, d, chunk, dtype):
    rng = np.random.default_rng(b * t)
    a = rng.uniform(0.3, 0.999, (b, t, d)).astype(dtype)
    x = rng.standard_normal((b, t, d)).astype(dtype)
    out = ops.lru_scan(jnp.asarray(a), jnp.asarray(x), chunk=chunk,
                       interpret=True)
    exp = ref.lru_scan_ref(jnp.asarray(a), jnp.asarray(x))
    np.testing.assert_allclose(out, exp, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("bh,t,k,v,chunk", [
    (2, 16, 8, 8, 8), (4, 32, 16, 32, 16), (1, 64, 64, 64, 16),
])
def test_wkv6_shapes(bh, t, k, v, chunk):
    rng = np.random.default_rng(bh + t)
    r = rng.standard_normal((bh, t, k)).astype(np.float32)
    kk = rng.standard_normal((bh, t, k)).astype(np.float32)
    w = rng.uniform(0.5, 0.999, (bh, t, k)).astype(np.float32)
    vv = rng.standard_normal((bh, t, v)).astype(np.float32)
    u = rng.standard_normal((bh, k)).astype(np.float32)
    args = tuple(map(jnp.asarray, (r, kk, w, vv, u)))
    out = ops.wkv6(*args, chunk=chunk, interpret=True)
    exp = ref.wkv6_ref(*args)
    np.testing.assert_allclose(out, exp, rtol=1e-3, atol=1e-3)


def test_mttkrp_kernel_matches_model_chunking():
    """Kernel path through the full executor (integration-level)."""
    from repro.core import MTTKRPExecutor, build_flycoo, init_factors, \
        mttkrp_ref
    rng = np.random.default_rng(0)
    dims = (33, 21, 17)
    idx = np.unique(np.stack([rng.integers(0, d, 700) for d in dims], 1)
                    .astype(np.int32), axis=0)
    val = rng.standard_normal(idx.shape[0]).astype(np.float32)
    t = build_flycoo(idx, val, dims, rows_pp=8, block_p=16)
    factors = init_factors(jax.random.PRNGKey(0), dims, 8)
    outs = MTTKRPExecutor(t, backend="pallas", interpret=True).all_modes(
        factors)
    for d in range(3):
        expd = mttkrp_ref(jnp.asarray(idx), jnp.asarray(val), factors, d,
                          dims[d])
        np.testing.assert_allclose(outs[d], expd, rtol=1e-4, atol=1e-4)


def test_wkv6_kernel_matches_model_timemix():
    """Pallas wkv6 == the model's chunked time_mix core recurrence."""
    from repro.models.rwkv import time_mix, init_rwkv_block
    from repro import configs
    # equivalence is exercised indirectly: both against the scan oracle
    rng = np.random.default_rng(1)
    bh, t, k = 3, 32, 8
    r = rng.standard_normal((bh, t, k)).astype(np.float32)
    kk = rng.standard_normal((bh, t, k)).astype(np.float32)
    w = rng.uniform(0.8, 0.999, (bh, t, k)).astype(np.float32)
    vv = rng.standard_normal((bh, t, k)).astype(np.float32)
    u = rng.standard_normal((bh, k)).astype(np.float32)
    args = tuple(map(jnp.asarray, (r, kk, w, vv, u)))
    out = ops.wkv6(*args, chunk=8, interpret=True)
    exp = ref.wkv6_ref(*args)
    np.testing.assert_allclose(out, exp, rtol=1e-3, atol=1e-3)
