"""Functional engine API: pytree EngineState + ExecutionConfig + scan.

Covers the acceptance criteria of the engine redesign:
  * ``engine.all_modes`` is ONE jitted ``lax.scan`` program (trace count
    stays 1 across calls; jaxpr contains a scan; dispatch count is 1 per
    full rotation instead of nmodes);
  * ``EngineState`` round-trips through ``jax.tree_util.tree_flatten``;
  * xla vs pallas-interpret parity for nmodes 3..6 (the paper's >4-mode
    claim previously had no test above 4 modes);
  * the deprecated ``MTTKRPExecutor`` shim matches ``mttkrp_ref`` on all
    modes for nmodes 3..6, works from any start mode, and ``reset()``
    restores mode 0 (regression for the removed mode-0 assertion).
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.core import (MTTKRPExecutor, build_flycoo, cp_als,
                        cp_als_reference, init_factors, mttkrp_ref)
from repro.engine import EngineState, ExecutionConfig

DIMS_BY_NMODES = {
    3: (23, 17, 11),
    4: (13, 11, 7, 9),
    5: (9, 8, 7, 6, 5),
    6: (7, 6, 5, 4, 3, 8),
}


def _tensor(seed, dims, nnz, **kw):
    rng = np.random.default_rng(seed)
    idx = np.unique(np.stack([rng.integers(0, d, nnz) for d in dims], 1)
                    .astype(np.int32), axis=0)
    val = rng.standard_normal(idx.shape[0]).astype(np.float32)
    return idx, val, build_flycoo(idx, val, dims, **kw)


def _refs(idx, val, factors, dims):
    return [mttkrp_ref(jnp.asarray(idx), jnp.asarray(val), factors, d,
                       dims[d]) for d in range(len(dims))]


# --------------------------------------------------------------------------
# Backend parity across mode counts (incl. the paper's >4-mode claim).
# --------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["xla", "pallas", "pallas_fused", "ref"])
@pytest.mark.parametrize("nmodes", [3, 4, 5, 6])
def test_all_modes_backend_parity(backend, nmodes):
    dims = DIMS_BY_NMODES[nmodes]
    idx, val, t = _tensor(nmodes, dims, 700, rows_pp=4, block_p=8)
    factors = tuple(init_factors(jax.random.PRNGKey(1), dims, 8))
    state = engine.init(t, ExecutionConfig(backend=backend, interpret=True))
    refs = _refs(idx, val, factors, dims)
    for _ in range(2):  # second sweep exercises remapped layouts
        outs, state = engine.all_modes(state, factors)
        for d in range(nmodes):
            np.testing.assert_allclose(outs[d], refs[d], rtol=2e-4,
                                       atol=2e-4)


@pytest.mark.parametrize("nmodes", [3, 4, 5, 6])
def test_pallas_fused_any_start_and_step(nmodes):
    """The fused EC+remap pipeline works from any resident mode, both as
    the scanned rotation and stepped one dispatch at a time."""
    dims = DIMS_BY_NMODES[nmodes]
    idx, val, t = _tensor(nmodes + 20, dims, 600, rows_pp=4, block_p=8)
    factors = tuple(init_factors(jax.random.PRNGKey(5), dims, 8))
    refs = _refs(idx, val, factors, dims)
    cfg = ExecutionConfig(backend="pallas_fused", interpret=True)
    for start in (0, nmodes - 1):
        state = engine.init(t, cfg, start_mode=start)
        outs, state = engine.all_modes(state, factors)
        assert state.mode == start
        for d in range(nmodes):
            np.testing.assert_allclose(outs[d], refs[d], rtol=2e-4,
                                       atol=2e-4)
    state = engine.init(t, cfg, start_mode=1)
    for i in range(nmodes):
        out, state = engine.mttkrp(state, factors)
        np.testing.assert_allclose(out, refs[(1 + i) % nmodes], rtol=2e-4,
                                   atol=2e-4)


@pytest.mark.parametrize("backend", ["xla", "pallas", "pallas_fused", "ref"])
def test_pad_slots_cannot_pollute_row_zero(backend):
    """Pad slots (lrow == -1) are dumped into segment 0 by the XLA
    segment-sum paths and carry in-bounds idx = 0 — so their contribution
    must be masked structurally, not by relying on pad val == 0. Forcing
    every pad val to a nonzero value must leave ALL outputs (in particular
    the user row that relabels to row 0) bit-identical to the oracle."""
    dims = DIMS_BY_NMODES[4]
    idx, val, t = _tensor(8, dims, 500, rows_pp=4, block_p=8)
    factors = tuple(init_factors(jax.random.PRNGKey(7), dims, 8))
    refs = _refs(idx, val, factors, dims)
    state = engine.init(t, ExecutionConfig(backend=backend, interpret=True))
    poisoned = state.replace(
        val=jnp.where(state.alpha[:, state.mode] < 0, 7.25, state.val))
    outs, _ = engine.all_modes(poisoned, factors)
    for d in range(4):
        np.testing.assert_allclose(outs[d], refs[d], rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("nmodes", [3, 4, 5, 6])
def test_single_mode_step_and_any_start(nmodes):
    """Stepping through modes one dispatch at a time matches the oracle,
    and a rotation may start anywhere (no mode-0 restriction)."""
    dims = DIMS_BY_NMODES[nmodes]
    idx, val, t = _tensor(nmodes + 10, dims, 500, rows_pp=4, block_p=8)
    factors = tuple(init_factors(jax.random.PRNGKey(2), dims, 4))
    refs = _refs(idx, val, factors, dims)

    state = engine.init(t)
    for d in range(nmodes):
        out, state = engine.mttkrp(state, factors)
        np.testing.assert_allclose(out, refs[d], rtol=2e-4, atol=2e-4)
    assert state.mode == 0

    start = nmodes - 1
    state = engine.init(t, start_mode=start)
    outs, state = engine.all_modes(state, factors)
    assert state.mode == start
    for d in range(nmodes):
        np.testing.assert_allclose(outs[d], refs[d], rtol=2e-4, atol=2e-4)


def test_mttkrp_rejects_nonresident_mode():
    dims = DIMS_BY_NMODES[3]
    _, _, t = _tensor(0, dims, 300, rows_pp=4, block_p=8)
    factors = tuple(init_factors(jax.random.PRNGKey(0), dims, 4))
    state = engine.init(t)
    with pytest.raises(ValueError, match="mode-0 layout"):
        engine.mttkrp(state, factors, mode=2)


# --------------------------------------------------------------------------
# Scan program: one trace, one dispatch per rotation, scan in the jaxpr.
# --------------------------------------------------------------------------
def test_all_modes_is_single_scanned_dispatch():
    dims = DIMS_BY_NMODES[4]
    idx, val, t = _tensor(1, dims, 600, rows_pp=4, block_p=8)
    factors = tuple(init_factors(jax.random.PRNGKey(3), dims, 8))
    state = engine.init(t)

    engine.reset_counters()
    for _ in range(3):
        outs, state = engine.all_modes(state, factors)
    # one traced program, reused; one dispatch per full rotation — the
    # old executor issued nmodes dispatches per rotation.
    assert engine.TRACE_COUNTS["all_modes"] == 1
    assert engine.DISPATCH_COUNTS["all_modes"] == 3

    jaxpr = str(engine.scan_jaxpr(state, factors))
    assert "scan" in jaxpr, "all_modes must lower to a lax.scan program"


# --------------------------------------------------------------------------
# Zero-HBM-intermediate acceptance: the fused scan step materializes no
# (S_d, N-1, R) gathered buffer (the unfused pallas backend does).
# --------------------------------------------------------------------------
def _scan_hlo(t, backend, factors):
    from repro.engine.api import _build_scan

    state = engine.init(t, ExecutionConfig(backend=backend, interpret=True,
                                           donate=False))
    fn = _build_scan(state, None)
    return state, jax.jit(fn).lower(
        (state.val, state.idx, state.alpha), state.relabel, state.sched,
        tuple(factors), None).as_text()


def test_fused_scan_has_no_gathered_intermediate():
    dims = DIMS_BY_NMODES[4]
    rank = 8
    _, _, t = _tensor(6, dims, 600, rows_pp=4, block_p=8)
    factors = tuple(init_factors(jax.random.PRNGKey(9), dims, rank))
    nm1 = len(dims) - 1

    state, fused_txt = _scan_hlo(t, "pallas_fused", factors)
    gathered_types = [f"tensor<{s.padded_nnz}x{nm1}x{rank}xf32>"
                      for s in state.statics]
    for ty in gathered_types:
        assert ty not in fused_txt, \
            f"pallas_fused scan step materializes a gathered buffer {ty}"

    # ... while the unfused pallas baseline does stage it through HBM.
    _, base_txt = _scan_hlo(t, "pallas", factors)
    assert any(ty in base_txt for ty in gathered_types), \
        "baseline should show the (S, N-1, R) gathered intermediate"


def test_fuse_remap_knob_and_vmem_budget():
    """fuse_remap=False forces the XLA scatter path (bit-parity with the
    fused one); vmem_budget_bytes sizes the vmem-policy row tiles."""
    dims = DIMS_BY_NMODES[3]
    idx, val, t = _tensor(12, dims, 400, rows_pp=4, block_p=8)
    factors = tuple(init_factors(jax.random.PRNGKey(3), dims, 8))
    outs_f, _ = engine.all_modes(
        engine.init(t, ExecutionConfig(backend="pallas_fused",
                                       interpret=True)), factors)
    outs_u, _ = engine.all_modes(
        engine.init(t, ExecutionConfig(backend="pallas_fused",
                                       interpret=True, fuse_remap=False)),
        factors)
    for a, b in zip(outs_f, outs_u):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)

    # VMEM budget -> rows_pp -> kappa: 64 KiB at R=32 (4 B) halves to 256
    # rows; explicit rows_pp still wins; no budget = library default.
    budget = ExecutionConfig(vmem_budget_bytes=64 * 1024)
    assert budget.resolve_rows_pp() == 256
    assert budget.kappa_for(1000) == 4  # ceil(1000 / 256)
    assert ExecutionConfig(vmem_budget_bytes=64 * 1024,
                           rows_pp=100).resolve_rows_pp() == 100
    assert ExecutionConfig().resolve_rows_pp() is None
    with pytest.raises(ValueError, match="vmem_budget_bytes"):
        ExecutionConfig(vmem_budget_bytes=0)


# --------------------------------------------------------------------------
# Compact block schedule: Zipf parity, bitwise vs rect, padded-slot wins.
# --------------------------------------------------------------------------
def _zipf_tensor(seed, dims, nnz, schedule, a=1.5, **kw):
    from repro.core import datasets

    ts = datasets.TensorSpec(name="zipf", dims=dims, nnz=nnz, zipf_a=a)
    idx, val = datasets.synthesize(ts, seed=seed)
    return idx, val, build_flycoo(idx, val, dims, schedule=schedule, **kw)


@pytest.mark.parametrize("backend", ["xla", "pallas", "pallas_fused", "ref"])
@pytest.mark.parametrize("nmodes", [3, 4, 5, 6])
def test_compact_schedule_zipf_parity(backend, nmodes):
    """Acceptance: on skewed (Zipf) tensors the compact schedule matches
    the COO oracle for every backend across nmodes 3-6, any start mode,
    inside the scanned rotation — and is BITWISE identical to the rect
    baseline (same partitions, same per-partition element order; the pad
    blocks it drops contribute exact zeros)."""
    dims = DIMS_BY_NMODES[nmodes]
    idx, val, t = _zipf_tensor(nmodes, dims, 900, "compact", rows_pp=4,
                               block_p=8)
    _, _, t_rect = _zipf_tensor(nmodes, dims, 900, "rect", rows_pp=4,
                                block_p=8)
    assert sum(p.padded_nnz for p in t.plans) <= \
        sum(p.padded_nnz for p in t_rect.plans)
    factors = tuple(init_factors(jax.random.PRNGKey(2), dims, 8))
    refs = _refs(idx, val, factors, dims)
    start = nmodes - 1
    cfg = ExecutionConfig(backend=backend, interpret=True)
    state = engine.init(t, cfg, start_mode=start)
    state_r = engine.init(t_rect, cfg, start_mode=start)
    for _ in range(2):  # second sweep exercises remapped compact layouts
        outs, state = engine.all_modes(state, factors)
        outs_r, state_r = engine.all_modes(state_r, factors)
        for d in range(nmodes):
            np.testing.assert_allclose(outs[d], refs[d], rtol=2e-4,
                                       atol=2e-4)
            np.testing.assert_array_equal(np.asarray(outs[d]),
                                          np.asarray(outs_r[d]))


def test_compact_reduces_padded_slots_on_skew():
    """On a skewed tensor the compact layout drops most pad blocks; the
    engine's uniform carrier S_max shrinks with it."""
    dims = (96, 64, 48)
    _, _, t = _zipf_tensor(7, dims, 2500, "compact", rows_pp=8, block_p=8)
    _, _, t_rect = _zipf_tensor(7, dims, 2500, "rect", rows_pp=8, block_p=8)
    compact_s = sum(p.padded_nnz for p in t.plans)
    rect_s = sum(p.padded_nnz for p in t_rect.plans)
    assert compact_s * 2 <= rect_s, (compact_s, rect_s)
    assert engine.init(t).smax < engine.init(t_rect).smax


def test_schedule_knob_plumbs_from_raw_coo():
    """ExecutionConfig.schedule governs plans built from raw COO input."""
    dims = (19, 13, 7)
    rng = np.random.default_rng(5)
    idx = np.unique(np.stack([rng.integers(0, d, 300) for d in dims], 1)
                    .astype(np.int32), axis=0)
    val = rng.standard_normal(idx.shape[0]).astype(np.float32)
    for sched in ("compact", "rect"):
        state = engine.init((idx, val, dims),
                            ExecutionConfig(schedule=sched, block_p=8))
        assert all(s.schedule == sched for s in state.statics)
    with pytest.raises(ValueError, match="schedule"):
        ExecutionConfig(schedule="bogus")


# --------------------------------------------------------------------------
# Pytree contract.
# --------------------------------------------------------------------------
def test_engine_state_pytree_roundtrip():
    dims = DIMS_BY_NMODES[4]
    idx, val, t = _tensor(2, dims, 400, rows_pp=4, block_p=8)
    state = engine.init(t, ExecutionConfig(backend="xla"))

    leaves, treedef = jax.tree_util.tree_flatten(state)
    assert all(isinstance(x, jax.Array) for x in leaves)
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(rebuilt, EngineState)
    assert rebuilt.aux_key() == state.aux_key()
    for a, b in zip(leaves, jax.tree_util.tree_leaves(rebuilt)):
        np.testing.assert_array_equal(a, b)

    # states pass transparently through jax transformations
    doubled = jax.tree_util.tree_map(lambda x: x * 2, state)
    np.testing.assert_allclose(doubled.val, state.val * 2)
    assert doubled.statics == state.statics


def test_execution_config_static_and_validated():
    assert hash(ExecutionConfig()) == hash(ExecutionConfig())
    assert ExecutionConfig(backend="pallas") != ExecutionConfig()
    with pytest.raises(ValueError, match="kappa_policy"):
        ExecutionConfig(kappa_policy="bogus")
    with pytest.raises(ValueError, match="requires kappa"):
        ExecutionConfig(kappa_policy="fixed")
    with pytest.raises(KeyError, match="unknown engine backend"):
        engine.get_backend("cuda")


def test_kappa_for_rounds_to_device_multiples():
    """One kappa policy for single- and multi-device plans: divisible by
    n_dev, never exceeding the row count, honoring fixed/vmem policies."""
    cfg = ExecutionConfig(rows_pp=8)
    from repro.core.partition import choose_kappa
    assert cfg.kappa_for(40) == choose_kappa(40, 8)
    for dim in (40, 30, 20, 9):
        for n_dev in (2, 4):
            k = cfg.kappa_for(dim, n_dev)
            assert k % n_dev == 0
            assert n_dev <= k <= dim
    # fixed policy: round the explicit kappa up to the device multiple
    fixed = ExecutionConfig(kappa_policy="fixed", kappa=3)
    assert fixed.kappa_for(100) == 3
    assert fixed.kappa_for(100, 4) == 4
    assert fixed.kappa_for(100, 2) == 4
    with pytest.raises(ValueError, match="fewer rows than devices"):
        ExecutionConfig().kappa_for(3, 4)


def test_init_from_raw_coo_uses_config_policy():
    dims = (19, 13, 7)
    rng = np.random.default_rng(5)
    idx = np.unique(np.stack([rng.integers(0, d, 300) for d in dims], 1)
                    .astype(np.int32), axis=0)
    val = rng.standard_normal(idx.shape[0]).astype(np.float32)
    cfg = ExecutionConfig(kappa_policy="fixed", kappa=2, block_p=8)
    state = engine.init((idx, val, dims), cfg)
    assert all(s.kappa == 2 for s in state.statics)
    factors = tuple(init_factors(jax.random.PRNGKey(0), dims, 4))
    outs, _ = engine.all_modes(state, factors)
    for d in range(3):
        ref = mttkrp_ref(jnp.asarray(idx), jnp.asarray(val), factors, d,
                         dims[d])
        np.testing.assert_allclose(outs[d], ref, rtol=2e-4, atol=2e-4)


def test_backend_registry_is_extensible():
    name = "_test_zeros"
    try:
        @engine.register_backend(name)
        def _zeros(layout, factors, mode, *, plan, config):
            r = factors[0].shape[1]
            return jnp.zeros((plan.relabeled_rows, r), jnp.float32)

        dims = DIMS_BY_NMODES[3]
        _, _, t = _tensor(4, dims, 200, rows_pp=4, block_p=8)
        factors = tuple(init_factors(jax.random.PRNGKey(0), dims, 4))
        state = engine.init(t, ExecutionConfig(backend=name))
        outs, _ = engine.all_modes(state, factors)
        for o in outs:
            np.testing.assert_array_equal(np.asarray(o), 0.0)
    finally:
        engine.BACKENDS.pop(name, None)


# --------------------------------------------------------------------------
# Deprecated shim: oracle parity 3..6 modes, partial rotation + reset.
# --------------------------------------------------------------------------
@pytest.mark.parametrize("nmodes", [3, 4, 5, 6])
def test_deprecated_shim_matches_oracle(nmodes):
    dims = DIMS_BY_NMODES[nmodes]
    idx, val, t = _tensor(nmodes, dims, 700, rows_pp=4, block_p=8)
    factors = init_factors(jax.random.PRNGKey(1), dims, 8)
    with pytest.deprecated_call():
        exe = MTTKRPExecutor(t)
    outs = exe.all_modes(factors)
    refs = _refs(idx, val, factors, dims)
    for d in range(nmodes):
        np.testing.assert_allclose(outs[d], refs[d], rtol=2e-4, atol=2e-4)


def test_shim_partial_rotation_reset_regression():
    """Step a partial rotation, reset, and match the oracle — the old
    executor hard-asserted ``current_mode == 0`` in all_modes."""
    dims = DIMS_BY_NMODES[4]
    idx, val, t = _tensor(9, dims, 600, rows_pp=4, block_p=8)
    factors = init_factors(jax.random.PRNGKey(4), dims, 8)
    refs = _refs(idx, val, factors, dims)

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        exe = MTTKRPExecutor(t)
    np.testing.assert_allclose(exe.step(factors), refs[0], rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(exe.step(factors), refs[1], rtol=2e-4,
                               atol=2e-4)
    assert exe.current_mode == 2

    outs = exe.all_modes(factors)  # mid-rotation: previously an assert
    for d in range(4):
        np.testing.assert_allclose(outs[d], refs[d], rtol=2e-4, atol=2e-4)
    assert exe.current_mode == 2

    exe.reset()
    assert exe.current_mode == 0
    np.testing.assert_allclose(exe.step(factors), refs[0], rtol=2e-4,
                               atol=2e-4)


# --------------------------------------------------------------------------
# CPD on the scanned engine.
# --------------------------------------------------------------------------
def test_cp_als_with_config_matches_reference():
    dims = (24, 18, 12)
    idx, val, t = _tensor(11, dims, 800, rows_pp=8, block_p=16)
    res = cp_als(t, rank=6, iters=4,
                 config=ExecutionConfig(backend="xla"))
    ref = cp_als_reference(idx, val, dims, 6, iters=4)
    assert res.fits == pytest.approx(ref.fits, abs=2e-3)
    with pytest.raises(ValueError, match="not both"):
        cp_als(t, rank=4, iters=1, config=ExecutionConfig(),
               backend="pallas")
