"""spMTTKRP engine vs. the COO oracle (both backends, all modes)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # container lacks hypothesis: skip only these
    from conftest import given, settings, st

from repro.core import (MTTKRPExecutor, build_flycoo, cp_als,
                        cp_als_reference, init_factors, mttkrp_ref)


def _tensor(seed, dims, nnz, **kw):
    rng = np.random.default_rng(seed)
    idx = np.unique(np.stack([rng.integers(0, d, nnz) for d in dims], 1)
                    .astype(np.int32), axis=0)
    val = rng.standard_normal(idx.shape[0]).astype(np.float32)
    return idx, val, build_flycoo(idx, val, dims, **kw)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("dims", [(40, 30, 20), (25, 17, 9, 13)])
def test_all_modes_match_oracle(backend, dims):
    idx, val, t = _tensor(0, dims, 1200, rows_pp=8, block_p=16)
    factors = init_factors(jax.random.PRNGKey(1), dims, 16)
    exe = MTTKRPExecutor(t, backend=backend, interpret=True)
    for sweep in range(2):  # second sweep exercises remapped layouts
        outs = exe.all_modes(factors)
        for d in range(len(dims)):
            ref = mttkrp_ref(jnp.asarray(idx), jnp.asarray(val), factors,
                             d, dims[d])
            np.testing.assert_allclose(outs[d], ref, rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 99),
       d0=st.integers(5, 40), d1=st.integers(5, 40), d2=st.integers(5, 40),
       rank=st.sampled_from([2, 8, 16]))
def test_mttkrp_property_random(seed, d0, d1, d2, rank):
    dims = (d0, d1, d2)
    idx, val, t = _tensor(seed, dims, 400, rows_pp=4, block_p=8)
    factors = init_factors(jax.random.PRNGKey(seed), dims, rank)
    exe = MTTKRPExecutor(t, backend="xla")
    outs = exe.all_modes(factors)
    for d in range(3):
        ref = mttkrp_ref(jnp.asarray(idx), jnp.asarray(val), factors, d,
                         dims[d])
        np.testing.assert_allclose(outs[d], ref, rtol=2e-4, atol=2e-4)


def test_mttkrp_linearity():
    """MTTKRP is linear in the tensor values."""
    dims = (30, 20, 10)
    idx, val, t1 = _tensor(3, dims, 500, rows_pp=8, block_p=16)
    t2 = build_flycoo(idx, 2.0 * val, dims, rows_pp=8, block_p=16)
    factors = init_factors(jax.random.PRNGKey(0), dims, 8)
    o1 = MTTKRPExecutor(t1).all_modes(factors)
    o2 = MTTKRPExecutor(t2).all_modes(factors)
    for a, b in zip(o1, o2):
        np.testing.assert_allclose(2.0 * a, b, rtol=1e-4, atol=1e-5)


def test_cpd_fit_monotone_and_matches_reference():
    dims = (30, 25, 20)
    idx, val, t = _tensor(7, dims, 900, rows_pp=8, block_p=16)
    res = cp_als(t, rank=8, iters=6)
    ref = cp_als_reference(idx, val, dims, 8, iters=6)
    assert res.fits == pytest.approx(ref.fits, abs=2e-3)
    # ALS is monotone in fit (up to fp noise)
    assert all(b >= a - 1e-3 for a, b in zip(res.fits, res.fits[1:]))


def test_cpd_recovers_low_rank_tensor():
    """CPD on an exactly rank-2 sparse-sampled tensor reaches high fit."""
    rng = np.random.default_rng(0)
    dims, rank = (20, 15, 10), 2
    a = rng.standard_normal((dims[0], rank))
    b = rng.standard_normal((dims[1], rank))
    c = rng.standard_normal((dims[2], rank))
    full = np.einsum("ir,jr,kr->ijk", a, b, c)
    # sparse-CPD semantics: COO entries ARE the tensor; plant it fully
    # observed so exact rank-2 recovery is well-posed
    idx = np.argwhere(np.ones(dims, bool)).astype(np.int32)
    val = full.reshape(-1).astype(np.float32)
    t = build_flycoo(idx, val, dims, rows_pp=4, block_p=8)
    res = cp_als(t, rank=4, iters=25, key=jax.random.PRNGKey(3))
    assert res.fits[-1] > 0.95
