"""Multi-device tests (subprocess with 8 fake CPU devices).

The test process keeps 1 device (conftest); anything needing a mesh runs in
a fresh interpreter with XLA_FLAGS set before jax import.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(body: str, devices: int = 8, timeout: int = 900) -> str:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count={devices}"
        import jax
        import jax.numpy as jnp
        import numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"stdout:{out.stdout}\nstderr:{out.stderr}"
    return out.stdout


def test_distributed_mttkrp_matches_oracle():
    """Deprecated shim: oracle parity on a (data=4, model=2) mesh, plus the
    regressions of the shim rework — ``all_modes`` from a mid-rotation
    mode (the old class hard-asserted ``current_mode == 0``) and
    ``reset()`` for parity with the ``MTTKRPExecutor`` shim."""
    out = run_sub("""
        import warnings
        from repro.core.distributed import (DistributedMTTKRP,
                                            build_sharded_flycoo)
        from repro.core import init_factors, mttkrp_ref
        from repro.launch.mesh import make_mesh

        rng = np.random.default_rng(0)
        dims = (40, 30, 20)
        idx = np.unique(np.stack(
            [rng.integers(0, d, 1500) for d in dims], 1).astype(np.int32),
            axis=0)
        val = rng.standard_normal(idx.shape[0]).astype(np.float32)
        mesh = make_mesh((4, 2), ("data", "model"))
        t = build_sharded_flycoo(idx, val, dims, n_dev=4, rows_pp=8,
                                 block_p=8)
        factors = init_factors(jax.random.PRNGKey(1), dims, 8)
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            try:
                DistributedMTTKRP(t, mesh)
            except DeprecationWarning:
                pass
            else:
                raise AssertionError("shim must warn DeprecationWarning")
        exe = DistributedMTTKRP(t, mesh, model_axis="model")
        refs = [mttkrp_ref(jnp.asarray(idx), jnp.asarray(val), factors, d,
                           dims[d]) for d in range(3)]
        for sweep in range(2):
            outs = exe.all_modes(factors)
            for d in range(3):
                np.testing.assert_allclose(np.asarray(outs[d]), refs[d],
                                           rtol=2e-4, atol=2e-4)
        # step to mode 1, run all_modes mid-rotation (was an assert), reset
        np.testing.assert_allclose(np.asarray(exe.step(factors)), refs[0],
                                   rtol=2e-4, atol=2e-4)
        assert exe.current_mode == 1
        outs = exe.all_modes(factors)
        assert exe.current_mode == 1
        for d in range(3):
            np.testing.assert_allclose(np.asarray(outs[d]), refs[d],
                                       rtol=2e-4, atol=2e-4)
        exe.reset()
        assert exe.current_mode == 0
        np.testing.assert_allclose(np.asarray(exe.step(factors)), refs[0],
                                   rtol=2e-4, atol=2e-4)
        print("DIST_MTTKRP_OK")
    """)
    assert "DIST_MTTKRP_OK" in out


def test_engine_dist_matches_single_device():
    """engine.dist parity: nmodes 3-5 on 2 and 4 fake devices, against both
    the single-device engine and the COO oracle, across two sweeps."""
    out = run_sub("""
        from repro import engine
        from repro.core import init_factors, mttkrp_ref
        from repro.core.distributed import build_sharded_flycoo
        from repro.launch.mesh import make_mesh

        rng = np.random.default_rng(0)
        for nmodes, dims in ((3, (24, 18, 12)), (4, (12, 10, 8, 6)),
                             (5, (9, 8, 7, 6, 5))):
            idx = np.unique(np.stack(
                [rng.integers(0, d, 700) for d in dims], 1).astype(np.int32),
                axis=0)
            val = rng.standard_normal(idx.shape[0]).astype(np.float32)
            factors = tuple(init_factors(jax.random.PRNGKey(1), dims, 8))
            t = build_sharded_flycoo(idx, val, dims, n_dev=4, rows_pp=4,
                                     block_p=8)
            state = engine.init(t)
            outs_1d, _ = engine.all_modes(state, factors)
            refs = [mttkrp_ref(jnp.asarray(idx), jnp.asarray(val), factors,
                               d, dims[d]) for d in range(nmodes)]
            for n_dev in (2, 4):
                mesh = make_mesh((n_dev,), ("data",))
                ds = engine.dist.shard_state(state, mesh)
                for sweep in range(2):
                    outs, ds = engine.dist.dist_all_modes(ds, factors)
                    for d in range(nmodes):
                        np.testing.assert_allclose(
                            np.asarray(outs[d]), np.asarray(outs_1d[d]),
                            rtol=1e-5, atol=1e-5)
                        np.testing.assert_allclose(
                            np.asarray(outs[d]), refs[d], rtol=2e-4,
                            atol=2e-4)
                # single-mode stepping matches too
                out, ds = engine.dist.dist_mttkrp(ds, factors)
                np.testing.assert_allclose(np.asarray(out), refs[0],
                                           rtol=2e-4, atol=2e-4)
                assert ds.mode == 1
        print("ENGINE_DIST_OK")
    """)
    assert "ENGINE_DIST_OK" in out


def test_engine_dist_pallas_fused_backend_parity():
    """The sharded path drives fusing backends through the SAME plain-EC
    contract as every other backend (the remap stays the cross-device
    exchange): pallas_fused under dist_all_modes matches the oracle."""
    out = run_sub("""
        from repro import engine
        from repro.core import init_factors, mttkrp_ref
        from repro.core.distributed import build_sharded_flycoo
        from repro.launch.mesh import make_mesh

        rng = np.random.default_rng(2)
        dims = (24, 18, 12)
        idx = np.unique(np.stack(
            [rng.integers(0, d, 700) for d in dims], 1).astype(np.int32),
            axis=0)
        val = rng.standard_normal(idx.shape[0]).astype(np.float32)
        factors = tuple(init_factors(jax.random.PRNGKey(1), dims, 8))
        t = build_sharded_flycoo(idx, val, dims, n_dev=4, rows_pp=4,
                                 block_p=8)
        refs = [mttkrp_ref(jnp.asarray(idx), jnp.asarray(val), factors, d,
                           dims[d]) for d in range(3)]
        cfg = engine.ExecutionConfig(backend="pallas_fused", interpret=True)
        state = engine.init(t, cfg)
        mesh = make_mesh((4,), ("data",))
        ds = engine.dist.shard_state(state, mesh)
        for sweep in range(2):
            outs, ds = engine.dist.dist_all_modes(ds, factors)
            for d in range(3):
                np.testing.assert_allclose(np.asarray(outs[d]), refs[d],
                                           rtol=2e-4, atol=2e-4)
        print("DIST_FUSED_OK")
    """, devices=4)
    assert "DIST_FUSED_OK" in out


def test_permute_schedule_matches_all_gather_baseline():
    """The collective_permute schedule and the all_gather baseline must
    produce bitwise-identical next layouts and outputs, the scanned
    program must compile ONCE per config, and the lowered permute program
    must contain collective_permute with no element-list all_gather."""
    out = run_sub("""
        from repro import engine
        from repro.core import init_factors
        from repro.core.distributed import build_sharded_flycoo
        from repro.engine.dist import DistConfig, lowered_text
        from repro.launch.mesh import make_mesh

        rng = np.random.default_rng(3)
        dims = (24, 18, 12)
        idx = np.unique(np.stack(
            [rng.integers(0, d, 900) for d in dims], 1).astype(np.int32),
            axis=0)
        val = rng.standard_normal(idx.shape[0]).astype(np.float32)
        factors = tuple(init_factors(jax.random.PRNGKey(1), dims, 8))
        t = build_sharded_flycoo(idx, val, dims, n_dev=4, rows_pp=4,
                                 block_p=8)
        state = engine.init(t)
        mesh = make_mesh((4,), ("data",))

        # ---- bitwise: permute vs all_gather layouts + outputs ----
        ds_p = engine.dist.shard_state(state, mesh,
                                       DistConfig(exchange="permute"))
        ds_a = engine.dist.shard_state(state, mesh,
                                       DistConfig(exchange="all_gather"))
        np.testing.assert_array_equal(np.asarray(ds_p.alpha),
                                      np.asarray(ds_a.alpha))
        for sweep in range(2):
            outs_p, ds_p = engine.dist.dist_all_modes(ds_p, factors)
            outs_a, ds_a = engine.dist.dist_all_modes(ds_a, factors)
            for a, b in zip(outs_p, outs_a):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            np.testing.assert_array_equal(np.asarray(ds_p.val),
                                          np.asarray(ds_a.val))
            np.testing.assert_array_equal(np.asarray(ds_p.idx),
                                          np.asarray(ds_a.idx))
            np.testing.assert_array_equal(np.asarray(ds_p.alpha),
                                          np.asarray(ds_a.alpha))

        # ---- one compile per distributed sweep config ----
        engine.reset_counters()
        # distinct pad_hop -> distinct jit cache entry: counts start fresh
        ds = engine.dist.shard_state(state, mesh, DistConfig(pad_hop=16))
        for _ in range(3):
            outs, ds = engine.dist.dist_all_modes(ds, factors)
        assert engine.TRACE_COUNTS["dist_all_modes"] == 1, \
            dict(engine.TRACE_COUNTS)
        assert engine.DISPATCH_COUNTS["dist_all_modes"] == 3, \
            dict(engine.DISPATCH_COUNTS)

        # ---- lowering: collective_permute, no element-list all_gather ----
        ds = engine.dist.shard_state(state, mesh)
        txt = lowered_text(ds, factors)
        assert "collective_permute" in txt
        sloc = ds.smax_loc
        for line in txt.splitlines():
            if "all_gather" in line:   # only the rows-x-R output gather
                assert f"tensor<{sloc}x" not in line, line
        txt_a = lowered_text(engine.dist.shard_state(
            state, mesh, DistConfig(exchange="all_gather")), factors)
        assert "collective_permute" not in txt_a
        assert any(f"tensor<{sloc}x" in line
                   for line in txt_a.splitlines() if "all_gather" in line)
        print("EXCHANGE_OK")
    """)
    assert "EXCHANGE_OK" in out


def test_dist_cp_als_single_traced_sweeps():
    """cp_als(mesh=...) runs distributed ALS sweeps through the dist fold
    hook and matches the single-device result; the whole run compiles the
    distributed sweep exactly once."""
    out = run_sub("""
        from repro import engine
        from repro.core import cp_als
        from repro.core.distributed import build_sharded_flycoo
        from repro.launch.mesh import make_mesh

        rng = np.random.default_rng(7)
        dims = (24, 18, 12)
        idx = np.unique(np.stack(
            [rng.integers(0, d, 900) for d in dims], 1).astype(np.int32),
            axis=0)
        val = rng.standard_normal(idx.shape[0]).astype(np.float32)
        t = build_sharded_flycoo(idx, val, dims, n_dev=4, rows_pp=4,
                                 block_p=8)
        mesh = make_mesh((4,), ("data",))
        engine.reset_counters()
        res_d = cp_als(t, rank=6, iters=4, mesh=mesh)
        assert engine.TRACE_COUNTS["dist_all_modes"] == 1
        assert engine.DISPATCH_COUNTS["dist_all_modes"] == 4
        res_s = cp_als(t, rank=6, iters=4)
        np.testing.assert_allclose(res_d.fits, res_s.fits, atol=2e-3)
        for a, b in zip(res_d.factors, res_s.factors):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-3, atol=2e-3)
        print("DIST_CPD_OK")
    """)
    assert "DIST_CPD_OK" in out


def test_exchange_schedule_is_static_upper_bound():
    """Host-only (no mesh): the precomputed schedule's per-hop capacities
    bound the true cross-device move counts from the FLYCOO plans, are
    padded to the requested multiple, and feed the traffic model."""
    import numpy as np

    from repro.core.distributed import build_sharded_flycoo
    from repro.engine.dist import (element_devices, exchange_bytes,
                                   row_bytes, schedule_for_plans)

    rng = np.random.default_rng(2)
    dims = (40, 30, 20)
    idx = np.unique(np.stack(
        [rng.integers(0, d, 1200) for d in dims], 1).astype(np.int32),
        axis=0)
    val = rng.standard_normal(idx.shape[0]).astype(np.float32)
    n = len(dims)
    for schedule in ("compact", "rect"):
        t = build_sharded_flycoo(idx, val, dims, n_dev=4, rows_pp=8,
                                 block_p=8, schedule=schedule)
        for p in t.plans:
            assert p.kappa % 4 == 0
        for n_dev, pad in ((2, 8), (4, 4)):
            sched = schedule_for_plans(t.plans, n_dev, pad_hop=pad)
            assert sched.n_dev == n_dev
            assert len(sched.hops) == n
            for d in range(n):
                src = element_devices(t.plans[d], n_dev)
                dst = element_devices(t.plans[(d + 1) % n], n_dev)
                if schedule == "rect":
                    # rect: device ownership degenerates to the slot stride
                    np.testing.assert_array_equal(
                        src, t.plans[d].slot_of_elem
                        // (t.plans[d].padded_nnz // n_dev))
                assert len(sched.hops[d]) == n_dev - 1
                for h in range(1, n_dev):
                    cap = sched.hops[d][h - 1]
                    assert cap % pad == 0 or cap == 0
                    for k in range(n_dev):
                        moved = int(np.sum((src == k)
                                           & (dst == (k + h) % n_dev)))
                        assert moved <= cap, (d, h, k, moved, cap)
            slocs = [p.padded_nnz // n_dev for p in t.plans]
            rows = exchange_bytes(sched, n, slocs)
            for d, r in enumerate(rows):
                assert r["permute_bytes"] == \
                    sched.permute_slots(d) * row_bytes(n)
                # the baseline gathers each remote device's mode-d list
                assert r["all_gather_bytes"] == \
                    (n_dev - 1) * slocs[d] * row_bytes(n)
                # the whole point: the schedule ships (far) fewer bytes
                assert r["permute_bytes"] <= r["all_gather_bytes"]
        with pytest.raises(ValueError, match="not divisible"):
            schedule_for_plans(t.plans, 3)


def test_dist_compact_matches_rect_bitwise():
    """Device-major numbering over the compact layout: the distributed
    rotation on a skewed tensor is bitwise-identical to the rect-schedule
    baseline (and to the single-device compact engine), while using fewer
    local slots per device."""
    out = run_sub("""
        from repro import engine
        from repro.core import datasets, init_factors
        from repro.core.distributed import build_sharded_flycoo
        from repro.launch.mesh import make_mesh

        dims = (48, 36, 24)
        ts = datasets.TensorSpec(name="zipf", dims=dims, nnz=2500,
                                 zipf_a=1.5)
        idx, val = datasets.synthesize(ts, seed=3)
        factors = tuple(init_factors(jax.random.PRNGKey(1), dims, 8))
        mesh = make_mesh((4,), ("data",))
        states, douts, slocs = {}, {}, {}
        for schedule in ("compact", "rect"):
            t = build_sharded_flycoo(idx, val, dims, n_dev=4, rows_pp=4,
                                     block_p=8, schedule=schedule)
            state = engine.init(t)
            outs_1d, _ = engine.all_modes(state, factors)
            ds = engine.dist.shard_state(state, mesh)
            slocs[schedule] = ds.smax_loc
            acc = []
            for sweep in range(2):
                outs, ds = engine.dist.dist_all_modes(ds, factors)
                acc += [np.asarray(o) for o in outs]
            douts[schedule] = acc
            for d in range(3):  # dist == single-device, bitwise
                np.testing.assert_array_equal(acc[d],
                                              np.asarray(outs_1d[d]))
        for a, b in zip(douts["compact"], douts["rect"]):
            np.testing.assert_array_equal(a, b)
        assert slocs["compact"] < slocs["rect"], slocs
        print("DIST_COMPACT_OK", slocs)
    """, devices=4)
    assert "DIST_COMPACT_OK" in out


def test_sharded_train_step_matches_single_device():
    out = run_sub("""
        import dataclasses
        from repro import configs, sharding as shlib
        from repro.launch.mesh import make_mesh
        from repro.training import (OptimizerConfig, SyntheticLM,
                                    init_state, make_train_step)

        cfg = configs.smoke("tinyllama-1.1b")
        ocfg = OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=10)
        data = SyntheticLM(cfg, batch=4, seq=32, seed=0)
        batch = data.next()
        state = init_state(cfg, ocfg, jax.random.PRNGKey(0))
        # single device reference
        _, m_ref = jax.jit(make_train_step(cfg, ocfg))(
            jax.tree.map(jnp.copy, state), batch)
        # 2x4 mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        ctx = shlib.make_ctx(mesh)
        with shlib.use(ctx):
            _, m_sh = jax.jit(make_train_step(cfg, ocfg))(state, batch)
        a, b = float(m_ref["loss"]), float(m_sh["loss"])
        assert abs(a - b) < 3e-2, (a, b)
        print("SHARDED_TRAIN_OK", a, b)
    """)
    assert "SHARDED_TRAIN_OK" in out


def test_moe_expert_parallel_matches_local():
    out = run_sub("""
        from repro import configs, sharding as shlib
        from repro.launch.mesh import make_mesh
        from repro.models.moe import apply_moe, init_moe, _apply_local
        import dataclasses

        cfg = dataclasses.replace(configs.smoke("olmoe-1b-7b"),
                                  capacity_factor=8.0)
        params = init_moe(cfg, jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                              jnp.bfloat16)
        ref = _apply_local(params, x, cfg)
        mesh = make_mesh((2, 4), ("data", "model"))
        ctx = shlib.make_ctx(mesh)
        with shlib.use(ctx):
            out = jax.jit(lambda p, t: apply_moe(p, t, cfg))(params, x)
        err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                    - ref.astype(jnp.float32))))
        assert err < 2e-2, err
        print("MOE_EP_OK", err)
    """)
    assert "MOE_EP_OK" in out


def test_gradient_compression_error_feedback():
    out = run_sub("""
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.training.compression import compressed_grad_sync

        mesh = make_mesh((4,), ("pod",))
        g_global = jax.random.normal(jax.random.PRNGKey(0), (4, 64, 128))

        try:
            from jax import shard_map
            sm = partial(shard_map, mesh=mesh, in_specs=(P("pod"), P()),
                         out_specs=(P("pod"), P("pod")), check_vma=False)
        except ImportError:
            from jax.experimental.shard_map import shard_map
            sm = partial(shard_map, mesh=mesh, in_specs=(P("pod"), P()),
                         out_specs=(P("pod"), P("pod")), check_rep=False)

        def body(g_shard, key):
            g = {"w": g_shard[0]}
            synced, err = compressed_grad_sync(g, key, rank=16, axis_name="pod")
            return synced["w"][None], err["w"][None]

        synced, err = jax.jit(sm(body))(g_global, jax.random.PRNGKey(1))
        true_mean = jnp.mean(g_global, axis=0)
        # every pod agrees on the synced value
        assert float(jnp.max(jnp.abs(synced - synced[0][None]))) < 1e-5
        # rank-16 approx of a rank-128 mean won't be exact; error feedback
        # must store the residual g + e - approx
        resid = g_global[0] - synced[0]
        np.testing.assert_allclose(np.asarray(err[0]), np.asarray(resid),
                                   rtol=1e-4, atol=1e-4)
        print("COMPRESS_OK")
    """)
    assert "COMPRESS_OK" in out


def test_dryrun_entry_small_mesh():
    """dryrun lower path works end to end on a small mesh in-process."""
    out = run_sub("""
        import dataclasses
        from repro.launch.dryrun import lower_cell
        from repro.launch.mesh import make_mesh
        from repro.configs import smoke

        cfg = smoke("tinyllama-1.1b")
        mesh = make_mesh((2, 4), ("data", "model"))
        rec = lower_cell("tinyllama-1.1b", "train_4k", cfg=dataclasses.replace(
            cfg, remat="full"), mesh=mesh)
        assert rec["cost"]["flops_per_device"] > 0
        assert rec["collectives_per_device"]["total"] > 0
        print("DRYRUN_SMALL_OK")
    """)
    assert "DRYRUN_SMALL_OK" in out


def test_pipeline_parallel_matches_sequential():
    out = run_sub("""
        from repro.launch.mesh import make_mesh
        from repro.training.pipeline import pipeline_apply

        n_stages, d = 4, 16
        mesh = make_mesh((4,), ("pp",))
        key = jax.random.PRNGKey(0)
        ws = jax.random.normal(key, (n_stages, d, d)) * 0.3

        def stage_fn(w, h):
            return jnp.tanh(h @ w)

        x = jax.random.normal(jax.random.PRNGKey(1), (8, d))
        # sequential reference
        ref = x
        for s in range(n_stages):
            ref = stage_fn(ws[s], ref)
        y = jax.jit(lambda w, t: pipeline_apply(
            stage_fn, w, t, mesh=mesh, n_micro=4))(ws, x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
        print("PIPELINE_OK")
    """)
    assert "PIPELINE_OK" in out


def test_dist_exchange_rung_bitwise():
    """Injected collective_permute failure steps the exchange rung
    ``permute -> all_gather`` mid-run; final factors stay bitwise-equal
    to an undisturbed permute run (the PR-3 exchange parity, now a
    resilience guarantee)."""
    out = run_sub("""
        from repro import engine, obs
        from repro.core import cp_als
        from repro.core.distributed import build_sharded_flycoo
        from repro.launch.mesh import make_mesh
        from repro.resilience import ChaosSpec, LadderPolicy, install

        rng = np.random.default_rng(0)
        dims = (24, 18, 12)
        idx = np.unique(np.stack(
            [rng.integers(0, d, 600) for d in dims], 1).astype(np.int32),
            axis=0)
        val = rng.standard_normal(idx.shape[0]).astype(np.float32)
        t = build_sharded_flycoo(idx, val, dims, n_dev=4, rows_pp=4,
                                 block_p=8)
        mesh = make_mesh((4,), ("data",))
        clean = cp_als(t, rank=4, iters=4, mesh=mesh)

        install(ChaosSpec(exchange_fail=1))   # 2nd permute dispatch dies
        pol = LadderPolicy(backoff_base_s=1e-4, backoff_cap_s=1e-3)
        res = cp_als(t, rank=4, iters=4, mesh=mesh, ladder=pol)
        for a, b in zip(clean.factors, res.factors):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert clean.fits == res.fits
        degr = obs.REGISTRY.metrics()[
            "resilience_degradations"].as_dict()
        assert degr.get("exchange:permute->all_gather", 0) == 1, degr
        rep = obs.resilience_report()
        assert "exchange_fail" in rep["answered"]
        assert rep["unanswered"] == []
        print("EXCHANGE_RUNG_OK")
    """, devices=4)
    assert "EXCHANGE_RUNG_OK" in out


def test_dist_device_loss_shrinks_mesh_bitwise():
    """Losing 2 of 4 devices mid-run rebuilds the engine on the surviving
    2-device mesh from the latest snapshot and finishes bitwise-equal to
    an undisturbed 4-device run."""
    out = run_sub("""
        import tempfile
        from repro import engine, obs
        from repro.core import cp_als
        from repro.core.distributed import build_sharded_flycoo
        from repro.launch.mesh import make_mesh
        from repro.resilience import ChaosSpec, LadderPolicy, install

        rng = np.random.default_rng(0)
        dims = (24, 18, 12)
        idx = np.unique(np.stack(
            [rng.integers(0, d, 600) for d in dims], 1).astype(np.int32),
            axis=0)
        val = rng.standard_normal(idx.shape[0]).astype(np.float32)
        t = build_sharded_flycoo(idx, val, dims, n_dev=4, rows_pp=4,
                                 block_p=8)
        mesh = make_mesh((4,), ("data",))
        clean = cp_als(t, rank=4, iters=5, mesh=mesh)

        install(ChaosSpec(device_lost=2, device_lost_n=2))
        pol = LadderPolicy(backoff_base_s=1e-4, backoff_cap_s=1e-3)
        res = cp_als(t, rank=4, iters=5, mesh=mesh, ladder=pol,
                     checkpoint=tempfile.mkdtemp())
        for a, b in zip(clean.factors, res.factors):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert clean.fits == res.fits
        degr = obs.REGISTRY.metrics()[
            "resilience_degradations"].as_dict()
        assert degr.get("device_lost:4->2", 0) == 1, degr
        rep = obs.resilience_report()
        assert "device_lost" in rep["answered"]
        assert rep["unanswered"] == []
        # without a ladder the loss is fatal, never silent
        install(ChaosSpec(device_lost=0))
        try:
            cp_als(t, rank=4, iters=2, mesh=mesh)
        except Exception as exc:
            assert "injected loss" in str(exc)
        else:
            raise AssertionError("device loss must raise without ladder")
        print("DEVICE_LOSS_OK")
    """, devices=4)
    assert "DEVICE_LOSS_OK" in out


def test_dist_transient_dispatch_retries_bitwise():
    """A transiently failing dist dispatch retries with seeded backoff
    (the stream-upload path, at the dist hook site) and converges to the
    clean run bitwise."""
    out = run_sub("""
        from repro import engine, obs
        from repro.core import cp_als
        from repro.core.distributed import build_sharded_flycoo
        from repro.launch.mesh import make_mesh
        from repro.resilience import ChaosSpec, LadderPolicy, install

        rng = np.random.default_rng(0)
        dims = (24, 18, 12)
        idx = np.unique(np.stack(
            [rng.integers(0, d, 600) for d in dims], 1).astype(np.int32),
            axis=0)
        val = rng.standard_normal(idx.shape[0]).astype(np.float32)
        t = build_sharded_flycoo(idx, val, dims, n_dev=4, rows_pp=4,
                                 block_p=8)
        mesh = make_mesh((2,), ("data",))
        clean = cp_als(t, rank=4, iters=3, mesh=mesh)

        install(ChaosSpec(dist_transient=1, dist_transient_times=2))
        pol = LadderPolicy(backoff_base_s=1e-4, backoff_cap_s=1e-3)
        res = cp_als(t, rank=4, iters=3, mesh=mesh, ladder=pol)
        for a, b in zip(clean.factors, res.factors):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        retries = obs.REGISTRY.metrics()["resilience_retries"].as_dict()
        assert retries.get("dist.dispatch", 0) == 2, retries
        rep = obs.resilience_report()
        assert "dist_transient" in rep["answered"]
        assert rep["unanswered"] == []
        print("DIST_TRANSIENT_OK")
    """, devices=4)
    assert "DIST_TRANSIENT_OK" in out


# --------------------------------------------------------------------------
# Elastic kill-resume: SIGKILL a 4-device sweep, resume on 2 and on 1.
# --------------------------------------------------------------------------
_ELASTIC_SCRIPT = """
import os
import sys
os.environ["XLA_FLAGS"] = \
    "--xla_force_host_platform_device_count=" + sys.argv[4]
import numpy as np
from repro.core.cpd import cp_als
from repro.core.distributed import build_sharded_flycoo
from repro.launch.mesh import make_mesh

dims = (24, 18, 12)
rng = np.random.default_rng(0)
idx = np.unique(np.stack([rng.integers(0, d, 600) for d in dims], 1)
                .astype(np.int32), axis=0)
val = rng.standard_normal(len(idx)).astype(np.float32)
# the tensor is always the 4-device build: its kappas (multiples of 4)
# divide every smaller mesh, which is what makes the restart elastic
t = build_sharded_flycoo(idx, val, dims, n_dev=4, rows_pp=4, block_p=8)
mesh = make_mesh((int(sys.argv[4]),), ("data",))
r = cp_als(t, rank=4, iters=6, mesh=mesh, checkpoint=sys.argv[1],
           resume=(sys.argv[2] == "resume"))
np.savez(sys.argv[3], *[np.asarray(f) for f in r.factors],
         lam=np.asarray(r.lam), fits=np.asarray(r.fits))
"""


def _run_elastic(ckpt_dir, out, mode, devices, chaos_env=None, timeout=900):
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("REPRO_CHAOS", None)
    if chaos_env:
        env["REPRO_CHAOS"] = chaos_env
    return subprocess.run(
        [sys.executable, "-c", _ELASTIC_SCRIPT, ckpt_dir, mode, out,
         str(devices)],
        env=env, capture_output=True, text=True, timeout=timeout)


def test_elastic_kill_resume_across_device_counts(tmp_path):
    """The ISSUE-10 acceptance scenario: a 4-device distributed run is
    SIGKILLed mid-sweep; resuming from its sharded snapshots on 2 devices
    AND on 1 device replays the remaining sweeps bitwise-identically to
    an uninterrupted 4-device run."""
    import shutil
    import signal as _signal

    ckpt = str(tmp_path / "ckpt")
    clean = str(tmp_path / "clean.npz")
    # uninterrupted 4-device reference
    r = _run_elastic(str(tmp_path / "unused"), clean, "fresh", 4)
    assert r.returncode == 0, r.stderr
    # SIGKILL at the start of sweep 3 on 4 devices
    r = _run_elastic(ckpt, "/dev/null", "fresh", 4,
                     chaos_env="kill_sweep=3")
    assert r.returncode == -_signal.SIGKILL, (r.returncode, r.stderr)
    assert os.listdir(ckpt), "no snapshot survived the kill"
    with np.load(clean) as a:
        ref = {name: a[name] for name in a.files}
    for n_dev in (2, 1):
        ckpt_n = str(tmp_path / f"ckpt{n_dev}")
        shutil.copytree(ckpt, ckpt_n)
        out = str(tmp_path / f"resumed{n_dev}.npz")
        r = _run_elastic(ckpt_n, out, "resume", n_dev)
        assert r.returncode == 0, r.stderr
        with np.load(out) as b:
            for name in ref:
                np.testing.assert_array_equal(
                    ref[name], b[name],
                    err_msg=f"{name} (resumed on {n_dev} devices)")


def test_elastic_checkpoint_reshard():
    """Save on a 4-device mesh, restore onto 2 devices (elastic shrink)."""
    out = run_sub("""
        import tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import configs, sharding as shlib
        from repro.launch.mesh import make_mesh
        from repro.training import (CheckpointManager, OptimizerConfig,
                                    init_state)
        from repro.launch import specs as speclib

        cfg = configs.smoke("olmo-1b")
        ocfg = OptimizerConfig()
        tmp = tempfile.mkdtemp()

        mesh4 = make_mesh((2, 2), ("data", "model"))
        ctx4 = shlib.make_ctx(mesh4)
        state = init_state(cfg, ocfg, jax.random.PRNGKey(0))
        sh4 = speclib.state_shardings(
            jax.eval_shape(lambda: state), ctx4)
        state4 = jax.tree.map(jax.device_put, state, sh4)
        mgr = CheckpointManager(tmp, async_save=False)
        mgr.save(state4, {"step": 0})

        # "restart" on a smaller mesh: 2 devices
        mesh2 = make_mesh((2, 1), ("data", "model"))
        ctx2 = shlib.make_ctx(mesh2)
        sh2 = speclib.state_shardings(jax.eval_shape(lambda: state), ctx2)
        restored, _ = mgr.restore_latest(like=state, shardings=sh2)
        chk = jax.tree.map(
            lambda a, b: bool(jnp.all(a == b)), state, restored)
        assert all(jax.tree.leaves(chk))
        d = jax.tree.leaves(restored)[5]
        assert len(d.sharding.device_set) <= 2
        print("ELASTIC_OK")
    """)
    assert "ELASTIC_OK" in out
