"""Multi-device tests (subprocess with 8 fake CPU devices).

The test process keeps 1 device (conftest); anything needing a mesh runs in
a fresh interpreter with XLA_FLAGS set before jax import.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(body: str, devices: int = 8, timeout: int = 900) -> str:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count={devices}"
        import jax
        import jax.numpy as jnp
        import numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"stdout:{out.stdout}\nstderr:{out.stderr}"
    return out.stdout


def test_distributed_mttkrp_matches_oracle():
    out = run_sub("""
        from repro.core.distributed import (DistributedMTTKRP,
                                            build_sharded_flycoo)
        from repro.core import init_factors, mttkrp_ref
        from repro.launch.mesh import make_mesh

        rng = np.random.default_rng(0)
        dims = (40, 30, 20)
        idx = np.unique(np.stack(
            [rng.integers(0, d, 1500) for d in dims], 1).astype(np.int32),
            axis=0)
        val = rng.standard_normal(idx.shape[0]).astype(np.float32)
        mesh = make_mesh((4, 2), ("data", "model"))
        t = build_sharded_flycoo(idx, val, dims, n_dev=4, rows_pp=8,
                                 block_p=8)
        factors = init_factors(jax.random.PRNGKey(1), dims, 8)
        exe = DistributedMTTKRP(t, mesh, model_axis="model")
        for sweep in range(2):
            outs = exe.all_modes(factors)
            for d in range(3):
                ref = mttkrp_ref(jnp.asarray(idx), jnp.asarray(val),
                                 factors, d, dims[d])
                np.testing.assert_allclose(np.asarray(outs[d]), ref,
                                           rtol=2e-4, atol=2e-4)
        print("DIST_MTTKRP_OK")
    """)
    assert "DIST_MTTKRP_OK" in out


def test_sharded_train_step_matches_single_device():
    out = run_sub("""
        import dataclasses
        from repro import configs, sharding as shlib
        from repro.launch.mesh import make_mesh
        from repro.training import (OptimizerConfig, SyntheticLM,
                                    init_state, make_train_step)

        cfg = configs.smoke("tinyllama-1.1b")
        ocfg = OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=10)
        data = SyntheticLM(cfg, batch=4, seq=32, seed=0)
        batch = data.next()
        state = init_state(cfg, ocfg, jax.random.PRNGKey(0))
        # single device reference
        _, m_ref = jax.jit(make_train_step(cfg, ocfg))(
            jax.tree.map(jnp.copy, state), batch)
        # 2x4 mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        ctx = shlib.make_ctx(mesh)
        with shlib.use(ctx):
            _, m_sh = jax.jit(make_train_step(cfg, ocfg))(state, batch)
        a, b = float(m_ref["loss"]), float(m_sh["loss"])
        assert abs(a - b) < 3e-2, (a, b)
        print("SHARDED_TRAIN_OK", a, b)
    """)
    assert "SHARDED_TRAIN_OK" in out


def test_moe_expert_parallel_matches_local():
    out = run_sub("""
        from repro import configs, sharding as shlib
        from repro.launch.mesh import make_mesh
        from repro.models.moe import apply_moe, init_moe, _apply_local
        import dataclasses

        cfg = dataclasses.replace(configs.smoke("olmoe-1b-7b"),
                                  capacity_factor=8.0)
        params = init_moe(cfg, jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                              jnp.bfloat16)
        ref = _apply_local(params, x, cfg)
        mesh = make_mesh((2, 4), ("data", "model"))
        ctx = shlib.make_ctx(mesh)
        with shlib.use(ctx):
            out = jax.jit(lambda p, t: apply_moe(p, t, cfg))(params, x)
        err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                    - ref.astype(jnp.float32))))
        assert err < 2e-2, err
        print("MOE_EP_OK", err)
    """)
    assert "MOE_EP_OK" in out


def test_gradient_compression_error_feedback():
    out = run_sub("""
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.training.compression import compressed_grad_sync

        mesh = make_mesh((4,), ("pod",))
        g_global = jax.random.normal(jax.random.PRNGKey(0), (4, 64, 128))

        try:
            from jax import shard_map
            sm = partial(shard_map, mesh=mesh, in_specs=(P("pod"), P()),
                         out_specs=(P("pod"), P("pod")), check_vma=False)
        except ImportError:
            from jax.experimental.shard_map import shard_map
            sm = partial(shard_map, mesh=mesh, in_specs=(P("pod"), P()),
                         out_specs=(P("pod"), P("pod")), check_rep=False)

        def body(g_shard, key):
            g = {"w": g_shard[0]}
            synced, err = compressed_grad_sync(g, key, rank=16, axis_name="pod")
            return synced["w"][None], err["w"][None]

        synced, err = jax.jit(sm(body))(g_global, jax.random.PRNGKey(1))
        true_mean = jnp.mean(g_global, axis=0)
        # every pod agrees on the synced value
        assert float(jnp.max(jnp.abs(synced - synced[0][None]))) < 1e-5
        # rank-16 approx of a rank-128 mean won't be exact; error feedback
        # must store the residual g + e - approx
        resid = g_global[0] - synced[0]
        np.testing.assert_allclose(np.asarray(err[0]), np.asarray(resid),
                                   rtol=1e-4, atol=1e-4)
        print("COMPRESS_OK")
    """)
    assert "COMPRESS_OK" in out


def test_dryrun_entry_small_mesh():
    """dryrun lower path works end to end on a small mesh in-process."""
    out = run_sub("""
        import dataclasses
        from repro.launch.dryrun import lower_cell
        from repro.launch.mesh import make_mesh
        from repro.configs import smoke

        cfg = smoke("tinyllama-1.1b")
        mesh = make_mesh((2, 4), ("data", "model"))
        rec = lower_cell("tinyllama-1.1b", "train_4k", cfg=dataclasses.replace(
            cfg, remat="full"), mesh=mesh)
        assert rec["cost"]["flops_per_device"] > 0
        assert rec["collectives_per_device"]["total"] > 0
        print("DRYRUN_SMALL_OK")
    """)
    assert "DRYRUN_SMALL_OK" in out


def test_pipeline_parallel_matches_sequential():
    out = run_sub("""
        from repro.launch.mesh import make_mesh
        from repro.training.pipeline import pipeline_apply

        n_stages, d = 4, 16
        mesh = make_mesh((4,), ("pp",))
        key = jax.random.PRNGKey(0)
        ws = jax.random.normal(key, (n_stages, d, d)) * 0.3

        def stage_fn(w, h):
            return jnp.tanh(h @ w)

        x = jax.random.normal(jax.random.PRNGKey(1), (8, d))
        # sequential reference
        ref = x
        for s in range(n_stages):
            ref = stage_fn(ws[s], ref)
        y = jax.jit(lambda w, t: pipeline_apply(
            stage_fn, w, t, mesh=mesh, n_micro=4))(ws, x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
        print("PIPELINE_OK")
    """)
    assert "PIPELINE_OK" in out


def test_elastic_checkpoint_reshard():
    """Save on a 4-device mesh, restore onto 2 devices (elastic shrink)."""
    out = run_sub("""
        import tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import configs, sharding as shlib
        from repro.launch.mesh import make_mesh
        from repro.training import (CheckpointManager, OptimizerConfig,
                                    init_state)
        from repro.launch import specs as speclib

        cfg = configs.smoke("olmo-1b")
        ocfg = OptimizerConfig()
        tmp = tempfile.mkdtemp()

        mesh4 = make_mesh((2, 2), ("data", "model"))
        ctx4 = shlib.make_ctx(mesh4)
        state = init_state(cfg, ocfg, jax.random.PRNGKey(0))
        sh4 = speclib.state_shardings(
            jax.eval_shape(lambda: state), ctx4)
        state4 = jax.tree.map(jax.device_put, state, sh4)
        mgr = CheckpointManager(tmp, async_save=False)
        mgr.save(state4, {"step": 0})

        # "restart" on a smaller mesh: 2 devices
        mesh2 = make_mesh((2, 1), ("data", "model"))
        ctx2 = shlib.make_ctx(mesh2)
        sh2 = speclib.state_shardings(jax.eval_shape(lambda: state), ctx2)
        restored, _ = mgr.restore_latest(like=state, shardings=sh2)
        chk = jax.tree.map(
            lambda a, b: bool(jnp.all(a == b)), state, restored)
        assert all(jax.tree.leaves(chk))
        d = jax.tree.leaves(restored)[5]
        assert len(d.sharding.device_set) <= 2
        print("ELASTIC_OK")
    """)
    assert "ELASTIC_OK" in out
