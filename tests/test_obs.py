"""repro.obs acceptance surface (ISSUE 8).

Span nesting/ordering and attrs, thread-safety of concurrent spans,
the disabled-mode no-op fast path (bounded overhead), Chrome-trace
schema round-trip + validation, metrics-registry parity with the
legacy engine counters, the span-derived vs count-derived streaming
``overlap_efficiency`` agreement on a real streamed ``cp_als``, the
``memory_probe`` relocation, and ``time_fn``'s dispersion stats.
"""
import json
import threading
import time

import numpy as np
import pytest

import jax

import repro.engine as engine
from repro import obs
from repro.core.flycoo import build_flycoo
from repro.obs.trace import SpanRecord


@pytest.fixture
def tracer():
    """A private tracer installed as the global one for the test."""
    prev = obs.get_tracer()
    t = obs.enable(obs.Tracer(xla_annotations=False))
    try:
        yield t
    finally:
        if prev is None:
            obs.disable()
        else:
            obs.enable(prev)


@pytest.fixture
def registry():
    """A private registry (the global one stays untouched)."""
    return obs.MetricsRegistry()


def _coo(nnz=900, seed=0, dims=(29, 23, 19)):
    rng = np.random.default_rng(seed)
    idx = np.unique(
        np.stack([rng.integers(0, d, nnz) for d in dims], 1)
        .astype(np.int64), axis=0)
    return idx, rng.standard_normal(len(idx)).astype(np.float32), dims


# --------------------------------------------------------------------------
# Spans: nesting, ordering, attrs.
# --------------------------------------------------------------------------
def test_span_nesting_and_ordering(tracer):
    with obs.span("outer", who="a"):
        with obs.span("inner1"):
            pass
        with obs.span("inner2") as sp:
            sp.set("late", 42)
    spans = tracer.spans()
    assert [s.name for s in spans] == ["outer", "inner1", "inner2"]
    outer, inner1, inner2 = spans
    assert outer.parent_id is None
    assert inner1.parent_id == outer.span_id
    assert inner2.parent_id == outer.span_id
    assert outer.attrs == {"who": "a"}
    assert inner2.attrs == {"late": 42}
    # wall-clock containment
    assert outer.start_ns <= inner1.start_ns <= inner1.end_ns
    assert inner2.end_ns <= outer.end_ns
    assert inner1.end_ns <= inner2.start_ns  # sequential siblings


def test_traced_decorator(tracer):
    @obs.traced("my.fn", tag=1)
    def f(x):
        return x + 1

    assert f(1) == 2
    (s,) = tracer.spans()
    assert s.name == "my.fn" and s.attrs == {"tag": 1}


def test_span_survives_exception(tracer):
    with pytest.raises(RuntimeError):
        with obs.span("boom"):
            raise RuntimeError("x")
    (s,) = tracer.spans()
    assert s.name == "boom"
    # the stack popped: a new root span has no parent
    with obs.span("after"):
        pass
    assert tracer.spans()[1].parent_id is None


def test_thread_safety(tracer):
    def work(i):
        for j in range(50):
            with obs.span("t", worker=i):
                with obs.span("t.child"):
                    pass

    threads = [threading.Thread(target=work, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    spans = tracer.spans()
    assert len(spans) == 4 * 50 * 2
    # every child's parent is a span on ITS OWN thread
    by_id = {s.span_id: s for s in spans}
    for s in spans:
        if s.name == "t.child":
            assert by_id[s.parent_id].thread_id == s.thread_id


def test_disabled_is_noop_and_cheap():
    prev = obs.get_tracer()
    obs.disable()
    try:
        assert not obs.is_enabled()
        sp = obs.span("x", a=1)
        assert sp is obs.NULL_SPAN
        with sp:
            sp.set("k", "v")
        # bounded overhead: a disabled span costs within 50x of a bare
        # no-op context (both are nanoseconds; 50x keeps CI noise out)
        n = 20_000

        class _Bare:
            def __enter__(self):
                return self

            def __exit__(self, *e):
                return False

        bare = _Bare()

        def loop_bare():
            t0 = time.perf_counter()
            for _ in range(n):
                with bare:
                    pass
            return time.perf_counter() - t0

        def loop_span():
            t0 = time.perf_counter()
            for _ in range(n):
                with obs.span("x"):
                    pass
            return time.perf_counter() - t0

        loop_bare(), loop_span()  # warm
        t_bare = min(loop_bare() for _ in range(3))
        t_span = min(loop_span() for _ in range(3))
        assert t_span < max(t_bare * 50, 20e-3), (t_span, t_bare)
    finally:
        if prev is not None:
            obs.enable(prev)


# --------------------------------------------------------------------------
# Metrics registry.
# --------------------------------------------------------------------------
def test_counter_dict_surface(registry):
    c = registry.counter("c", "help")
    c.inc("a")
    c["a"] += 2          # legacy dict-style increment
    c["b"] = 5
    assert c["a"] == 3 and c["b"] == 5 and c["missing"] == 0
    assert dict(c) == {"a": 3, "b": 5}
    assert set(c.keys()) == {"a", "b"}
    assert c.total() == 8
    c.clear()
    assert dict(c) == {} and c["a"] == 0


def test_gauge_and_histogram(registry):
    g = registry.gauge("g")
    g.set("x", 1.5)
    g.max("x", 0.5)      # running max keeps 1.5
    g.max("x", 2.5)
    assert g["x"] == 2.5
    h = registry.histogram("h")
    for v in (1.0, 3.0, 2.0):
        h.observe("k", v)
    s = h.summary("k")
    assert s["count"] == 3 and s["min"] == 1.0 and s["max"] == 3.0
    assert s["mean"] == pytest.approx(2.0)


def test_registry_kind_conflict(registry):
    registry.counter("m")
    with pytest.raises(TypeError):
        registry.gauge("m")


def test_legacy_counter_parity():
    """TRACE_COUNTS / DISPATCH_COUNTS live on the obs registry but keep
    the legacy surface the benchmarks and tests rely on."""
    assert isinstance(engine.TRACE_COUNTS, obs.Counter)
    assert engine.TRACE_COUNTS is obs.REGISTRY.counter("engine_traces")
    engine.reset_counters()
    idx, val, dims = _coo()
    t = build_flycoo(idx, val, dims)
    state = engine.init(t, engine.ExecutionConfig(backend="xla"))
    factors = [jax.random.uniform(k, (d, 4), jax.numpy.float32)
               for k, d in zip(jax.random.split(jax.random.PRNGKey(0),
                                                len(dims)), dims)]
    for _ in range(3):
        outs, state = engine.all_modes(state, factors)
    assert engine.DISPATCH_COUNTS["all_modes"] == 3
    assert engine.TRACE_COUNTS["all_modes"] == 1
    assert dict(engine.DISPATCH_COUNTS)["all_modes"] == 3
    # and the same numbers flow out through the registry snapshot
    snap = {m["name"]: m["values"] for m in obs.REGISTRY.collect()}
    assert snap["engine_dispatches"]["all_modes"] == 3
    engine.reset_counters()
    assert engine.DISPATCH_COUNTS["all_modes"] == 0


# --------------------------------------------------------------------------
# Export: Chrome-trace schema round-trip.
# --------------------------------------------------------------------------
def test_chrome_trace_roundtrip(tracer, registry, tmp_path):
    registry.counter("events").inc("n", 7)
    with obs.span("parent", mode=1):
        with obs.span("child", chunk=0):
            pass
    path = tmp_path / "trace.json"
    obs.write_chrome_trace(str(path), tracer, registry,
                           manifest={"test": True})
    with open(path) as f:
        trace = json.load(f)
    assert obs.validate_chrome_trace(trace) == []
    xs = [e for e in trace["traceEvents"] if e["ph"] == "X"]
    assert {e["name"] for e in xs} == {"parent", "child"}
    child = next(e for e in xs if e["name"] == "child")
    parent = next(e for e in xs if e["name"] == "parent")
    assert child["args"]["parent_id"] == parent["args"]["span_id"]
    assert child["args"]["chunk"] == 0
    assert child["ts"] >= parent["ts"] >= 0
    cs = [e for e in trace["traceEvents"] if e["ph"] == "C"]
    assert any(e["name"] == "events" and e["args"] == {"n": 7} for e in cs)
    assert trace["metadata"]["manifest"] == {"test": True}
    assert trace["metadata"]["span_count"] == 2


def test_validate_rejects_malformed():
    assert obs.validate_chrome_trace([]) != []
    assert obs.validate_chrome_trace({"traceEvents": "nope"}) != []
    bad = {"traceEvents": [{"name": "x", "ph": "X", "pid": 0, "tid": 0,
                            "ts": -1, "dur": 1, "args": {}}]}
    errs = obs.validate_chrome_trace(bad)
    assert any("ts" in e for e in errs)
    assert any("span_id" in e for e in errs)


def test_jsonl_export(tracer, tmp_path):
    with obs.span("a"):
        pass
    path = tmp_path / "spans.jsonl"
    assert obs.write_jsonl(str(path), tracer) == 1
    rec = json.loads(path.read_text().strip())
    assert rec["name"] == "a" and rec["dur_ns"] >= 0


def test_run_manifest_contents():
    m = obs.run_manifest(spec=engine.PlanSpec(),
                        dataset_signature=((4, 5), 17))
    assert m["jax_version"] == jax.__version__
    assert m["plan_spec"]["backend"] == "xla"
    assert m["dataset_signature"] == [[4, 5], 17]


def test_env_var_enables(tmp_path):
    import subprocess
    import sys
    out = tmp_path / "t.json"
    code = ("import repro.obs as o\n"
            "assert o.is_enabled()\n"
            "with o.span('x'):\n"
            "    pass\n")
    subprocess.run([sys.executable, "-c", code], check=True,
                   env={"PYTHONPATH": "src", "REPRO_TRACE": str(out),
                        "PATH": "/usr/bin:/bin"}, cwd="/root/repo")
    trace = json.loads(out.read_text())
    assert obs.validate_chrome_trace(trace) == []
    assert any(e.get("name") == "x" for e in trace["traceEvents"])


# --------------------------------------------------------------------------
# Span-derived vs count-derived streaming overlap.
# --------------------------------------------------------------------------
def test_overlap_rule_synthetic():
    mk = lambda name, sid, par, t0, t1, **a: SpanRecord(
        name, sid, par, 1, "main", t0, t1, a)
    spans = [
        mk("stream.mode", 1, None, 0, 100),
        mk("stream.upload", 2, 1, 1, 4, chunk=0),   # first: never overlapped
        mk("stream.upload", 3, 1, 5, 9, chunk=1),   # prefetch before c0 runs
        mk("stream.compute", 4, 1, 10, 30, chunk=0),
        mk("stream.compute", 5, 1, 31, 50, chunk=1),
    ]
    assert obs.stream_overlap_from_spans(spans) == 0.5
    # same via a chrome export
    t = obs.Tracer(xla_annotations=False)
    for s in spans:
        t._record(s)
    trace = obs.chrome_trace(t, obs.MetricsRegistry())
    assert obs.stream_overlap_from_chrome(trace) == 0.5
    assert obs.stream_overlap_from_spans([]) is None


def test_streamed_cpd_overlap_agreement(tracer):
    """The ISSUE 8 acceptance: on a streamed cp_als run the span-derived
    overlap_efficiency agrees with StreamStats' upload-count metric
    within 0.1 (they are in fact constructed to agree exactly)."""
    from repro.engine.stream import cp_als_stream, stream_init

    idx, val, dims = _coo(nnz=2000)
    t = build_flycoo(idx, val, dims, kappa=4)
    config = engine.ExecutionConfig(backend="xla", kappa_policy="fixed",
                                    kappa=4, chunk_nnz=128, stream_ring=2)
    state = stream_init(t, config)
    assert state.plan.chunks[0].nchunks > 1, "need multiple chunks"
    res = cp_als_stream(t, rank=4, iters=2, config=config)
    assert len(res.fits) == 2

    span_eff = obs.stream_overlap_from_spans(tracer.spans())
    # count-derived, via a fresh run's StreamStats (same plan/config)
    state2 = stream_init(t, config)
    factors = [jax.random.uniform(k, (d, 4), jax.numpy.float32)
               for k, d in zip(jax.random.split(jax.random.PRNGKey(1),
                                                len(dims)), dims)]
    from repro.engine.stream import stream_all_modes
    stream_all_modes(state2, factors)
    count_eff = state2.stats.overlap_efficiency
    assert state2.stats.uploads > 0 and count_eff > 0
    assert span_eff is not None
    assert abs(span_eff - count_eff) <= 0.1, (span_eff, count_eff)


def test_stream_stats_as_row_has_device_peak():
    from repro.engine.stream import StreamStats

    row = StreamStats().as_row()
    assert "device_peak_bytes" in row  # None on CPU jax is fine


# --------------------------------------------------------------------------
# Report.
# --------------------------------------------------------------------------
def test_render_report(tracer, registry):
    registry.counter("plan_cache_outcomes").inc("hit", 3)
    registry.counter("plan_cache_outcomes").inc("miss")
    with obs.span("factory.make_engine"):
        with obs.span("plan.mode", mode=0):
            pass
    text = obs.render_report(tracer, registry)
    assert "factory.make_engine" in text and "plan.mode" in text
    assert "hit" in text and "75.0%" in text
    md = obs.render_report(tracer, registry, fmt="markdown")
    assert md.startswith("# repro run report")
    with pytest.raises(ValueError):
        obs.render_report(tracer, registry, fmt="html")


# --------------------------------------------------------------------------
# Satellites: probe relocation + time_fn dispersion.
# --------------------------------------------------------------------------
def test_memory_probe_moved_and_reexported():
    import benchmarks.common as common

    assert common.memory_probe is obs.memory_probe
    probe = obs.memory_probe()
    assert probe["host_peak_rss_bytes"] is None or \
        probe["host_peak_rss_bytes"] > 0


def test_time_fn_dispersion(tmp_path, monkeypatch):
    import benchmarks.common as common

    t = common.time_fn(lambda: np.zeros(4), iters=5, warmup=1)
    assert isinstance(t, common.Timing) and float(t) > 0
    assert set(t.stats) == {"p10", "p90", "iqr", "timing_iters"}
    assert t.stats["p10"] <= float(t) <= t.stats["p90"]
    us = t * 1e6           # the figure scripts' unit conversion
    assert isinstance(us, common.Timing)
    assert us.stats["p90"] == pytest.approx(t.stats["p90"] * 1e6)
    assert us.stats["timing_iters"] == 5
    # emit folds the stats into the JSON extras
    out = tmp_path / "results.json"
    monkeypatch.setattr(common, "_JSON_PATH", str(out))
    common.emit([("row", us, 1.0)])
    rec = {r["name"]: r for r in json.loads(out.read_text())}["row"]
    assert rec["p90"] == pytest.approx(round(t.stats["p90"] * 1e6, 1))
    assert rec["timing_iters"] == 5
