"""Plan cache + factory + autotuner tests (ISSUE 6 acceptance surface).

Cache-key properties (permutation -> structural hit, changed sparsity ->
miss, bitwise plan equality), PlanSpec/PlanSpace factory semantics,
autotuner determinism + never-worse-than-default on modeled cost, and
engine parity under factory-built / cached / autotuned plans for all four
backends plus the distributed engine (subprocess, fake CPU devices).
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # container lacks hypothesis: skip only these
    from conftest import given, settings, st

from repro.core import datasets
from repro.core.flycoo import build_flycoo
from repro.core.plancache import PlanCache, sparsity_signature

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _coo(seed=0, dims=(60, 50, 40), nnz=2500, a=1.5):
    t = datasets.zipf_tensor(dims, nnz, a=a, seed=seed)
    return t.indices, t.values, t.dims


def _assert_plans_equal(pa, pb):
    for a, b in zip(pa, pb):
        assert (a.kappa, a.rows_pp, a.block_p, a.schedule, a.nblocks,
                a.blocks_pp, a.max_degree) == \
               (b.kappa, b.rows_pp, b.block_p, b.schedule, b.nblocks,
                b.blocks_pp, b.max_degree)
        np.testing.assert_array_equal(a.row_relabel, b.row_relabel)
        np.testing.assert_array_equal(a.slot_of_elem, b.slot_of_elem)
        np.testing.assert_array_equal(a.part_nnz, b.part_nnz)
        np.testing.assert_array_equal(a.block_part, b.block_part)


# --------------------------------------------------------------------------
# Sparsity signature + cache key properties.
# --------------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 999), zipf_a=st.floats(1.1, 2.5))
def test_signature_permutation_invariant(seed, zipf_a):
    idx, val, dims = _coo(seed=seed, a=zipf_a)
    perm = np.random.default_rng(seed).permutation(idx.shape[0])
    assert sparsity_signature(idx, dims) == \
        sparsity_signature(idx[perm], dims)


def test_signature_distinguishes_dims_and_sparsity():
    idx, val, dims = _coo()
    assert sparsity_signature(idx, dims) != \
        sparsity_signature(idx, (dims[0] + 1,) + dims[1:])
    assert sparsity_signature(idx[:-1], dims) != \
        sparsity_signature(idx, dims)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 999))
def test_cache_hit_structural_miss(seed):
    """Same element list -> identity hit; permuted order -> structural
    hit; changed sparsity or dims -> miss."""
    idx, val, dims = _coo(seed=seed)
    rng = np.random.default_rng(seed)
    cache = PlanCache()
    cache.get_tensor(idx, val, dims)
    assert cache.last_outcome == "miss"
    cache.get_tensor(idx.copy(), val, dims)   # distinct, equal array
    assert cache.last_outcome == "hit"
    perm = rng.permutation(idx.shape[0])
    cache.get_tensor(idx[perm], val[perm], dims)
    assert cache.last_outcome == "structural"
    mut = idx.copy()
    mut[0, 0] = (mut[0, 0] + 1) % dims[0]
    cache.get_tensor(mut, val, dims)
    assert cache.last_outcome == "miss"
    assert cache.stats()["misses"] == 2


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 999), schedule=st.sampled_from(["compact",
                                                           "rect"]))
def test_cached_plans_bitwise_equal_fresh(seed, schedule):
    """Identity-hit and structural-hit plans are bitwise-equal to freshly
    built ones (the cache can never change numerics)."""
    idx, val, dims = _coo(seed=seed)
    rng = np.random.default_rng(seed)
    cache = PlanCache()
    t0 = cache.get_tensor(idx, val, dims, schedule=schedule)
    t1 = cache.get_tensor(idx.copy(), val, dims, schedule=schedule)
    assert cache.last_outcome == "hit"
    _assert_plans_equal(t0.plans, t1.plans)
    _assert_plans_equal(t1.plans,
                        build_flycoo(idx, val, dims,
                                     schedule=schedule).plans)
    perm = rng.permutation(idx.shape[0])
    t2 = cache.get_tensor(idx[perm], val[perm], dims, schedule=schedule)
    assert cache.last_outcome == "structural"
    _assert_plans_equal(t2.plans,
                        build_flycoo(idx[perm], val[perm], dims,
                                     schedule=schedule).plans)


def test_cache_knob_key_separates_plans():
    idx, val, dims = _coo()
    cache = PlanCache()
    a = cache.get_tensor(idx, val, dims, block_p=32)
    b = cache.get_tensor(idx, val, dims, block_p=64)
    assert cache.last_outcome == "miss"  # known structure, new knobs
    assert a.plans[0].block_p == 32 and b.plans[0].block_p == 64
    cache.get_tensor(idx, val, dims, block_p=32)
    assert cache.last_outcome == "hit"


def test_cache_eviction_bounds_entries():
    cache = PlanCache(max_entries=3)
    for seed in range(6):
        idx, val, dims = _coo(seed=seed, nnz=400)
        cache.get_tensor(idx, val, dims)
    assert cache.stats()["entries"] <= 3


# --------------------------------------------------------------------------
# Factory: PlanSpec / PlanSpace semantics.
# --------------------------------------------------------------------------
def test_planspace_enumeration_canonical_and_deterministic():
    from repro.engine import PlanSpace, PlanSpec

    space = PlanSpace(backend=("xla", "pallas_fused"),
                      schedule=("compact", "rect"),
                      block_p=(64, 128), dedup=(True, False))
    specs = space.specs()
    assert specs == space.specs()  # deterministic enumeration
    assert len(set(specs)) == len(specs)
    for s in specs:
        # canonicalized: dedup only varies where tables exist
        if s.schedule == "rect" or s.backend == "xla":
            assert s.dedup is True
    # xla never sees a dedup=False duplicate: 2 backends * 2 schedules *
    # 2 P * dedup only for (pallas_fused, compact)
    assert len(specs) == 2 * 2 * 2 + 2


def test_planspec_validation():
    from repro.engine import PlanSpec

    with pytest.raises(ValueError):
        PlanSpec(schedule="diagonal")
    with pytest.raises(ValueError):
        PlanSpec(exchange="broadcast")
    with pytest.raises(ValueError):
        PlanSpec(kappa_policy="fixed")  # fixed requires kappa


def test_make_engine_uses_cache_and_matches_cold():
    import repro.engine as engine
    from repro.engine import PlanSpec, make_engine

    idx, val, dims = _coo()
    rng = np.random.default_rng(0)
    factors = tuple(rng.standard_normal((d, 8)).astype(np.float32)
                    for d in dims)
    cache = PlanCache()
    spec = PlanSpec(backend="xla", rows_pp=16, block_p=32)
    s_cold = make_engine((idx, val, dims), spec, cache=False)
    make_engine((idx, val, dims), spec, cache=cache)
    s_hit = make_engine((idx, val, dims), spec, cache=cache)
    assert cache.last_outcome == "hit"
    o_cold, _ = engine.all_modes(s_cold, factors)
    o_hit, _ = engine.all_modes(s_hit, factors)
    for d in range(len(dims)):
        np.testing.assert_array_equal(np.asarray(o_cold[d]),
                                      np.asarray(o_hit[d]))


# --------------------------------------------------------------------------
# Autotuner: determinism + modeled-cost guarantee + backend parity.
# --------------------------------------------------------------------------
def _small_space():
    from repro.engine import PlanSpace, PlanSpec

    return PlanSpace(backend=("pallas_fused",), block_p=(16, 32, 64),
                     base=PlanSpec(backend="pallas_fused", rows_pp=16,
                                   block_p=32))


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 99))
def test_autotune_deterministic_under_seed(seed):
    from repro.engine.autotune import autotune

    idx, val, dims = _coo(nnz=1200)
    r1 = autotune(idx, val, dims, _small_space(), seed=seed)
    r2 = autotune(idx, val, dims, _small_space(), seed=seed)
    assert r1.best == r2.best
    assert r1.modeled == r2.modeled
    assert r1.analytic == r2.analytic


def test_autotune_never_worse_than_default_on_modeled_cost():
    from repro.engine.autotune import autotune

    idx, val, dims = _coo()
    r = autotune(idx, val, dims, _small_space(), seed=0)
    assert r.default in r.modeled
    assert r.modeled[r.best] <= r.modeled[r.default]


def test_hill_climb_deterministic_and_traced():
    from repro.engine.autotune import autotune

    idx, val, dims = _coo(nnz=1200)
    space = _small_space()

    def run(seed):
        # synthetic measure: analytic cost stands in for wall time, so the
        # climb has a deterministic landscape with real moves
        r0 = autotune(idx, val, dims, space, seed=seed)
        return autotune(idx, val, dims, space, seed=seed,
                        measure=lambda s: r0.analytic.get(s, 1e9))

    r1, r2 = run(3), run(3)
    assert r1.best == r2.best
    assert [s["spec"] for s in r1.trace] == [s["spec"] for s in r2.trace]
    assert r1.trace[0]["move"] == "start"


def test_backends_identical_under_factory_cached_autotuned():
    """Each backend's result is bitwise-identical across factory-built,
    cached, and autotuned plans (and backends agree to float tolerance)."""
    import repro.engine as engine
    from repro.engine import PlanSpec, make_engine
    from repro.engine.autotune import autotune

    idx, val, dims = _coo()
    rng = np.random.default_rng(1)
    factors = tuple(rng.standard_normal((d, 8)).astype(np.float32)
                    for d in dims)
    space = _small_space()
    tuned = autotune(idx, val, dims, space, seed=0).best
    outs = {}
    for b in ("xla", "ref", "pallas", "pallas_fused"):
        spec = PlanSpec(backend=b, rows_pp=16, block_p=32)
        cache = PlanCache()
        runs = []
        for cch in (False, cache, cache):   # cold, miss, identity hit
            st_ = make_engine((idx, val, dims), spec, cache=cch)
            o, _ = engine.all_modes(st_, factors)
            runs.append([np.asarray(x) for x in o])
        assert cache.last_outcome == "hit"
        # autotuned knobs under the same backend
        st_ = make_engine((idx, val, dims),
                          dataclasses.replace(tuned, backend=b),
                          cache=cache)
        o, _ = engine.all_modes(st_, factors)
        for d in range(len(dims)):
            np.testing.assert_array_equal(runs[0][d], runs[1][d])
            np.testing.assert_array_equal(runs[0][d], runs[2][d])
            # plan knobs may legally change accumulation order; parity
            # across specs is numeric, not bitwise
            np.testing.assert_allclose(runs[0][d], np.asarray(o[d]),
                                       rtol=2e-5, atol=2e-5)
        outs[b] = runs[0]
    for b in ("ref", "pallas", "pallas_fused"):
        for d in range(len(dims)):
            np.testing.assert_allclose(outs["xla"][d], outs[b][d],
                                       rtol=2e-5, atol=2e-5)


def test_dedup_off_matches_dedup_on():
    """dedup=False (trivial tables) is bitwise-identical to dedup=True for
    the fused backend — only DMA staging differs, not accumulation."""
    import repro.engine as engine
    from repro.engine import PlanSpec, make_engine

    idx, val, dims = _coo()
    rng = np.random.default_rng(2)
    factors = tuple(rng.standard_normal((d, 8)).astype(np.float32)
                    for d in dims)
    outs = []
    for dedup in (True, False):
        spec = PlanSpec(backend="pallas_fused", rows_pp=16, block_p=32,
                        dedup=dedup)
        o, _ = engine.all_modes(
            make_engine((idx, val, dims), spec, cache=False), factors)
        outs.append([np.asarray(x) for x in o])
    for d in range(len(dims)):
        np.testing.assert_array_equal(outs[0][d], outs[1][d])


# --------------------------------------------------------------------------
# Distributed engine parity under the factory (subprocess, fake devices).
# --------------------------------------------------------------------------
def test_distributed_identical_under_factory_and_cache():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count=4"
        import jax
        import numpy as np
        import repro.engine as engine
        from repro.core import datasets
        from repro.core.plancache import PlanCache
        from repro.engine import PlanSpec, make_engine
        from repro.launch.mesh import make_mesh

        t = datasets.zipf_tensor((40, 30, 20), 1200, a=1.5, seed=0)
        idx, val, dims = t.indices, t.values, t.dims
        rng = np.random.default_rng(0)
        factors = tuple(rng.standard_normal((d, 8)).astype(np.float32)
                        for d in dims)
        mesh = make_mesh((4,), ("data",))
        spec = PlanSpec(backend="xla", rows_pp=8, block_p=8)
        cache = PlanCache()
        ds_cold = make_engine((idx, val, dims), spec, cache=False,
                              mesh=mesh)
        make_engine((idx, val, dims), spec, cache=cache, mesh=mesh)
        ds_hit = make_engine((idx, val, dims), spec, cache=cache,
                             mesh=mesh)
        assert cache.last_outcome == "hit", cache.last_outcome
        o_cold, _ = engine.dist_all_modes(ds_cold, factors)
        o_hit, _ = engine.dist_all_modes(ds_hit, factors)
        for d in range(3):
            np.testing.assert_array_equal(np.asarray(o_cold[d]),
                                          np.asarray(o_hit[d]))
        # and the sharded result matches the single-device engine
        st = make_engine((idx, val, dims), spec, cache=cache)
        o_single, _ = engine.all_modes(st, factors)
        for d in range(3):
            np.testing.assert_allclose(np.asarray(o_cold[d]),
                                       np.asarray(o_single[d]),
                                       rtol=2e-5, atol=2e-5)
        print("DIST-FACTORY-OK")
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=900, env=env)
    assert out.returncode == 0, f"stdout:{out.stdout}\nstderr:{out.stderr}"
    assert "DIST-FACTORY-OK" in out.stdout
