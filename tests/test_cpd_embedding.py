"""CPD-factorized embedding: lookup vs dense table, VJP vs autodiff oracle
(the backward IS an spMTTKRP — DESIGN.md §4)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.tensorized import (cpd_embed, cpd_logits, dense_table,
                              init_cpd_embedding, split_dims)


def _params(vocab=300, d=32, rank=8, seed=0):
    return init_cpd_embedding(jax.random.PRNGKey(seed), vocab, d, rank)


def test_lookup_matches_dense_table():
    params = _params()
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, 300)
    out = cpd_embed(params, tokens)
    table = dense_table(params)
    np.testing.assert_allclose(out, table[tokens], rtol=1e-5, atol=1e-5)


def test_custom_vjp_matches_autodiff():
    """The hand-written spMTTKRP backward == jax.grad of the naive lookup."""
    params = _params(vocab=200, d=16, rank=4)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (3, 8), 0, 200)
    tgt = jax.random.normal(jax.random.PRNGKey(3), (3, 8, 16))

    def loss_custom(p):
        return jnp.sum((cpd_embed(p, tokens) - tgt) ** 2)

    def loss_naive(p):
        out, _ = __import__(
            "repro.tensorized.cpd_embedding", fromlist=["_lookup"]
        )._lookup(p, tokens)
        return jnp.sum((out - tgt) ** 2)

    g1 = jax.grad(loss_custom)(params)
    g2 = jax.grad(loss_naive)(params)
    for k in ("A", "B", "C"):
        np.testing.assert_allclose(g1[k], g2[k], rtol=1e-3, atol=1e-4)


def test_cpd_logits_match_dense_head():
    params = _params(vocab=144, d=24, rank=6)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 5, 24))
    logits = cpd_logits(params, x)
    table = dense_table(params)
    np.testing.assert_allclose(logits[..., :144], (x @ table.T)[..., :144],
                               rtol=1e-4, atol=1e-4)


def test_split_dims_covers_vocab():
    for v in (10, 100, 256000, 257216, 51866):
        v1, v2 = split_dims(v)
        assert v1 * v2 >= v


def test_compression_ratio():
    """The point of the technique: storage is (V1+V2+D)R << V*D."""
    vocab, d, rank = 256000, 1024, 64
    params = init_cpd_embedding(jax.random.PRNGKey(0), vocab, d, rank)
    n = sum(p.size for k, p in params.items() if k != "v2")
    assert n * 20 < vocab * d


def test_cpd_embedding_inside_model_trains():
    """cfg.cpd_embedding=True: the LM trains with the spMTTKRP-backward
    embedding + tied CPD head (the paper's technique as a model feature)."""
    import dataclasses

    from repro import configs
    from repro.training import (OptimizerConfig, SyntheticLM, init_state,
                                make_train_step)

    cfg = dataclasses.replace(configs.smoke("tinyllama-1.1b"),
                              cpd_embedding=True, cpd_rank=16)
    ocfg = OptimizerConfig(lr=3e-3, warmup_steps=2, total_steps=20)
    state = init_state(cfg, ocfg, jax.random.PRNGKey(0))
    assert "embed_cpd" in state["params"]
    assert "embed" not in state["params"]
    step = jax.jit(make_train_step(cfg, ocfg))
    data = SyntheticLM(cfg, batch=4, seq=32, seed=0)
    losses = []
    for _ in range(15):
        state, m = step(state, data.next())
        losses.append(float(m["loss"]))
    assert np.mean(losses[-3:]) < np.mean(losses[:3])
