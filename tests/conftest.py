import os
import sys

import pytest

# tests see 1 CPU device (the dry-run sets its own 512-device flag in
# subprocesses; never globally — see launch/dryrun.py)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


# --------------------------------------------------------------------------
# Optional-dependency stand-ins: the container may lack `hypothesis`.
# Property-test modules fall back to these so ONLY the property tests skip
# (the seed behavior was an import error that killed whole files).
# --------------------------------------------------------------------------
def given(*_args, **_kwargs):
    def deco(fn):
        def stub():
            pytest.skip("hypothesis not installed")
        stub.__name__ = fn.__name__
        stub.__doc__ = fn.__doc__
        return stub
    return deco


def settings(*_args, **_kwargs):
    return lambda fn: fn


class st:  # noqa: N801 — mirrors `hypothesis.strategies as st`
    @staticmethod
    def integers(*_a, **_k):
        return None

    @staticmethod
    def floats(*_a, **_k):
        return None

    @staticmethod
    def sampled_from(*_a, **_k):
        return None
