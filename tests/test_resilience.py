"""Resilience layer tests (ISSUE 9 acceptance surface).

Chaos scenarios, each gated on BITWISE parity with an undisturbed run
wherever the design promises it: kill-at-sweep + resume (subprocess,
``REPRO_CHAOS``), in-process checkpoint/resume, streamed OOM ->
chunk-budget halving, compile failure -> backend ladder, transient upload
failure -> retry-with-backoff, NaN burst -> rollback + ridge recovery,
torn PlanCache blob -> quarantine + self-heal, resident OOM -> streaming
fallback. Plus the pure pieces: snapshot roundtrip/quarantine, failure
classification, ladder order, seeded backoff, ``REPRO_CHAOS`` parsing,
and the ``resilience_report`` no-silent-degradation pairing.
"""
import os
import signal
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.engine as engine
from repro import obs
from repro.core.cpd import cp_als
from repro.core.flycoo import build_flycoo
from repro.core.plancache import PlanCache
from repro.engine import ExecutionConfig, PlanSpec, make_engine
from repro.engine.stream import StreamState, cp_als_stream, stream_all_modes, stream_init
from repro.resilience import (ChaosDeviceLost, ChaosExchangeError, ChaosOOM,
                              ChaosSpec, ChaosUploadError, DEFAULT_POLICY,
                              LadderPolicy, Snapshot, SnapshotStore,
                              backoff_delay, chaos, classify, factor_shards,
                              fingerprint, install, install_ambient, ladder,
                              next_backend, resolve_policy, uninstall,
                              uninstall_ambient)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _coo(nmodes=3, nnz=300, seed=0):
    dims = (29, 23, 19, 13, 11, 7)[:nmodes]
    rng = np.random.default_rng(seed)
    idx = np.unique(
        np.stack([rng.integers(0, d, nnz) for d in dims], 1)
        .astype(np.int64), axis=0)
    return idx, rng.standard_normal(len(idx)).astype(np.float32), dims


def _factors(dims, rank=5, seed=1):
    key = jax.random.PRNGKey(seed)
    return tuple(
        jax.random.normal(jax.random.fold_in(key, d), (dims[d], rank),
                          jnp.float32) for d in range(len(dims)))


@pytest.fixture(autouse=True)
def _no_chaos_leak():
    """Every test starts and ends with chaos uninstalled."""
    uninstall()
    yield
    uninstall()


def _tensor(**kw):
    idx, val, dims = _coo(**kw)
    return build_flycoo(idx, val, dims, rows_pp=8)


# --------------------------------------------------------------------------
# Pure pieces: classify / ladder order / backoff / env parsing / policy.
# --------------------------------------------------------------------------
def test_classify():
    assert classify(ChaosOOM("x")) == "oom"
    assert classify(ChaosUploadError("x")) == "transient"
    assert classify(RuntimeError("RESOURCE_EXHAUSTED: out of memory")) \
        == "oom"
    assert classify(RuntimeError("Mosaic lowering failed")) == "compile"
    assert classify(RuntimeError("transfer failed: connection reset")) \
        == "transient"
    assert classify(ValueError("bad rank")) == "fatal"


def test_ladder_order_deterministic():
    assert engine.config.BACKEND_LADDER == \
        ("pallas_fused", "pallas", "xla", "ref")
    chain, b = [], "pallas_fused"
    while b is not None:
        chain.append(b)
        b = next_backend(b)
    assert chain == ["pallas_fused", "pallas", "xla", "ref"]
    assert next_backend("ref") is None
    assert next_backend("not_a_backend") is None


def test_backoff_seeded_and_bounded():
    p = LadderPolicy(backoff_base_s=0.01, backoff_cap_s=0.05, jitter=0.5,
                     seed=3)
    delays = [backoff_delay(p, a, token="t") for a in range(6)]
    assert delays == [backoff_delay(p, a, token="t") for a in range(6)]
    assert all(0 <= d <= 0.05 for d in delays)
    assert backoff_delay(p, 0, token="other") != delays[0]


def test_resolve_policy():
    assert resolve_policy(None) is None
    assert resolve_policy(False) is None
    assert resolve_policy(True) is DEFAULT_POLICY
    p = LadderPolicy(max_retries=7)
    assert resolve_policy(p) is p


def test_chaos_from_env():
    spec = chaos.from_env("upload_fail=1,oom_chunk=3,kill_sweep=2,"
                          "compile_fail=pallas_fused|pallas,"
                          "corrupt_blob,seed=7")
    assert spec == ChaosSpec(seed=7, upload_fail=1, oom_chunk=3,
                             kill_sweep=2,
                             compile_fail=("pallas_fused", "pallas"),
                             corrupt_blob=True)
    with pytest.raises(ValueError):
        chaos.from_env("explode=1")


def test_chaos_from_env_dist_keys():
    spec = chaos.from_env("exchange_fail=0,device_lost=2,device_lost_n=2,"
                          "dist_transient=1,dist_transient_times=3")
    assert spec == ChaosSpec(exchange_fail=0, device_lost=2,
                             device_lost_n=2, dist_transient=1,
                             dist_transient_times=3)


def test_classify_dist_kinds():
    assert classify(ChaosDeviceLost("gone", lost=2)) == "device_lost"
    assert classify(ChaosExchangeError("x")) == "exchange"
    assert classify(RuntimeError("INTERNAL: device lost")) == "device_lost"
    assert classify(RuntimeError(
        "collective_permute deadline exceeded")) == "exchange"
    assert ChaosDeviceLost("gone", lost=2).lost == 2


def test_ladder_from_env_and_ambient():
    assert ladder.from_env("1") is DEFAULT_POLICY
    assert ladder.from_env("default") is DEFAULT_POLICY
    p = ladder.from_env("max_retries=7,backoff_base_s=0.001")
    assert p.max_retries == 7 and p.backoff_base_s == 0.001
    with pytest.raises(ValueError):
        ladder.from_env("not_a_knob=1")
    prev = ladder.ambient()
    try:
        install_ambient(p)
        assert ladder.ambient() is p
        assert resolve_policy(None) is p      # None defers to ambient
        assert resolve_policy(False) is None  # False stays off
        assert resolve_policy(True) is DEFAULT_POLICY
    finally:
        uninstall_ambient()
        if prev is not None:
            install_ambient(prev)
    assert resolve_policy(None) is prev


def test_chaos_dist_hook_fires_and_counts():
    install(ChaosSpec(exchange_fail=1, device_lost=3, device_lost_n=2))
    cz = chaos.active()
    cz.on_dist_dispatch("xla", exchange="permute", n_dev=4)   # ordinal 0
    with pytest.raises(ChaosExchangeError):                   # ordinal 1
        cz.on_dist_dispatch("xla", exchange="permute", n_dev=4)
    # fired once: the retried dispatch (attempt>0) does not re-raise
    cz.on_dist_dispatch("xla", exchange="permute", n_dev=4, attempt=1)
    cz.on_dist_dispatch("xla", exchange="all_gather", n_dev=4)  # ordinal 2
    with pytest.raises(ChaosDeviceLost) as ei:                  # ordinal 3
        cz.on_dist_dispatch("xla", exchange="all_gather", n_dev=4)
    assert ei.value.lost == 2
    # all_gather dispatches never consume exchange ordinals
    install(ChaosSpec(exchange_fail=0))
    cz = chaos.active()
    cz.on_dist_dispatch("xla", exchange="all_gather", n_dev=4)
    with pytest.raises(ChaosExchangeError):
        cz.on_dist_dispatch("xla", exchange="permute", n_dev=4)


# --------------------------------------------------------------------------
# Snapshot store: roundtrip, fingerprint binding, corrupt quarantine.
# --------------------------------------------------------------------------
def test_snapshot_roundtrip_and_gc(tmp_path):
    store = SnapshotStore(str(tmp_path), keep=2)
    idx, val, dims = _coo()
    fp = fingerprint(idx, val, dims, 5)
    factors = [np.asarray(f) for f in _factors(dims)]
    lam = np.ones(5, np.float32)
    for sweep in (1, 2, 3):
        store.save(fp, sweep, factors, lam, fits=[0.1] * sweep)
    snap = store.latest(fp)
    assert snap is not None and snap.sweep == 3
    assert snap.fingerprint == fp
    for a, b in zip(snap.factors, factors):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(snap.lam, lam)
    assert snap.fits == [0.1, 0.1, 0.1]
    # retention: keep=2 leaves sweeps {2, 3}
    assert len([n for n in os.listdir(tmp_path) if n.endswith(".npz")]) == 2
    # a different problem never resumes from these blobs
    fp2 = fingerprint(idx, val, dims, 6)
    assert store.latest(fp2) is None


def test_factor_shards_reassembly_order():
    full = np.arange(24, dtype=np.float32).reshape(6, 4)

    class _Shard:
        def __init__(self, row0, row1):
            self.index = (slice(row0, row1), slice(None))
            self.data = full[row0:row1]

    class _Sharded:
        shape, dtype = full.shape, full.dtype
        # replicas out of order + duplicated: dedup by row offset
        addressable_shards = [_Shard(3, 6), _Shard(0, 3), _Shard(3, 6)]

    shards = factor_shards(_Sharded())
    assert [r for r, _ in shards] == [0, 3]
    np.testing.assert_array_equal(np.concatenate([d for _, d in shards]),
                                  full)
    # plain host array: one full shard at row 0
    (row0, data), = factor_shards(full)
    assert row0 == 0
    np.testing.assert_array_equal(data, full)


def test_snapshot_sharded_v2_roundtrip(tmp_path):
    from repro.engine.dist import DistConfig
    from repro.launch.mesh import make_mesh

    store = SnapshotStore(str(tmp_path))
    idx, val, dims = _coo()
    fp = fingerprint(idx, val, dims, 5)
    factors = [np.asarray(f) for f in _factors(dims)]
    lam = np.ones(5, np.float32)
    mesh = make_mesh((1,), ("data",))
    dist = DistConfig(exchange="all_gather")
    store.save(fp, 2, factors, lam, fits=[0.5, 0.6], mesh=mesh, dist=dist)
    snap = store.latest(fp)
    assert snap is not None and snap.sweep == 2
    for a, b in zip(snap.factors, factors):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(snap.lam, lam)
    # v2 meta: the saving mesh's fingerprint + DistConfig repr survive
    assert snap.mesh == {"n_dev": 1, "axes": {"data": 1},
                         "platform": "cpu"}
    assert snap.dist == repr(dist)
    # v1 blobs keep loading with no mesh meta
    store.save(fp, 3, factors, lam)
    snap = store.latest(fp)
    assert snap.sweep == 3 and snap.mesh is None and snap.dist is None


def test_snapshot_sharded_v2_multi_shard_load(tmp_path):
    """Multi-shard blobs (as a >1-device mesh writes) reassemble on load —
    exercised host-side with fake sharded arrays."""
    full = np.arange(48, dtype=np.float32).reshape(12, 4)

    class _Shard:
        def __init__(self, row0, row1):
            self.index = (slice(row0, row1), slice(None))
            self.data = full[row0:row1]

    class _Sharded:
        shape, dtype = full.shape, full.dtype
        addressable_shards = [_Shard(0, 6), _Shard(6, 12)]

    class _Mesh:
        devices = np.array(jax.devices()[:1])
        shape = {"data": 1}

    store = SnapshotStore(str(tmp_path))
    fp = "ab" * 32
    store.save(fp, 1, [_Sharded()], np.ones(4, np.float32), mesh=_Mesh())
    snap = store.latest(fp)
    assert snap is not None
    np.testing.assert_array_equal(snap.factors[0], full)


def test_snapshot_corrupt_quarantine_falls_back(tmp_path):
    store = SnapshotStore(str(tmp_path), keep=3)
    idx, val, dims = _coo()
    fp = fingerprint(idx, val, dims, 5)
    factors = [np.asarray(f) for f in _factors(dims)]
    lam = np.ones(5, np.float32)
    store.save(fp, 1, factors, lam)
    newest = store.save(fp, 2, factors, lam)
    with open(newest, "r+b") as f:         # tear the newest blob
        f.truncate(os.path.getsize(newest) // 2)
    snap = store.latest(fp)
    assert snap is not None and snap.sweep == 1   # fell back
    assert store.corrupt == 1
    assert os.path.exists(newest + ".corrupt")


# --------------------------------------------------------------------------
# Checkpoint/resume parity (in-process), resident + streamed.
# --------------------------------------------------------------------------
def test_cp_als_resume_bitwise(tmp_path):
    t = _tensor()
    full = cp_als(t, rank=4, iters=6)
    half = cp_als(t, rank=4, iters=3, checkpoint=str(tmp_path))
    resumed = cp_als(t, rank=4, iters=6, checkpoint=str(tmp_path),
                     resume=True)
    assert resumed.fits[:3] == half.fits
    for a, b in zip(full.factors, resumed.factors):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(full.lam),
                                  np.asarray(resumed.lam))
    assert full.fits == resumed.fits


def test_cp_als_stream_resume_bitwise(tmp_path):
    t = _tensor()
    config = ExecutionConfig(rows_pp=8, chunk_nnz=128)
    full = cp_als_stream(t, rank=4, iters=6, config=config)
    cp_als_stream(t, rank=4, iters=3, config=config,
                  checkpoint=str(tmp_path))
    resumed = cp_als_stream(t, rank=4, iters=6, config=config,
                            checkpoint=str(tmp_path), resume=True)
    for a, b in zip(full.factors, resumed.factors):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert full.fits == resumed.fits


def test_make_engine_resume_shape_guard(tmp_path):
    idx, val, dims = _coo()
    wrong = Snapshot(fingerprint="0" * 64, sweep=1,
                     factors=[np.zeros((d + 1, 4), np.float32)
                              for d in dims],
                     lam=np.ones(4, np.float32), fits=[], path="x")
    with pytest.raises(ValueError, match="does not match this problem"):
        make_engine((idx, val, dims), PlanSpec(), resume=wrong)
    ok = Snapshot(fingerprint="0" * 64, sweep=1,
                  factors=[np.zeros((d, 4), np.float32) for d in dims],
                  lam=np.ones(4, np.float32), fits=[], path="x")
    state = make_engine((idx, val, dims), PlanSpec(), resume=ok)
    assert state is not None


# --------------------------------------------------------------------------
# Kill at sweep k (SIGKILL via REPRO_CHAOS) -> resume -> bitwise parity.
# --------------------------------------------------------------------------
_KILL_SCRIPT = """
import sys
import numpy as np
from repro.core.flycoo import build_flycoo
from repro.core.cpd import cp_als

dims = (29, 23, 19)
rng = np.random.default_rng(0)
idx = np.unique(np.stack([rng.integers(0, d, 300) for d in dims], 1)
                .astype(np.int64), axis=0)
val = rng.standard_normal(len(idx)).astype(np.float32)
t = build_flycoo(idx, val, dims, rows_pp=8)
r = cp_als(t, rank=4, iters=6, checkpoint=sys.argv[1],
           resume=(sys.argv[2] == "resume"))
np.savez(sys.argv[3], *[np.asarray(f) for f in r.factors],
         lam=np.asarray(r.lam), fits=np.asarray(r.fits))
"""


def _run_als_subprocess(ckpt_dir, out, mode, chaos_env=None):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop(chaos.ENV_VAR, None)
    if chaos_env:
        env[chaos.ENV_VAR] = chaos_env
    return subprocess.run(
        [sys.executable, "-c", _KILL_SCRIPT, ckpt_dir, mode, out],
        env=env, capture_output=True, text=True, timeout=600)


def test_kill_sweep_resume_bitwise(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    clean = str(tmp_path / "clean.npz")
    resumed = str(tmp_path / "resumed.npz")
    # uninterrupted reference (fresh process: identical jit environment)
    r = _run_als_subprocess(ckpt + "_unused", clean, "fresh")
    assert r.returncode == 0, r.stderr
    # killed mid-run: SIGKILL at the start of sweep 3
    r = _run_als_subprocess(ckpt, "/dev/null", "fresh",
                            chaos_env="kill_sweep=3")
    assert r.returncode == -signal.SIGKILL
    assert os.listdir(ckpt), "no snapshot survived the kill"
    # resume WITHOUT chaos; must replay sweeps 3..5 bitwise-identically
    r = _run_als_subprocess(ckpt, resumed, "resume")
    assert r.returncode == 0, r.stderr
    with np.load(clean) as a, np.load(resumed) as b:
        for name in a.files:
            np.testing.assert_array_equal(a[name], b[name],
                                          err_msg=name)


# --------------------------------------------------------------------------
# Streamed OOM at chunk k -> budget halving + replan, bitwise parity.
# --------------------------------------------------------------------------
def test_stream_oom_halves_chunk_budget_bitwise():
    idx, val, dims = _coo()
    t = build_flycoo(idx, val, dims, rows_pp=8)
    config = ExecutionConfig(rows_pp=8, chunk_nnz=512)
    factors = _factors(dims)
    ss = stream_init(t, config)
    outs_clean, _ = stream_all_modes(ss, factors)

    install(ChaosSpec(oom_chunk=2))
    ss = stream_init(t, config)
    outs, ss = stream_all_modes(ss, factors, policy=DEFAULT_POLICY)
    for d in range(t.nmodes):
        np.testing.assert_array_equal(np.asarray(outs_clean[d]),
                                      np.asarray(outs[d]),
                                      err_msg=f"mode {d}")
    # the degraded budget sticks on the returned state, and was recorded
    assert ss.config.chunk_nnz is not None
    assert ss.config.chunk_nnz < 512
    degr = obs.REGISTRY.metrics()["resilience_degradations"].as_dict()
    assert any(k.startswith("oom:") and k != "oom:full->stream"
               for k in degr)


def test_stream_oom_without_policy_raises():
    idx, val, dims = _coo()
    t = build_flycoo(idx, val, dims, rows_pp=8)
    install(ChaosSpec(oom_chunk=0))
    ss = stream_init(t, ExecutionConfig(rows_pp=8, chunk_nnz=512))
    with pytest.raises(ChaosOOM):
        stream_all_modes(ss, _factors(dims))


def test_stream_replan_goes_through_plan_cache():
    """The chunk-budget rung's replan is a PlanCache structural-tier
    lookup: same geometry + knobs -> hit, changed chunk budget -> miss."""
    from repro.engine.stream import plan_stream_cached

    idx, val, dims = _coo()
    t = build_flycoo(idx, val, dims, rows_pp=8)
    cache = PlanCache()
    cfg = ExecutionConfig(rows_pp=8, chunk_nnz=256)
    p1 = plan_stream_cached(t, cfg, cache=cache)
    p2 = plan_stream_cached(t, cfg, cache=cache)
    assert p2 is p1
    assert cache.stats()["stream_misses"] == 1
    assert cache.stats()["stream_hits"] == 1
    # a halved budget is a different structural key -> plans once, then hits
    half = ExecutionConfig(rows_pp=8, chunk_nnz=128)
    plan_stream_cached(t, half, cache=cache)
    p4 = plan_stream_cached(t, half, cache=cache)
    assert cache.stats()["stream_misses"] == 2
    assert cache.stats()["stream_hits"] == 2
    assert p4.chunks[0].nchunks >= p1.chunks[0].nchunks
    # cache=False forces a cold replan
    assert plan_stream_cached(t, cfg, cache=False) is not p1


def test_stream_oom_counts_budget_halvings():
    idx, val, dims = _coo()
    t = build_flycoo(idx, val, dims, rows_pp=8)
    install(ChaosSpec(oom_chunk=1))
    ss = stream_init(t, ExecutionConfig(rows_pp=8, chunk_nnz=512))
    _, ss = stream_all_modes(ss, _factors(dims), policy=DEFAULT_POLICY)
    assert ss.stats.budget_halvings >= 1
    row = ss.stats.as_row()
    assert row["budget_halvings"] == ss.stats.budget_halvings
    assert "backend_steps" in row and "upload_retries" in row


def test_plan_spec_ladder_hook():
    """``PlanSpec(ladder=...)`` and the ambient REPRO_LADDER policy both
    feed ``make_engine``'s residency rung without a ``ladder=`` kwarg."""
    idx, val, dims = _coo()
    install(ChaosSpec(oom_resident=True))
    state = make_engine((idx, val, dims), PlanSpec(chunk_nnz=128,
                                                   ladder=True))
    assert isinstance(state, StreamState)
    # ambient policy answers when neither kwarg nor spec opt in
    install(ChaosSpec(oom_resident=True))
    prev = ladder.ambient()
    try:
        install_ambient(DEFAULT_POLICY)
        state = make_engine((idx, val, dims), PlanSpec(chunk_nnz=128))
        assert isinstance(state, StreamState)
        # spec-level False wins over ambient
        install(ChaosSpec(oom_resident=True))
        with pytest.raises(ChaosOOM):
            make_engine((idx, val, dims), PlanSpec(ladder=False))
    finally:
        uninstall_ambient()
        if prev is not None:
            install_ambient(prev)


# --------------------------------------------------------------------------
# Transient upload failure -> retry with backoff, counted, parity.
# --------------------------------------------------------------------------
def test_upload_retry_bitwise_and_counted():
    idx, val, dims = _coo()
    t = build_flycoo(idx, val, dims, rows_pp=8)
    config = ExecutionConfig(rows_pp=8, chunk_nnz=128)
    factors = _factors(dims)
    outs_clean, _ = stream_all_modes(stream_init(t, config), factors)

    install(ChaosSpec(upload_fail=1, upload_fail_times=2))
    policy = LadderPolicy(backoff_base_s=1e-4, backoff_cap_s=1e-3)
    ss = stream_init(t, config)
    outs, ss = stream_all_modes(ss, factors, policy=policy)
    for d in range(t.nmodes):
        np.testing.assert_array_equal(np.asarray(outs_clean[d]),
                                      np.asarray(outs[d]))
    assert ss.stats.upload_retries == 2
    assert ss.stats.as_row()["upload_retries"] == 2


def test_upload_retries_exhausted_raises():
    idx, val, dims = _coo()
    t = build_flycoo(idx, val, dims, rows_pp=8)
    install(ChaosSpec(upload_fail=0, upload_fail_times=10))
    policy = LadderPolicy(max_retries=2, backoff_base_s=1e-4,
                          backoff_cap_s=1e-3)
    ss = stream_init(t, ExecutionConfig(rows_pp=8, chunk_nnz=128))
    with pytest.raises(ChaosUploadError):
        stream_all_modes(ss, _factors(dims), policy=policy)


# --------------------------------------------------------------------------
# Compile failure -> backend ladder, bitwise parity with the landing rung.
# --------------------------------------------------------------------------
def test_backend_ladder_bitwise():
    t = _tensor()
    ref = cp_als(t, rank=4, iters=4, config=ExecutionConfig(backend="xla"))
    install(ChaosSpec(compile_fail=("pallas_fused", "pallas")))
    res = cp_als(t, rank=4, iters=4,
                 config=ExecutionConfig(backend="pallas_fused"),
                 ladder=True)
    for a, b in zip(ref.factors, res.factors):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ref.fits == res.fits
    degr = obs.REGISTRY.metrics()["resilience_degradations"].as_dict()
    assert degr.get("compile:pallas_fused->pallas", 0) >= 1
    assert degr.get("compile:pallas->xla", 0) >= 1


def test_backend_ladder_off_raises():
    t = _tensor()
    install(ChaosSpec(compile_fail=("xla",)))
    with pytest.raises(Exception, match="injected Mosaic"):
        cp_als(t, rank=4, iters=2, config=ExecutionConfig(backend="xla"))


# --------------------------------------------------------------------------
# NaN burst -> rollback + ridge-recovery replay.
# --------------------------------------------------------------------------
def test_nan_rollback_recovers():
    t = _tensor()
    install(ChaosSpec(nan_sweep=2))
    res = cp_als(t, rank=4, iters=5, ladder=True)
    assert all(np.isfinite(np.asarray(f)).all() for f in res.factors)
    assert np.isfinite(np.asarray(res.lam)).all()
    assert len(res.fits) == 5 and np.isfinite(res.fits).all()
    recov = obs.REGISTRY.metrics()["resilience_recoveries"].as_dict()
    assert recov.get("nan_rollback", 0) >= 1


def test_nan_without_ladder_reaches_results():
    t = _tensor()
    install(ChaosSpec(nan_sweep=1))
    res = cp_als(t, rank=4, iters=3)     # no guard without a policy
    # the burst lands in that sweep's fit — nothing rolled it back
    assert np.isnan(res.fits[1])


# --------------------------------------------------------------------------
# PlanCache torn blob -> checksum quarantine + transparent self-heal.
# --------------------------------------------------------------------------
def test_plancache_corrupt_blob_quarantine_and_selfheal(tmp_path):
    idx, val, dims = _coo()
    install(ChaosSpec(corrupt_blob=True))   # tears the first disk save
    c1 = PlanCache(path=str(tmp_path))
    t1 = c1.get_tensor(idx, val, dims, rows_pp=8)
    uninstall()
    # fresh process-equivalent: load meets the torn blob, quarantines,
    # rebuilds cold, re-persists
    c2 = PlanCache(path=str(tmp_path))
    t2 = c2.get_tensor(idx, val, dims, rows_pp=8)
    assert c2.stats()["disk_corrupt"] == 1
    assert any(n.endswith(".corrupt") for n in os.listdir(tmp_path))
    np.testing.assert_array_equal(t1.values, t2.values)
    # self-healed: the third load hits the re-persisted intact blob
    c3 = PlanCache(path=str(tmp_path))
    c3.get_tensor(idx, val, dims, rows_pp=8)
    assert c3.stats()["disk_corrupt"] == 0
    assert c3.stats()["disk_loads"] == 1


# --------------------------------------------------------------------------
# Resident-placement OOM -> streaming fallback (factory rung).
# --------------------------------------------------------------------------
def test_factory_resident_oom_falls_back_to_stream():
    idx, val, dims = _coo()
    install(ChaosSpec(oom_resident=True))
    state = make_engine((idx, val, dims), PlanSpec(chunk_nnz=128),
                        ladder=True)
    assert isinstance(state, StreamState)
    degr = obs.REGISTRY.metrics()["resilience_degradations"].as_dict()
    assert degr.get("oom:full->stream", 0) >= 1


def test_factory_resident_oom_without_ladder_raises():
    idx, val, dims = _coo()
    install(ChaosSpec(oom_resident=True))
    with pytest.raises(ChaosOOM):
        make_engine((idx, val, dims), PlanSpec())


# --------------------------------------------------------------------------
# resilience_report: every injected fault pairs with an answering event.
# --------------------------------------------------------------------------
def test_resilience_report_pairs_all_injections(tmp_path):
    obs.REGISTRY.reset()     # pair THIS run's faults, not the session's
    idx, val, dims = _coo()
    t = build_flycoo(idx, val, dims, rows_pp=8)
    install(ChaosSpec(upload_fail=1, oom_chunk=4, nan_sweep=1))
    cp_als_stream(t, rank=4, iters=3,
                  config=ExecutionConfig(rows_pp=8, chunk_nnz=512),
                  ladder=LadderPolicy(backoff_base_s=1e-4,
                                      backoff_cap_s=1e-3),
                  checkpoint=str(tmp_path))
    rep = obs.resilience_report()
    for site in ("upload_fail", "oom_chunk", "nan_burst"):
        assert site in rep["injections"]
        assert site in rep["answered"]
    assert rep["unanswered"] == []


def test_resilience_report_flags_silent_faults():
    obs.REGISTRY.reset()
    install(ChaosSpec(nan_sweep=0))
    t = _tensor(seed=3)
    cp_als(t, rank=4, iters=2)          # no ladder: burst goes unanswered
    rep = obs.resilience_report()
    assert "nan_burst" in rep["unanswered"]
