"""Per-arch smoke tests (reduced configs): forward/train step, decode parity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import decode_step, forward, init_cache, init_model
from repro.training import (OptimizerConfig, SyntheticLM, init_state,
                            make_train_step)

ALL_ARCHS = list(configs.ARCHS)


def _inputs(cfg, rng, b, s):
    kw = {}
    if cfg.kind == "vlm":
        kw["embeds"] = jax.random.normal(
            rng, (b, cfg.n_img_tokens, cfg.d_model), cfg.cdtype)
        toks = jax.random.randint(rng, (b, s - cfg.n_img_tokens), 0,
                                  cfg.vocab)
    elif cfg.kind == "audio":
        kw["enc_embeds"] = jax.random.normal(rng, (b, s, cfg.d_model),
                                             cfg.cdtype)
        toks = jax.random.randint(rng, (b, s), 0, cfg.vocab)
    else:
        toks = jax.random.randint(rng, (b, s), 0, cfg.vocab)
    return toks, kw


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_shapes_no_nan(arch):
    cfg = configs.smoke(arch)
    rng = jax.random.PRNGKey(0)
    params = init_model(cfg, rng)
    b, s = 2, 32
    toks, kw = _inputs(cfg, rng, b, s)
    logits = forward(params, cfg, tokens=toks, **kw)
    assert logits.shape == (b, s if cfg.kind != "vlm" else s,
                            cfg.vocab_padded)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch):
    cfg = configs.smoke(arch)
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    state = init_state(cfg, ocfg, jax.random.PRNGKey(0))
    data = SyntheticLM(cfg, batch=2, seq=32)
    step = jax.jit(make_train_step(cfg, ocfg))
    l0 = None
    for _ in range(3):
        state, metrics = step(state, data.next())
        loss = float(metrics["loss"])
        assert np.isfinite(loss)
        l0 = loss if l0 is None else l0
    assert int(state["step"]) == 3
    assert float(metrics["grad_norm"]) > 0


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "qwen2.5-3b",
                                  "command-r-plus-104b", "olmo-1b",
                                  "rwkv6-3b", "recurrentgemma-9b"])
def test_decode_matches_forward(arch):
    cfg = configs.smoke(arch)
    rng = jax.random.PRNGKey(0)
    params = init_model(cfg, rng)
    b, s = 2, 16
    toks = jax.random.randint(rng, (b, s), 0, cfg.vocab)
    ref = forward(params, cfg, tokens=toks).astype(jnp.float32)
    cache = init_cache(cfg, b, s)
    outs = []
    for t in range(s):
        lg, cache = decode_step(params, cache, cfg, toks[:, t:t + 1])
        outs.append(lg.astype(jnp.float32))
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(dec, ref, rtol=2e-2, atol=2e-2)


def test_moe_decode_matches_forward_without_drops():
    """MoE decode == forward when capacity can't drop (documented
    capacity-semantics difference otherwise)."""
    cfg = dataclasses.replace(configs.smoke("olmoe-1b-7b"),
                              capacity_factor=16.0)
    rng = jax.random.PRNGKey(0)
    params = init_model(cfg, rng)
    b, s = 2, 16
    toks = jax.random.randint(rng, (b, s), 0, cfg.vocab)
    ref = forward(params, cfg, tokens=toks).astype(jnp.float32)
    cache = init_cache(cfg, b, s)
    outs = []
    for t in range(s):
        lg, cache = decode_step(params, cache, cfg, toks[:, t:t + 1])
        outs.append(lg.astype(jnp.float32))
    np.testing.assert_allclose(jnp.concatenate(outs, 1), ref, rtol=2e-2,
                               atol=2e-2)


def test_window_attention_restricts_context():
    """Sliding-window layers must ignore tokens beyond the window."""
    arch = "recurrentgemma-9b"
    cfg = dataclasses.replace(
        configs.smoke(arch), block_pattern=("local",), n_layers=2, window=4)
    rng = jax.random.PRNGKey(0)
    params = init_model(cfg, rng)
    toks = jax.random.randint(rng, (1, 24), 0, cfg.vocab)
    base = forward(params, cfg, tokens=toks)
    # perturb a token far outside the window of the last position
    toks2 = toks.at[0, 2].set((toks[0, 2] + 1) % cfg.vocab)
    pert = forward(params, cfg, tokens=toks2)
    last_diff = float(jnp.max(jnp.abs(
        (base - pert)[0, -1].astype(jnp.float32))))
    assert last_diff == 0.0, "token outside window leaked into attention"


def test_param_count_formula_close_to_actual():
    for arch in ALL_ARCHS:
        cfg = configs.smoke(arch)
        params = init_model(cfg, jax.random.PRNGKey(0))
        actual = sum(x.size for x in jax.tree.leaves(params))
        est = cfg.param_count()
        assert abs(est - actual) / actual < 0.35, (arch, est, actual)


def test_full_config_param_counts():
    """Analytic param counts of the assigned configs are in the right
    ballpark of their nameplates."""
    expect = {
        "command-r-plus-104b": 104e9,
        "qwen3-moe-235b-a22b": 235e9,
        "olmoe-1b-7b": 7e9,
        "tinyllama-1.1b": 1.1e9,
        "rwkv6-3b": 3e9,
        "recurrentgemma-9b": 9e9,
    }
    for arch, n in expect.items():
        got = configs.get_config(arch).param_count()
        assert 0.6 * n < got < 1.5 * n, (arch, got, n)
