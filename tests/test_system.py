"""End-to-end behaviour tests for the paper's system (FLYCOO + CPD-ALS)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cp_als, datasets


@pytest.mark.parametrize("name", ["amazon", "music", "nell1", "vast"])
def test_paper_dataset_family_cpd(name):
    """CPD-ALS runs on scaled synthetics of every paper dataset family and
    improves fit (Table 3 shapes, Zipf nonzero distribution)."""
    t = datasets.load(name, scale=2e-4, max_nnz=20_000)
    res = cp_als(t, rank=4, iters=3)
    assert all(np.isfinite(f) for f in res.fits)
    assert res.fits[-1] >= res.fits[0] - 1e-3


def test_five_mode_tensors_supported():
    """Twitch/Vast are 5-mode — the paper's headline vs BLCO/MM-CSF."""
    for name in ("twitch", "vast"):
        t = datasets.load(name, scale=1e-4, max_nnz=8_000)
        assert t.nmodes == 5
        res = cp_als(t, rank=3, iters=2)
        assert all(np.isfinite(f) for f in res.fits)


def test_load_balance_on_skewed_data():
    """Degree-sorted cyclic partitioning keeps partitions within the
    round-robin bound (mean + d_max) on Zipf-skewed synthetics (paper
    Sec. 3.4.1 regime)."""
    import numpy as np

    t = datasets.load("nell1", scale=5e-4, max_nnz=30_000)
    for d, bal in enumerate(t.load_balance()):
        d_max = np.bincount(t.indices[:, d], minlength=t.dims[d]).max()
        assert bal["max"] <= bal["mean"] + d_max + 1, (d, bal)


def test_remap_roundtrip_preserves_elements():
    """After a full sweep of dynamic remapping the layout returns to mode 0
    with exactly the original element multiset."""
    from repro.core import MTTKRPExecutor, init_factors

    t = datasets.load("music", scale=2e-4, max_nnz=10_000)
    exe = MTTKRPExecutor(t)
    before = np.sort(np.asarray(exe.layout["val"]))
    factors = init_factors(jax.random.PRNGKey(0), t.dims, 4)
    exe.all_modes(factors)
    after = np.sort(np.asarray(exe.layout["val"]))
    np.testing.assert_array_equal(before, after)
    assert exe.current_mode == 0


def test_single_tensor_copy_invariant():
    """Mode-agnostic: the executor holds ONE live layout (plus the remap
    target inside the jit), never N mode-specific copies."""
    from repro.core import MTTKRPExecutor, init_factors

    t = datasets.load("vast", scale=1e-3, max_nnz=5_000)
    exe = MTTKRPExecutor(t)
    factors = init_factors(jax.random.PRNGKey(0), t.dims, 4)
    exe.step(factors)
    assert set(exe.layout.keys()) == {"val", "idx", "alpha"}
    live = exe.layout["val"].size
    assert live == t.plans[exe.current_mode].padded_nnz
