"""Serving engine: generation across families, cache semantics."""
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import init_model
from repro.serving import Engine, ServeConfig


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "olmoe-1b-7b",
                                  "recurrentgemma-9b", "rwkv6-3b",
                                  "paligemma-3b"])
def test_generate_shapes(arch):
    cfg = configs.smoke(arch)
    params = init_model(cfg, jax.random.PRNGKey(0))
    eng = Engine(params, cfg, ServeConfig(batch=2, max_len=64))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, cfg.vocab)
    out = eng.generate(prompt, 5)
    assert out.shape == (2, 5)
    assert int(out.max()) < cfg.vocab


def test_whisper_requires_encoder_input():
    cfg = configs.smoke("whisper-large-v3")
    params = init_model(cfg, jax.random.PRNGKey(0))
    with pytest.raises(AssertionError):
        Engine(params, cfg, ServeConfig(batch=1, max_len=32))


def test_whisper_generation_uses_encoder_memory():
    cfg = configs.smoke("whisper-large-v3")
    key = jax.random.PRNGKey(0)
    params = init_model(cfg, key)
    enc1 = jax.random.normal(key, (1, 16, cfg.d_model), cfg.cdtype)
    enc2 = enc1 + 1.0
    tok = jnp.zeros((1, 1), jnp.int32)
    e1 = Engine(params, cfg, ServeConfig(batch=1, max_len=32),
                enc_embeds=enc1)
    e2 = Engine(params, cfg, ServeConfig(batch=1, max_len=32),
                enc_embeds=enc2)
    o1, o2 = e1.prefill(tok), e2.prefill(tok)
    assert float(jnp.max(jnp.abs(
        o1.astype(jnp.float32) - o2.astype(jnp.float32)))) > 0


def test_greedy_is_deterministic():
    cfg = configs.smoke("tinyllama-1.1b")
    params = init_model(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0, cfg.vocab)
    outs = []
    for _ in range(2):
        eng = Engine(params, cfg, ServeConfig(batch=2, max_len=32))
        outs.append(eng.generate(prompt, 6))
    assert jnp.array_equal(outs[0], outs[1])


def test_long_context_state_size_constant():
    """SSM/hybrid caches don't grow with max_len (the long_500k property)."""
    cfg = configs.smoke("rwkv6-3b")
    from repro.models import init_cache
    c1 = init_cache(cfg, 1, 64)
    c2 = init_cache(cfg, 1, 4096)
    s1 = sum(x.size for x in jax.tree.leaves(c1))
    s2 = sum(x.size for x in jax.tree.leaves(c2))
    assert s1 == s2

    cfg = configs.smoke("recurrentgemma-9b")
    c1 = init_cache(cfg, 1, 64)
    c2 = init_cache(cfg, 1, 4096)
    s1 = sum(x.size for x in jax.tree.leaves(c1))
    s2 = sum(x.size for x in jax.tree.leaves(c2))
    # only the (bounded) local-attention window grows, capped at cfg.window
    assert s2 <= s1 * (cfg.window / 16 + 1)


def test_kv_quant_decode_close_to_exact():
    """int8 KV cache (kv_quant): decode stays within quantization noise."""
    import dataclasses
    from repro.models import decode_step, forward, init_cache, init_model
    import jax.numpy as jnp

    cfg = configs.smoke("tinyllama-1.1b")
    cfgq = dataclasses.replace(cfg, kv_quant=True)
    params = init_model(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    ref = forward(params, cfg, tokens=toks).astype(jnp.float32)
    cache = init_cache(cfgq, 2, 12)
    outs = []
    for t in range(12):
        lg, cache = decode_step(params, cache, cfgq, toks[:, t:t + 1])
        outs.append(lg.astype(jnp.float32))
    dec = jnp.concatenate(outs, 1)
    rel = float(jnp.max(jnp.abs(dec - ref))) / (
        float(jnp.max(jnp.abs(ref))) + 1e-9)
    assert rel < 0.15, rel
    # and the cache really is int8
    k = cache["stage0"]["b0"]["k"]
    assert k.dtype == jnp.int8
