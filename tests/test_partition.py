"""Property tests for the paper's partitioning scheme (Alg. 1, Obs. 1/2)."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # container lacks hypothesis: skip only these
    from conftest import given, settings, st

from repro.core.partition import (plan_from_structure, plan_mode,
                                  plan_mode_reference)
from repro.core.flycoo import build_flycoo


def _random_coo(rng, dims, nnz):
    idx = np.stack([rng.integers(0, d, nnz) for d in dims], 1)
    idx = np.unique(idx.astype(np.int32), axis=0)
    val = rng.standard_normal(idx.shape[0]).astype(np.float32)
    return idx, val


@settings(max_examples=25, deadline=None)
@given(dim=st.integers(4, 200), nnz=st.integers(10, 2000),
       kappa=st.integers(1, 16), seed=st.integers(0, 999),
       schedule=st.sampled_from(["compact", "rect"]))
def test_remap_ids_are_unique(dim, nnz, kappa, seed, schedule):
    """Observation 1: remap ids are unique per mode => scatter conflict-free."""
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, dim, nnz).astype(np.int64)
    plan = plan_mode(idx, dim, 0, kappa=kappa, schedule=schedule)
    slots = plan.slot_of_elem
    assert len(np.unique(slots)) == len(slots)
    assert slots.max() < plan.padded_nnz


@settings(max_examples=25, deadline=None)
@given(dim=st.integers(4, 200), nnz=st.integers(10, 2000),
       kappa=st.integers(1, 16), seed=st.integers(0, 999),
       schedule=st.sampled_from(["compact", "rect"]))
def test_row_ownership(dim, nnz, kappa, seed, schedule):
    """Observation 2: all elements of a row land in that row's partition
    (the owning partition is the block descriptor lookup — which under
    ``rect`` must agree with the fixed slot stride)."""
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, dim, nnz).astype(np.int64)
    plan = plan_mode(idx, dim, 0, kappa=kappa, schedule=schedule)
    part_of_elem = plan.block_part[plan.slot_of_elem // plan.block_p]
    part_of_row = plan.row_relabel // plan.rows_pp
    np.testing.assert_array_equal(part_of_elem, part_of_row[idx])
    if schedule == "rect":
        stride = plan.blocks_pp * plan.block_p
        np.testing.assert_array_equal(part_of_elem,
                                      plan.slot_of_elem // stride)


@settings(max_examples=25, deadline=None)
@given(dim=st.integers(4, 300), seed=st.integers(0, 999),
       kappa=st.integers(1, 16))
def test_relabel_is_injective(dim, seed, kappa):
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, dim, 500).astype(np.int64)
    plan = plan_mode(idx, dim, 0, kappa=kappa)
    assert len(np.unique(plan.row_relabel)) == dim
    assert plan.row_relabel.max() < plan.relabeled_rows


@settings(max_examples=20, deadline=None)
@given(dim=st.integers(16, 400), nnz=st.integers(200, 5000),
       kappa=st.integers(2, 16), seed=st.integers(0, 99),
       zipf_a=st.floats(1.1, 3.0))
def test_load_balance_bound(dim, nnz, kappa, seed, zipf_a):
    """Paper Sec. 3.4.1 cites Graham's 4/3 (LPT). The cyclic deal over
    degree-sorted vertices is round-robin, whose provable makespan bound is
    ``mean + d_max`` (each partition exceeds the mean by at most one
    first-round item); note d_max <= OPT, so this is <= 2*OPT and equals
    the 4/3 regime whenever d_max <= OPT/3 (the common sparse case)."""
    rng = np.random.default_rng(seed)
    raw = rng.zipf(zipf_a, nnz)
    idx = ((raw - 1) % dim).astype(np.int64)
    plan = plan_mode(idx, dim, 0, kappa=kappa)
    loads = plan.part_nnz
    degrees = np.bincount(idx, minlength=dim)
    mean = loads.sum() / plan.kappa
    assert loads.max() <= mean + degrees.max() + 1
    # and in the paper's regime (no dominating vertex) the 4/3 holds
    opt_lb = max(mean, degrees.max())
    if degrees.max() <= mean / 3:
        assert loads.max() <= (4.0 / 3.0) * opt_lb + plan.kappa


def test_memory_formula_matches_paper():
    """Sec. 3.5.1: bits/elem = N log2|X| + sum log2 I_h + 32."""
    rng = np.random.default_rng(0)
    dims = (64, 32, 16)
    idx, val = _random_coo(rng, dims, 500)
    t = build_flycoo(idx, val, dims)
    import math
    expected = 3 * math.log2(t.nnz) + sum(math.log2(d) for d in dims) + 32
    assert abs(t.memory_bits_per_element() - expected) < 1e-9


@pytest.mark.parametrize("nmodes", [3, 4, 5])
def test_high_mode_support(nmodes):
    """Sec. 5.6: >4-mode tensors are supported (unlike BLCO/MM-CSF)."""
    rng = np.random.default_rng(1)
    dims = tuple(rng.integers(8, 40, nmodes))
    idx, val = _random_coo(rng, dims, 800)
    t = build_flycoo(idx, val, dims, rows_pp=8, block_p=16)
    assert t.nmodes == nmodes
    assert all(p.kappa >= 1 for p in t.plans)


# --------------------------------------------------------------------------
# Compact block schedule + load-balance reporting + dedup tables.
# --------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(dim=st.integers(8, 300), nnz=st.integers(20, 3000),
       kappa=st.integers(1, 16), seed=st.integers(0, 999),
       zipf_a=st.floats(1.1, 3.0))
def test_compact_padded_leq_rect(dim, nnz, kappa, seed, zipf_a):
    """The compact schedule never uses more slots than the rectangular
    one, with equality exactly when every partition needs the same block
    count (balanced partitions)."""
    rng = np.random.default_rng(seed)
    raw = rng.zipf(zipf_a, nnz)
    idx = ((raw - 1) % dim).astype(np.int64)
    compact = plan_mode(idx, dim, 0, kappa=kappa, schedule="compact")
    rect = plan_mode(idx, dim, 0, kappa=kappa, schedule="rect")
    assert compact.padded_nnz <= rect.padded_nnz
    blocks = np.maximum(1, np.ceil(compact.part_nnz / compact.block_p))
    balanced = blocks.min() == blocks.max()
    assert (compact.padded_nnz == rect.padded_nnz) == balanced
    # both schedules describe the same partition assignment
    np.testing.assert_array_equal(compact.part_nnz, rect.part_nnz)
    # descriptor invariants: nondecreasing, every partition >= 1 block
    assert (np.diff(compact.block_part) >= 0).all()
    assert len(np.unique(compact.block_part)) == compact.kappa


def test_load_balance_reports_opt_lower_bound():
    """The documented bound is OPT >= max(mean, d_max): with one dominant
    vertex the max/mean ratio explodes, but the achieved-vs-OPT imbalance
    must stay ~1 (no schedule can split a single vertex's hyperedges)."""
    dim, kappa = 64, 8
    idx = np.concatenate([np.zeros(1000, np.int64),
                          np.arange(1, dim, dtype=np.int64)])
    plan = plan_mode(idx, dim, 0, kappa=kappa)
    lb = plan.load_balance()
    assert lb["max_degree"] == 1000
    assert lb["opt_lower_bound"] == max(lb["mean"], 1000.0)
    assert lb["imbalance"] == pytest.approx(lb["max"] / 1000.0)
    assert lb["imbalance"] <= 1.01           # dominated by the hot vertex
    assert lb["imbalance_vs_mean"] > 5.0     # the old ratio overstates it


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 999), zipf_a=st.floats(1.1, 2.5),
       block_p=st.sampled_from([8, 16, 32]))
def test_dedup_tables_reconstruct_rows(seed, zipf_a, block_p):
    """uidx/upos/nuniq invariants: every alive slot's factor row is
    ``uidx[block, upos]``; per block the uniques are exactly the distinct
    rows, counted by nuniq; dedup row copies never exceed per-slot ones."""
    from repro.core import datasets

    t = datasets.zipf_tensor((40, 30, 20), 900, a=zipf_a, seed=seed,
                             rows_pp=8, block_p=block_p)
    for d in range(t.nmodes):
        plan = t.plans[d]
        uidx, upos, nuniq = t.dedup_tables(d)
        in_modes = [w for w in range(t.nmodes) if w != d]
        slots = plan.slot_of_elem
        blocks = slots // plan.block_p
        for k, w in enumerate(in_modes):
            rows = t.indices[:, w].astype(np.int64)
            # reconstruction: slot's row == unique table at its position
            got = uidx[k, blocks * plan.block_p + upos[slots, k]]
            np.testing.assert_array_equal(got, rows)
            # per-block unique counts match the distinct row counts
            for b in np.unique(blocks):
                mask = blocks == b
                assert nuniq[k, b] == len(np.unique(rows[mask]))
            assert int(nuniq[k].sum()) <= plan.nblocks * plan.block_p


@settings(max_examples=25, deadline=None)
@given(dim=st.integers(4, 300), nnz=st.integers(10, 3000),
       kappa=st.integers(1, 16), seed=st.integers(0, 999),
       schedule=st.sampled_from(["compact", "rect"]),
       block_p=st.sampled_from([8, 32, 128]),
       zipf_a=st.floats(1.1, 3.0))
def test_vectorized_plan_bitwise_matches_reference(dim, nnz, kappa, seed,
                                                   schedule, block_p,
                                                   zipf_a):
    """The vectorized cold path produces bitwise-identical plans to the
    pre-autotuner reference implementation (narrow sort keys preserve
    every stable-sort comparison), and rebuilding a permuted element
    list from cached structure equals a cold plan of that list."""
    rng = np.random.default_rng(seed)
    raw = rng.zipf(zipf_a, nnz)
    idx = ((raw - 1) % dim).astype(np.int64)
    new = plan_mode(idx, dim, 0, kappa=kappa, schedule=schedule,
                    block_p=block_p)
    ref = plan_mode_reference(idx, dim, 0, kappa=kappa, schedule=schedule,
                              block_p=block_p)
    assert (new.kappa, new.rows_pp, new.blocks_pp, new.nblocks,
            new.max_degree) == (ref.kappa, ref.rows_pp, ref.blocks_pp,
                                ref.nblocks, ref.max_degree)
    np.testing.assert_array_equal(new.row_relabel, ref.row_relabel)
    np.testing.assert_array_equal(new.slot_of_elem, ref.slot_of_elem)
    np.testing.assert_array_equal(new.part_nnz, ref.part_nnz)
    np.testing.assert_array_equal(new.block_part, ref.block_part)
    # structure reuse on a reordered element list == cold plan of it
    perm = rng.permutation(nnz)
    rebuilt = plan_from_structure(idx[perm], new)
    cold = plan_mode(idx[perm], dim, 0, kappa=kappa, schedule=schedule,
                     block_p=block_p)
    np.testing.assert_array_equal(rebuilt.slot_of_elem, cold.slot_of_elem)
    assert rebuilt.row_relabel is new.row_relabel  # shared, not copied


def test_dma_row_model_dedups_hot_rows():
    """On a skewed tensor the modeled dedup DMA rows are far below the
    per-slot count (the hot-row re-fetch factor the kernel removes)."""
    from repro.core import datasets

    t = datasets.zipf_tensor((300, 200, 100), 20_000, a=1.5, seed=0,
                             block_p=128)
    m = t.dma_row_model(0)
    assert m["dedup_rows"] < m["per_slot_rows"]
    assert m["dedup_reduction_x"] >= 2.0
