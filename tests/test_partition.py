"""Property tests for the paper's partitioning scheme (Alg. 1, Obs. 1/2)."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # container lacks hypothesis: skip only these
    from conftest import given, settings, st

from repro.core.partition import plan_mode
from repro.core.flycoo import build_flycoo


def _random_coo(rng, dims, nnz):
    idx = np.stack([rng.integers(0, d, nnz) for d in dims], 1)
    idx = np.unique(idx.astype(np.int32), axis=0)
    val = rng.standard_normal(idx.shape[0]).astype(np.float32)
    return idx, val


@settings(max_examples=25, deadline=None)
@given(dim=st.integers(4, 200), nnz=st.integers(10, 2000),
       kappa=st.integers(1, 16), seed=st.integers(0, 999))
def test_remap_ids_are_unique(dim, nnz, kappa, seed):
    """Observation 1: remap ids are unique per mode => scatter conflict-free."""
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, dim, nnz).astype(np.int64)
    plan = plan_mode(idx, dim, 0, kappa=kappa)
    slots = plan.slot_of_elem
    assert len(np.unique(slots)) == len(slots)
    assert slots.max() < plan.padded_nnz


@settings(max_examples=25, deadline=None)
@given(dim=st.integers(4, 200), nnz=st.integers(10, 2000),
       kappa=st.integers(1, 16), seed=st.integers(0, 999))
def test_row_ownership(dim, nnz, kappa, seed):
    """Observation 2: all elements of a row land in that row's partition."""
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, dim, nnz).astype(np.int64)
    plan = plan_mode(idx, dim, 0, kappa=kappa)
    stride = plan.blocks_pp * plan.block_p
    part_of_elem = plan.slot_of_elem // stride
    part_of_row = plan.row_relabel // plan.rows_pp
    np.testing.assert_array_equal(part_of_elem, part_of_row[idx])


@settings(max_examples=25, deadline=None)
@given(dim=st.integers(4, 300), seed=st.integers(0, 999),
       kappa=st.integers(1, 16))
def test_relabel_is_injective(dim, seed, kappa):
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, dim, 500).astype(np.int64)
    plan = plan_mode(idx, dim, 0, kappa=kappa)
    assert len(np.unique(plan.row_relabel)) == dim
    assert plan.row_relabel.max() < plan.relabeled_rows


@settings(max_examples=20, deadline=None)
@given(dim=st.integers(16, 400), nnz=st.integers(200, 5000),
       kappa=st.integers(2, 16), seed=st.integers(0, 99),
       zipf_a=st.floats(1.1, 3.0))
def test_load_balance_bound(dim, nnz, kappa, seed, zipf_a):
    """Paper Sec. 3.4.1 cites Graham's 4/3 (LPT). The cyclic deal over
    degree-sorted vertices is round-robin, whose provable makespan bound is
    ``mean + d_max`` (each partition exceeds the mean by at most one
    first-round item); note d_max <= OPT, so this is <= 2*OPT and equals
    the 4/3 regime whenever d_max <= OPT/3 (the common sparse case)."""
    rng = np.random.default_rng(seed)
    raw = rng.zipf(zipf_a, nnz)
    idx = ((raw - 1) % dim).astype(np.int64)
    plan = plan_mode(idx, dim, 0, kappa=kappa)
    loads = plan.part_nnz
    degrees = np.bincount(idx, minlength=dim)
    mean = loads.sum() / plan.kappa
    assert loads.max() <= mean + degrees.max() + 1
    # and in the paper's regime (no dominating vertex) the 4/3 holds
    opt_lb = max(mean, degrees.max())
    if degrees.max() <= mean / 3:
        assert loads.max() <= (4.0 / 3.0) * opt_lb + plan.kappa


def test_memory_formula_matches_paper():
    """Sec. 3.5.1: bits/elem = N log2|X| + sum log2 I_h + 32."""
    rng = np.random.default_rng(0)
    dims = (64, 32, 16)
    idx, val = _random_coo(rng, dims, 500)
    t = build_flycoo(idx, val, dims)
    import math
    expected = 3 * math.log2(t.nnz) + sum(math.log2(d) for d in dims) + 32
    assert abs(t.memory_bits_per_element() - expected) < 1e-9


@pytest.mark.parametrize("nmodes", [3, 4, 5])
def test_high_mode_support(nmodes):
    """Sec. 5.6: >4-mode tensors are supported (unlike BLCO/MM-CSF)."""
    rng = np.random.default_rng(1)
    dims = tuple(rng.integers(8, 40, nmodes))
    idx, val = _random_coo(rng, dims, 800)
    t = build_flycoo(idx, val, dims, rows_pp=8, block_p=16)
    assert t.nmodes == nmodes
    assert all(p.kappa >= 1 for p in t.plans)
