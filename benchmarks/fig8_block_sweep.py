"""Paper Fig. 8: thread-block shape sweep (P = nonzeros per block).

The paper sweeps P in {1..64} at R = 32 and finds P = 32 optimal for a
1024-thread block. The TPU analogue sweeps the kernel block P over
{8..256}: P sets the MXU contraction depth of the one-hot segment
reduction and the padding overhead of the rectangular layout. We report
wall time of the (XLA-lowered) blocked EC per P plus the analytic VMEM
footprint per block — the structural argument for the default P = 128
(one sublane tile).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import datasets, init_factors
from repro.core.mttkrp import MTTKRPExecutor, compute_lrow, _ec_xla
from repro.core.flycoo import build_flycoo

from .common import RANK, emit, time_fn


def run():
    rows = []
    name = "nell1"
    ts = datasets.spec(name, scale=3e-4, max_nnz=60_000)
    idx, val = datasets.synthesize(ts, seed=0)
    for p in (8, 16, 32, 64, 128, 256):
        t = build_flycoo(idx, val, ts.dims, block_p=p)
        plan = t.plans[0]
        exe = MTTKRPExecutor(t)
        factors = tuple(init_factors(jax.random.PRNGKey(0), t.dims, RANK))
        rr = exe.row_relabel[0]

        @jax.jit
        def ec(layout, f, rr, plan=plan):
            alive = layout["alpha"][:, 0] >= 0
            lrow = compute_lrow(layout["idx"][:, 0], rr, plan.rows_pp, alive)
            return _ec_xla({"val": layout["val"], "idx": layout["idx"],
                            "lrow": lrow}, f, 0, rows_pp=plan.rows_pp,
                           blocks_pp=plan.blocks_pp, block_p=plan.block_p,
                           kappa=plan.kappa)

        wall = time_fn(ec, exe.layout, factors, rr)
        pad = plan.padded_nnz / t.nnz
        # kernel VMEM/block: gathered (P, N-1, R) + out tile (rows_pp, R) f32
        vmem_kb = (p * (t.nmodes - 1) * RANK + plan.rows_pp * RANK) * 4 / 1024
        rows.append((f"fig8_block_sweep/P={p}", wall * 1e6,
                     f"padding_overhead={pad:.3f};vmem_per_block_kb="
                     f"{vmem_kb:.0f}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
