"""Paper Fig. 8: thread-block shape sweep + block-schedule comparison.

The paper sweeps P in {1..64} at R = 32 and finds P = 32 optimal for a
1024-thread block. The TPU analogue sweeps the kernel block P over
{8..256}: P sets the MXU contraction depth of the one-hot segment
reduction and the padding overhead of the block layout. We report wall
time of the scanned engine rotation per P plus the analytic VMEM
footprint per block — the structural argument for the default P = 128
(one sublane tile).

On top of the P sweep, this figure records the *block-schedule* numbers
the compact-grid work is gated on (paper challenge (3), load balance):
per dataset — including the skewed first-class ``zipf`` tensor —

  pad_slots_reduction_x   sum_d S_d under ``rect`` / under ``compact``
  dma_rows_reduction_x    per-slot factor-row DMA copies / after in-block
                          dedup (sum of per-block unique rows)
  pad_block_fraction      fraction of all-pad kernel blocks, per schedule
  imbalance               achieved max load vs the OPT lower bound
                          ``max(mean, d_max)`` (mode-0 plan)

all merged into ``benchmarks/out/results.json`` (CI gates the zipf
reductions at >= 2x).
"""
from __future__ import annotations

import jax

from repro.core import datasets, init_factors
from repro.core.flycoo import build_flycoo
from repro import engine

from .common import BENCH_DATASETS, RANK, emit, load_bench_tensor, time_fn


def _schedule_rows(names):
    # Partition-exercising tile knobs: the default 512-row VMEM tile
    # collapses benchmark-scale tensors to kappa == 1 (one partition, no
    # schedule difference to measure); 8-row tiles give tens of partitions.
    tile = dict(rows_pp=8, block_p=32)
    rows = []
    for name in names:
        t_c = load_bench_tensor(name, schedule="compact", **tile)
        t_r = load_bench_tensor(name, schedule="rect", **tile)
        pad_c = sum(p.padded_nnz for p in t_c.plans)
        pad_r = sum(p.padded_nnz for p in t_r.plans)
        models = [t_c.dma_row_model(d) for d in range(t_c.nmodes)]
        per_slot = sum(m["per_slot_rows"] for m in models)
        dedup = sum(m["dedup_rows"] for m in models)
        lb = t_c.plans[0].load_balance()
        extras = {
            "schedule_compact_slots": pad_c,
            "schedule_rect_slots": pad_r,
            "pad_slots_reduction_x": round(pad_r / max(pad_c, 1), 2),
            "dma_rows_per_slot": per_slot,
            "dma_rows_dedup": dedup,
            "dma_rows_reduction_x": round(per_slot / max(dedup, 1), 2),
            "pad_block_fraction": {
                "compact": round(
                    sum(p.pad_block_fraction for p in t_c.plans)
                    / t_c.nmodes, 4),
                "rect": round(
                    sum(p.pad_block_fraction for p in t_r.plans)
                    / t_r.nmodes, 4),
            },
            "imbalance_vs_opt": round(lb["imbalance"], 3),
            "imbalance_vs_mean": round(lb["imbalance_vs_mean"], 3),
        }
        rows.append((
            f"fig8_block_sweep/schedule_{name}", 0.0,
            f"pad_slots_reduction={extras['pad_slots_reduction_x']:.2f}x;"
            f"dma_rows_reduction={extras['dma_rows_reduction_x']:.2f}x;"
            f"imbalance={extras['imbalance_vs_opt']:.2f}",
            extras))
    return rows


def run():
    rows = []
    # --- block-schedule comparison (zipf always included: the skewed
    #     stress tensor the compact schedule + dedup are gated on) -------
    names = list(dict.fromkeys(["zipf", *BENCH_DATASETS]))
    rows += _schedule_rows(names)

    # --- P sweep on the compact schedule (scanned engine rotation) -----
    name = "nell1"
    ts = datasets.spec(name, scale=3e-4, max_nnz=60_000)
    idx, val = datasets.synthesize(ts, seed=0)
    for p in (8, 16, 32, 64, 128, 256):
        t = build_flycoo(idx, val, ts.dims, block_p=p)
        plan = t.plans[0]
        factors = tuple(init_factors(jax.random.PRNGKey(0), t.dims, RANK))
        state = engine.init(t, engine.ExecutionConfig(donate=False))

        wall = time_fn(lambda f: engine.all_modes(state, f)[0],
                       factors) / t.nmodes
        pad = plan.padded_nnz / t.nnz
        # kernel VMEM/block: gathered (P, N-1, R) + out tile (rows_pp, R) f32
        vmem_kb = (p * (t.nmodes - 1) * RANK + plan.rows_pp * RANK) * 4 / 1024
        rows.append((f"fig8_block_sweep/P={p}", wall * 1e6,
                     f"padding_overhead={pad:.3f};vmem_per_block_kb="
                     f"{vmem_kb:.0f}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
