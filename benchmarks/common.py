"""Shared benchmark helpers (CPU wall-clock + dry-run byte analysis).

Output contract: every ``run()`` prints ``name,us_per_call,derived`` CSV
rows (grader contract, unchanged) AND merges the same rows — plus any
structured extras such as dispatch counts — into a JSON results file
(``benchmarks/out/results.json``, override with ``BENCH_JSON``).
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.core import datasets

# Workload knobs, overridable from the environment so CI can run the same
# figure scripts as a bounded smoke (tiny synthetic tensors, few timing
# iterations) without forking the code paths.
BENCH_DATASETS = tuple(
    os.environ.get("BENCH_DATASETS",
                   "amazon,delicious,music,nell1,twitch,vast").split(","))
BENCH_SCALE = float(os.environ.get("BENCH_SCALE", 3e-4))
BENCH_MAX_NNZ = int(os.environ.get("BENCH_MAX_NNZ", 60_000))
BENCH_ITERS = int(os.environ.get("BENCH_ITERS", 5))
RANK = int(os.environ.get("BENCH_RANK", 32))  # paper default R

_JSON_PATH = os.environ.get(
    "BENCH_JSON",
    os.path.join(os.path.dirname(__file__), "out", "results.json"))


def load_bench_tensor(name: str, **kw):
    return datasets.load(name, scale=BENCH_SCALE, max_nnz=BENCH_MAX_NNZ,
                         seed=0, **kw)


def time_fn(fn, *args, iters: int | None = None, warmup: int = 2) -> float:
    """Median wall time (seconds) of a device-blocking call."""
    iters = BENCH_ITERS if iters is None else iters
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def memory_probe() -> dict:
    """Peak-memory observability hook for the out-of-core tier.

    Returns ``host_peak_rss_bytes`` (the process high-water mark — on
    Linux ``ru_maxrss`` is KiB) and ``device_peak_bytes`` (the first
    device's allocator high-water mark, ``None`` where the platform
    doesn't report one, e.g. CPU jax). fig11's oversubscription rows and
    the CI stream gate record both next to the modeled ring bytes, so a
    residency regression shows up as measured numbers, not just model
    drift.
    """
    probe: dict = {"host_peak_rss_bytes": None, "device_peak_bytes": None}
    try:
        import resource
        import sys

        peak = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
        scale = 1024 if sys.platform.startswith("linux") else 1
        probe["host_peak_rss_bytes"] = int(peak) * scale
    except (ImportError, ValueError, OSError):
        pass
    try:
        stats = jax.local_devices()[0].memory_stats() or {}
        probe["device_peak_bytes"] = stats.get(
            "peak_bytes_in_use", stats.get("bytes_in_use"))
    except Exception:  # memory_stats unsupported on this backend
        pass
    return probe


def emit(rows):
    """CSV contract: name,us_per_call,derived. Rows may carry an optional
    4th element — a dict of structured extras recorded only in the JSON."""
    records = []
    for row in rows:
        name, us, derived = row[0], row[1], row[2]
        extra = row[3] if len(row) > 3 else {}
        print(f"{name},{us:.1f},{derived}")
        records.append({"name": name, "us_per_call": round(us, 1),
                        "derived": derived, **extra})
    _merge_json(records)


def ensure_results_file() -> str:
    """Create ``benchmarks/out/results.json`` (empty list) if absent, so
    every run — even one where individual figures fail — leaves an
    artifact CI can upload. Returns the path."""
    _merge_json([])
    return _JSON_PATH


def _merge_json(records):
    try:
        os.makedirs(os.path.dirname(_JSON_PATH), exist_ok=True)
        existing = {}
        if os.path.exists(_JSON_PATH):
            try:
                with open(_JSON_PATH) as f:
                    existing = {r["name"]: r for r in json.load(f)}
            except (ValueError, KeyError, TypeError):
                existing = {}  # corrupt/legacy file: start fresh
        for r in records:
            existing[r["name"]] = r
        tmp = _JSON_PATH + ".tmp"
        with open(tmp, "w") as f:
            json.dump(list(existing.values()), f, indent=2, sort_keys=True)
            f.write("\n")
        os.replace(tmp, _JSON_PATH)  # atomic: a killed run can't corrupt
    except OSError:  # read-only checkout: CSV contract still satisfied
        pass
