"""Shared benchmark helpers (CPU wall-clock + dry-run byte analysis).

Output contract: every ``run()`` prints ``name,us_per_call,derived`` CSV
rows (grader contract, unchanged) AND merges the same rows — plus any
structured extras such as dispatch counts — into a JSON results file
(``benchmarks/out/results.json``, override with ``BENCH_JSON``).
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.core import datasets
from repro.obs.probe import memory_probe  # re-export (moved to repro.obs)

__all__ = ["load_bench_tensor", "time_fn", "Timing", "memory_probe",
           "emit", "ensure_results_file"]

# Workload knobs, overridable from the environment so CI can run the same
# figure scripts as a bounded smoke (tiny synthetic tensors, few timing
# iterations) without forking the code paths.
BENCH_DATASETS = tuple(
    os.environ.get("BENCH_DATASETS",
                   "amazon,delicious,music,nell1,twitch,vast").split(","))
BENCH_SCALE = float(os.environ.get("BENCH_SCALE", 3e-4))
BENCH_MAX_NNZ = int(os.environ.get("BENCH_MAX_NNZ", 60_000))
BENCH_ITERS = int(os.environ.get("BENCH_ITERS", 5))
RANK = int(os.environ.get("BENCH_RANK", 32))  # paper default R

_JSON_PATH = os.environ.get(
    "BENCH_JSON",
    os.path.join(os.path.dirname(__file__), "out", "results.json"))


def load_bench_tensor(name: str, **kw):
    return datasets.load(name, scale=BENCH_SCALE, max_nnz=BENCH_MAX_NNZ,
                         seed=0, **kw)


class Timing(float):
    """A median wall time that also carries the sample dispersion.

    Behaves as a plain ``float`` (the median) everywhere — including
    through the callers' ``time_fn(...) * 1e6`` unit conversions, which
    scale the stats along with the value — while ``.stats`` rides to
    :func:`emit`, which folds it into the JSON extras.  Stats keys are
    unit-neutral quantile/dispersion names (``p10``/``p90``/``iqr``) in
    the same unit as the value itself.
    """

    __slots__ = ("stats",)

    def __new__(cls, value: float, stats: dict | None = None):
        self = super().__new__(cls, value)
        self.stats = stats or {}
        return self

    def _scaled(self, k: float) -> "Timing":
        return Timing(float(self) * k,
                      {key: (v * k if key != "timing_iters" else v)
                       for key, v in self.stats.items()})

    def __mul__(self, other):
        if isinstance(other, (int, float)):
            return self._scaled(float(other))
        return NotImplemented

    __rmul__ = __mul__


def time_fn(fn, *args, iters: int | None = None, warmup: int = 2) -> Timing:
    """Median wall time (seconds) of a device-blocking call, as a
    :class:`Timing` carrying the sample dispersion (p10/p90, iqr)."""
    iters = BENCH_ITERS if iters is None else iters
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    p10, p90 = np.percentile(ts, [10, 90])
    q1, q3 = np.percentile(ts, [25, 75])
    return Timing(float(np.median(ts)), {
        "p10": float(p10), "p90": float(p90), "iqr": float(q3 - q1),
        "timing_iters": iters})


def emit(rows):
    """CSV contract: name,us_per_call,derived. Rows may carry an optional
    4th element — a dict of structured extras recorded only in the JSON.
    A :class:`Timing` value contributes its dispersion stats to the
    extras automatically (explicit extras win on key collision)."""
    records = []
    for row in rows:
        name, us, derived = row[0], row[1], row[2]
        extra = row[3] if len(row) > 3 else {}
        if isinstance(us, Timing) and us.stats:
            extra = {**{k: (round(v, 1) if isinstance(v, float) else v)
                        for k, v in us.stats.items()}, **extra}
        print(f"{name},{us:.1f},{derived}")
        records.append({"name": name, "us_per_call": round(us, 1),
                        "derived": derived, **extra})
    _merge_json(records)


def ensure_results_file() -> str:
    """Create ``benchmarks/out/results.json`` (empty list) if absent, so
    every run — even one where individual figures fail — leaves an
    artifact CI can upload. Returns the path."""
    _merge_json([])
    return _JSON_PATH


def _merge_json(records):
    try:
        os.makedirs(os.path.dirname(_JSON_PATH), exist_ok=True)
        existing = {}
        if os.path.exists(_JSON_PATH):
            try:
                with open(_JSON_PATH) as f:
                    existing = {r["name"]: r for r in json.load(f)}
            except (ValueError, KeyError, TypeError):
                existing = {}  # corrupt/legacy file: start fresh
        for r in records:
            existing[r["name"]] = r
        tmp = _JSON_PATH + ".tmp"
        with open(tmp, "w") as f:
            json.dump(list(existing.values()), f, indent=2, sort_keys=True)
            f.write("\n")
        os.replace(tmp, _JSON_PATH)  # atomic: a killed run can't corrupt
    except OSError:  # read-only checkout: CSV contract still satisfied
        pass
