"""Shared benchmark helpers (CPU wall-clock + dry-run byte analysis)."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import datasets

BENCH_DATASETS = ("amazon", "delicious", "music", "nell1", "twitch", "vast")
BENCH_SCALE = 3e-4
BENCH_MAX_NNZ = 60_000
RANK = 32  # paper default R


def load_bench_tensor(name: str, **kw):
    return datasets.load(name, scale=BENCH_SCALE, max_nnz=BENCH_MAX_NNZ,
                         seed=0, **kw)


def time_fn(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall time (seconds) of a device-blocking call."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(rows):
    """CSV contract: name,us_per_call,derived."""
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
