"""Paper Fig. 9 / Table 4: total spMTTKRP time along all modes vs baselines.

Baselines (same algorithmic roles as the paper's):
  coo-atomic     plain COO + scatter-add per mode, single tensor copy,
                 no locality ordering (ParTI-style mode-agnostic)
  mode-specific  N pre-sorted tensor copies, no dynamic remap
                 (MM-CSF-style; copy-prep excluded, as the paper excludes
                 baseline reorder costs in Fig. 9)
  flycoo         ours: single copy + partition-ordered layout + fused
                 dynamic remap (remap cost INCLUDED, as in the paper),
                 executed as ONE jitted lax.scan over the mode rotation
                 (``engine.all_modes``) — the JSON records the dispatch
                 reduction vs the removed per-mode host loop.

Wall-clock here is CPU-XLA, where the COO baselines pay no atomic or
synchronization costs (segment_sum is race-free on one core) — i.e. the
very mechanism the paper's GPU baselines lose to does not exist on CPU.
Measured ratios (0.3-1.6x) therefore do NOT reproduce the paper's GPU
speedups and are reported as an honest negative; the structural wins are
quantified instead by fig6_7 (HBM bytes the fusion avoids) and by the
kernel's VMEM-resident accumulation (tests/benchmarks).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.core import init_factors, mttkrp_ref

from .common import BENCH_DATASETS, RANK, emit, load_bench_tensor, time_fn


def _baseline_coo(t, factors):
    idx = jnp.asarray(t.indices)
    val = jnp.asarray(t.values)

    @jax.jit
    def all_modes(factors):
        return [mttkrp_ref(idx, val, factors, d, t.dims[d])
                for d in range(t.nmodes)]

    return lambda: all_modes(factors)


def _baseline_mode_specific(t, factors):
    """Per-mode pre-sorted copies (sorted by output index => monotonic
    segment ids, best case for segment_sum); sort cost excluded."""
    per_mode = []
    for d in range(t.nmodes):
        order = np.argsort(t.indices[:, d], kind="stable")
        per_mode.append((jnp.asarray(t.indices[order]),
                         jnp.asarray(t.values[order])))

    @jax.jit
    def all_modes(factors):
        outs = []
        for d in range(t.nmodes):
            idx, val = per_mode[d]
            outs.append(mttkrp_ref(idx, val, factors, d, t.dims[d]))
        return outs

    return lambda: all_modes(factors)


def run():
    rows = []
    for name in BENCH_DATASETS:
        t = load_bench_tensor(name)
        factors = tuple(init_factors(jax.random.PRNGKey(0), t.dims, RANK))

        coo_fn = _baseline_coo(t, factors)        # build + jit once
        ms_fn = _baseline_mode_specific(t, factors)
        t_coo = time_fn(coo_fn)
        t_ms = time_fn(ms_fn)

        # Functional engine: every call starts from the immutable mode-0
        # state — no executor cloning, no host-side mode loop. Donation is
        # pinned off: the timing loop reuses one state, and donated buffers
        # would be deleted after the first call on TPU/GPU.
        state = engine.init(t, engine.ExecutionConfig(donate=False))
        engine.reset_counters()
        iters, warmup = 3, 1
        t_fly = time_fn(lambda: engine.all_modes(state, factors)[0],
                        iters=iters, warmup=warmup)
        per_rotation = engine.DISPATCH_COUNTS["all_modes"] / (iters + warmup)
        rows.append((f"fig9_total_time/{name}", t_fly * 1e6,
                     f"speedup_vs_coo={t_coo / t_fly:.2f}x;"
                     f"speedup_vs_modespecific={t_ms / t_fly:.2f}x;"
                     f"dispatches_per_rotation={per_rotation:.0f}",
                     {"dispatches_per_rotation": per_rotation,
                      "dispatches_host_loop": t.nmodes,
                      "dispatch_reduction": f"{t.nmodes:.0f}x",
                      "traces": engine.TRACE_COUNTS["all_modes"]}))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
