"""Paper Figs. 6-7: compute / memory-locality throughput comparison.

Nsight's SM- and L1-throughput counters have no CPU analogue, so the TPU
translation is measured on the *compiled artifacts* (cost_analysis):

  fig6 (compute): useful-FLOP rate = MTTKRP flops / wall time, ours vs the
       naive-COO baseline — the paper's "higher SM throughput from load
       balancing + no intermediate traffic". Ours is the scanned
       ``engine.all_modes`` rotation (ONE dispatch, remap included),
       amortized per mode; the baseline gets the same one-jit treatment.
  fig7 (memory):  HBM bytes that the fused FLYCOO kernel AVOIDS — the
       (nnz x R) Hadamard partials stay in VMEM (paper: in L1). We report
       bytes-accessed of the fused-kernel lowering vs the unfused reference
       (partials materialized).
  fig7_fused_hbm: modeled per-mode HBM traffic of the ``pallas`` backend
       (XLA gathers an (S, N-1, R) operand into HBM, the kernel re-reads
       it, and the Alg. 3 remap is three full-S_max XLA scatters) vs the
       ``pallas_fused`` pipeline (factor rows DMA'd straight into VMEM
       inside the kernel grid; remap scattered by the same pass). Model:
       ``cost_analysis()`` of each backend's XLA-side per-mode program (the
       kernel-boundary arrays; list-valued returns on jax 0.4.37 handled in
       ``_lower_cost``) plus the kernel-side traffic XLA cannot see, both
       charged row-granularly (Nisa et al.'s gather model — each nonzero
       reads one R-row per input factor): the gathered operand's kernel
       re-read for ``pallas``; the factor-row DMA, layout block reads and
       next-layout write-back for ``pallas_fused``. The XLA gather's
       operand-size read charge is swapped out for the same row-granular
       term so both pipelines are on one ruler.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import engine
from repro.core import init_factors, mttkrp_ref

from .common import BENCH_DATASETS, RANK, emit, load_bench_tensor, time_fn


def _mttkrp_flops(t, rank):
    # per mode: nnz * (N-1) hadamard mults * R + nnz * R scale + adds
    n = t.nmodes
    return n * t.nnz * rank * (n - 1 + 2)


def _lower_cost(fn, *args):
    lowered = jax.jit(fn).lower(*args)
    cost = lowered.compile().cost_analysis()
    # jax returns one dict per device on some versions, a bare dict on others
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def run():
    rows = []
    for name in BENCH_DATASETS:
        t = load_bench_tensor(name)
        factors = tuple(init_factors(jax.random.PRNGKey(0), t.dims, RANK))
        # donate=False: the timing loop reuses this one state; donation
        # would delete its buffers after the first call on TPU/GPU.
        state = engine.init(t, engine.ExecutionConfig(donate=False))
        plan = state.statics[0]

        # ---- fig6: useful-FLOP rate vs naive COO (both all-modes jits) ----
        idx, val = jnp.asarray(t.indices), jnp.asarray(t.values)

        @jax.jit
        def coo_all(f):
            return [mttkrp_ref(idx, val, f, d, t.dims[d])
                    for d in range(t.nmodes)]

        t_coo = time_fn(coo_all, factors) / t.nmodes

        engine.reset_counters()
        t_fly = time_fn(
            lambda f: engine.all_modes(state, f)[0], factors) / t.nmodes
        dispatches = engine.DISPATCH_COUNTS["all_modes"]
        gf = _mttkrp_flops(t, RANK) / t.nmodes
        rows.append((f"fig6_compute_throughput/{name}", t_fly * 1e6,
                     f"gflops={gf / t_fly / 1e9:.2f};"
                     f"vs_coo={t_coo / t_fly:.2f}x",
                     {"scanned_all_modes": True,
                      "dispatches_per_rotation": 1,
                      "measured_dispatches": dispatches}))

        # ---- fig7: HBM bytes avoided by fusion (partials in VMEM) ----
        s = plan.padded_nnz
        nm1 = t.nmodes - 1
        gathered = jax.ShapeDtypeStruct((s, nm1, RANK), jnp.float32)
        valspec = jax.ShapeDtypeStruct((s,), jnp.float32)
        lrowspec = jax.ShapeDtypeStruct((s,), jnp.int32)
        bpart0 = jnp.asarray(t.plans[0].block_part)

        def unfused(g, v, lw):
            ell = jnp.prod(g, axis=1) * v[:, None]   # (S, R) partials -> HBM
            part = jnp.take(bpart0, jnp.arange(s, dtype=jnp.int32)
                            // plan.block_p, axis=0)
            gid = jnp.where(lw < 0, 0, part * plan.rows_pp + lw)
            return jax.ops.segment_sum(ell, gid,
                                       num_segments=plan.relabeled_rows)

        cost_unfused = _lower_cost(unfused, gathered, valspec, lrowspec)
        partial_bytes = s * RANK * 4 * 2  # write + read of (S, R) partials
        rows.append((
            f"fig7_memory_traffic/{name}",
            cost_unfused.get("bytes accessed", 0.0) / 1e6,
            f"hbm_bytes_avoided_by_fusion_mb={partial_bytes / 1e6:.1f}"))

        # ---- fig7_fused_hbm: modeled per-mode HBM bytes, pallas (unfused
        #      gather + XLA remap scatters) vs pallas_fused (in-kernel
        #      gather + in-kernel remap). See module docstring for the
        #      accounting; both sides use the row-granular gather model. --
        n, sd, smax = t.nmodes, plan.padded_nnz, state.smax
        nm1 = n - 1
        valspec2 = jax.ShapeDtypeStruct((smax,), jnp.float32)
        idxspec2 = jax.ShapeDtypeStruct((smax, n), jnp.int32)
        alspec2 = jax.ShapeDtypeStruct((smax, n), jnp.int32)
        facspecs = tuple(jax.ShapeDtypeStruct((d, RANK), jnp.float32)
                         for d in t.dims)

        def pallas_boundary(val, idx, alpha, factors):
            # XLA-side work around the unfused kernel: materialize the
            # (S, N-1, R) gathered operand + the three full-S_max scatters.
            v, ix, al = val[:sd], idx[:sd], alpha[:sd]
            gathered = jnp.stack(
                [jnp.take(f, ix[:, w], axis=0, mode="fill", fill_value=0.0)
                 for w, f in enumerate(factors) if w != 0], 1)
            dst = jnp.where(al[:, 0] >= 0, al[:, 1 % n], smax)
            nval = jnp.zeros((smax,), jnp.float32).at[dst].set(
                v, mode="drop", unique_indices=True)
            nidx = jnp.zeros((smax, n), jnp.int32).at[dst].set(
                ix, mode="drop", unique_indices=True)
            nalpha = jnp.full((smax, n), -1, jnp.int32).at[dst].set(
                al, mode="drop", unique_indices=True)
            return gathered, nval, nidx, nalpha

        def fused_boundary(val, idx, alpha, factors):
            # XLA-side work around the fused kernel: only the (N-1, S) i32
            # scalar-prefetch table — gather and remap live in-kernel.
            ix = idx[:sd]
            return jnp.stack([ix[:, w] for w in range(n) if w != 0]
                             ).astype(jnp.int32)

        bnd_p = _lower_cost(pallas_boundary, valspec2, idxspec2, alspec2,
                            facspecs).get("bytes accessed", 0.0)
        bnd_f = _lower_cost(fused_boundary, valspec2, idxspec2, alspec2,
                            facspecs).get("bytes accessed", 0.0)
        row_gather = sd * nm1 * RANK * 4       # one R-row per slot+factor
        fac_params = sum(t.dims[w] for w in range(1, n)) * RANK * 4
        gathered_reread = sd * nm1 * RANK * 4  # kernel reads the operand
        layout_kernel = (sd * (8 + 8 * n)      # val+lrow + idx+alpha blocks
                         + smax * (4 + 8 * n))  # next-layout write-back
        bytes_pallas = bnd_p - fac_params + row_gather + gathered_reread
        bytes_fused = bnd_f + row_gather + layout_kernel
        reduction = bytes_pallas / max(bytes_fused, 1.0)
        rows.append((
            f"fig7_fused_hbm/{name}",
            bytes_fused / 1e6,
            f"pallas_mb={bytes_pallas / 1e6:.1f};reduction={reduction:.2f}x",
            {"modeled_hbm_bytes_per_mode": {
                "pallas": round(bytes_pallas),
                "pallas_fused": round(bytes_fused)},
             "xla_boundary_bytes_per_mode": {
                "pallas": round(bnd_p), "pallas_fused": round(bnd_f)},
             "hbm_reduction_x": round(reduction, 2)}))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
