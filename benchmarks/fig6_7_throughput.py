"""Paper Figs. 6-7: compute / memory-locality throughput comparison.

Nsight's SM- and L1-throughput counters have no CPU analogue, so the TPU
translation is measured on the *compiled artifacts* (cost_analysis):

  fig6 (compute): useful-FLOP rate = MTTKRP flops / wall time, ours vs the
       naive-COO baseline — the paper's "higher SM throughput from load
       balancing + no intermediate traffic". Ours is the scanned
       ``engine.all_modes`` rotation (ONE dispatch, remap included),
       amortized per mode; the baseline gets the same one-jit treatment.
  fig7 (memory):  HBM bytes that the fused FLYCOO kernel AVOIDS — the
       (nnz x R) Hadamard partials stay in VMEM (paper: in L1). We report
       bytes-accessed of the fused-kernel lowering vs the unfused reference
       (partials materialized).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import engine
from repro.core import init_factors, mttkrp_ref

from .common import BENCH_DATASETS, RANK, emit, load_bench_tensor, time_fn


def _mttkrp_flops(t, rank):
    # per mode: nnz * (N-1) hadamard mults * R + nnz * R scale + adds
    n = t.nmodes
    return n * t.nnz * rank * (n - 1 + 2)


def _lower_cost(fn, *args):
    lowered = jax.jit(fn).lower(*args)
    cost = lowered.compile().cost_analysis()
    # jax returns one dict per device on some versions, a bare dict on others
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def run():
    rows = []
    for name in BENCH_DATASETS:
        t = load_bench_tensor(name)
        factors = tuple(init_factors(jax.random.PRNGKey(0), t.dims, RANK))
        # donate=False: the timing loop reuses this one state; donation
        # would delete its buffers after the first call on TPU/GPU.
        state = engine.init(t, engine.ExecutionConfig(donate=False))
        plan = state.statics[0]

        # ---- fig6: useful-FLOP rate vs naive COO (both all-modes jits) ----
        idx, val = jnp.asarray(t.indices), jnp.asarray(t.values)

        @jax.jit
        def coo_all(f):
            return [mttkrp_ref(idx, val, f, d, t.dims[d])
                    for d in range(t.nmodes)]

        t_coo = time_fn(coo_all, factors) / t.nmodes

        engine.reset_counters()
        t_fly = time_fn(
            lambda f: engine.all_modes(state, f)[0], factors) / t.nmodes
        dispatches = engine.DISPATCH_COUNTS["all_modes"]
        gf = _mttkrp_flops(t, RANK) / t.nmodes
        rows.append((f"fig6_compute_throughput/{name}", t_fly * 1e6,
                     f"gflops={gf / t_fly / 1e9:.2f};"
                     f"vs_coo={t_coo / t_fly:.2f}x",
                     {"scanned_all_modes": True,
                      "dispatches_per_rotation": 1,
                      "measured_dispatches": dispatches}))

        # ---- fig7: HBM bytes avoided by fusion (partials in VMEM) ----
        s = plan.padded_nnz
        nm1 = t.nmodes - 1
        gathered = jax.ShapeDtypeStruct((s, nm1, RANK), jnp.float32)
        valspec = jax.ShapeDtypeStruct((s,), jnp.float32)
        lrowspec = jax.ShapeDtypeStruct((s,), jnp.int32)

        def unfused(g, v, lw):
            ell = jnp.prod(g, axis=1) * v[:, None]   # (S, R) partials -> HBM
            part = jnp.arange(s, dtype=jnp.int32) // (
                plan.blocks_pp * plan.block_p)
            gid = jnp.where(lw < 0, 0, part * plan.rows_pp + lw)
            return jax.ops.segment_sum(ell, gid,
                                       num_segments=plan.relabeled_rows)

        cost_unfused = _lower_cost(unfused, gathered, valspec, lrowspec)
        partial_bytes = s * RANK * 4 * 2  # write + read of (S, R) partials
        rows.append((
            f"fig7_memory_traffic/{name}",
            cost_unfused.get("bytes accessed", 0.0) / 1e6,
            f"hbm_bytes_avoided_by_fusion_mb={partial_bytes / 1e6:.1f}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
