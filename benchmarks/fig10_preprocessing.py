"""Paper Fig. 10: tensor-format generation (preprocessing) time.

The paper's point: FLYCOO partitioning touches only nonzeros
(O(nnz log nnz) per mode), never the index space — unlike ParTI, whose
partitioner spans all of prod(I_d). We time build_flycoo per dataset and
an index-space-spanning strawman for the smallest dataset to show the gap.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import datasets
from repro.core.flycoo import build_flycoo

from .common import BENCH_DATASETS, emit


def run():
    rows = []
    for name in BENCH_DATASETS:
        ts = datasets.spec(name, scale=3e-4, max_nnz=60_000)
        idx, val = datasets.synthesize(ts, seed=0)
        t0 = time.perf_counter()
        t = build_flycoo(idx, val, ts.dims)
        dt = time.perf_counter() - t0
        rows.append((f"fig10_preprocessing/{name}", dt * 1e6,
                     f"nnz={t.nnz};modes={t.nmodes};"
                     f"us_per_nnz_mode={dt * 1e6 / t.nnz / t.nmodes:.3f}"))
    # ParTI-style partitioners span the index space: report the full-scale
    # (paper Table 3) cells/nnz ratio — the asymptotic gap our nnz-only
    # preprocessing avoids (10^2..10^15 x). Synthetic-only datasets (e.g.
    # "zipf") have no Table 3 row to compare against.
    for name in BENCH_DATASETS:
        if name not in datasets.PAPER_TENSORS:
            continue
        dims, nnz = datasets.PAPER_TENSORS[name]
        cells = 1
        for d in dims:
            cells *= d
        rows.append((f"fig10_preprocessing/index_space_ratio_{name}", 0.0,
                     f"index_cells_over_nnz={cells / nnz:.2e}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
