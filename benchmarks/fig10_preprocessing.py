"""Paper Fig. 10: tensor-format generation (preprocessing) time.

The paper's point: FLYCOO partitioning touches only nonzeros
(O(nnz log nnz) per mode), never the index space — unlike ParTI, whose
partitioner spans all of prod(I_d). We time build_flycoo per dataset and
an index-space-spanning strawman for the smallest dataset to show the gap.

The ``fig10_plan_wall/*`` section records the preprocessing-wall work of
this PR on a dedicated zipf tensor (sized by ``FIG10_PLAN_NNZ``,
independent of ``BENCH_MAX_NNZ`` so the ratios are stable in CI smoke):
the pre-PR ``plan_mode_reference`` baseline, the vectorized cold path,
plan-cache identity/structural hits, and the autotuned plan with its
chosen knobs. CI gates hit >= 10x cold and cold >= 2x baseline from
these rows.
"""
from __future__ import annotations

import dataclasses
import os
import time

import numpy as np

from repro.core import datasets
from repro.core.flycoo import build_flycoo

from .common import BENCH_DATASETS, emit


def run():
    rows = []
    for name in BENCH_DATASETS:
        ts = datasets.spec(name, scale=3e-4, max_nnz=60_000)
        idx, val = datasets.synthesize(ts, seed=0)
        t0 = time.perf_counter()
        t = build_flycoo(idx, val, ts.dims)
        dt = time.perf_counter() - t0
        rows.append((f"fig10_preprocessing/{name}", dt * 1e6,
                     f"nnz={t.nnz};modes={t.nmodes};"
                     f"us_per_nnz_mode={dt * 1e6 / t.nnz / t.nmodes:.3f}"))
    # ParTI-style partitioners span the index space: report the full-scale
    # (paper Table 3) cells/nnz ratio — the asymptotic gap our nnz-only
    # preprocessing avoids (10^2..10^15 x). Synthetic-only datasets (e.g.
    # "zipf") have no Table 3 row to compare against.
    for name in BENCH_DATASETS:
        if name not in datasets.PAPER_TENSORS:
            continue
        dims, nnz = datasets.PAPER_TENSORS[name]
        cells = 1
        for d in dims:
            cells *= d
        rows.append((f"fig10_preprocessing/index_space_ratio_{name}", 0.0,
                     f"index_cells_over_nnz={cells / nnz:.2e}"))
    rows.extend(_plan_wall_rows())
    emit(rows)
    return rows


def _best_of(fn, n: int = 3) -> float:
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _plan_wall_rows():
    """Cold-plan vs cache-hit vs autotuned-plan timings (CI gate source)."""
    from repro.core.partition import plan_mode, plan_mode_reference
    from repro.core.plancache import PlanCache
    from repro.engine import PlanSpace, PlanSpec
    from repro.engine.autotune import autotune

    dims = (100_000, 80_000, 60_000)
    want = int(os.environ.get("FIG10_PLAN_NNZ", 1_000_000))
    t = datasets.zipf_tensor(dims, want, a=1.5, seed=0)
    idx, val = t.indices, t.values
    nnz, n = t.nnz, t.nmodes
    idx_t = np.ascontiguousarray(idx.T)

    # pre-PR cold baseline: the reference plan kernel on the strided
    # columns the old build_flycoo handed it
    t_ref = _best_of(lambda: [plan_mode_reference(idx[:, d], dims[d], d)
                              for d in range(n)])
    # vectorized cold path (contiguous columns, as build_flycoo now calls)
    t_cold = _best_of(lambda: [plan_mode(idx_t[d], dims[d], d)
                               for d in range(n)])

    cache = PlanCache()
    t0 = time.perf_counter()
    cache.get_tensor(idx, val, dims)                      # populate (miss)
    t_miss = time.perf_counter() - t0                     # full cold fetch
    # identity hit through the realistic path: a distinct, equal array
    hits = []
    for _ in range(5):
        eq = idx.copy()
        t0 = time.perf_counter()
        cache.get_tensor(eq, val, dims)
        hits.append(time.perf_counter() - t0)
        assert cache.last_outcome == "hit"
    t_hit = float(np.median(hits))
    # structural hit: same sparsity, permuted nonzero order (each distinct
    # permutation re-resolves structurally against the original entry, so
    # best-of-3 is measurable without identity hits short-circuiting it)
    rng = np.random.default_rng(0)
    t_struct = float("inf")
    for _ in range(3):
        perm = rng.permutation(nnz)
        t0 = time.perf_counter()
        cache.get_tensor(idx[perm], val[perm], dims)
        t_struct = min(t_struct, time.perf_counter() - t0)
        assert cache.last_outcome == "structural"

    space = PlanSpace(base=PlanSpec(backend="pallas_fused"))
    t0 = time.perf_counter()
    result = autotune(idx, val, dims, space, seed=0, cache=cache)
    t_tune = time.perf_counter() - t0
    best = result.best

    tag = f"nnz={nnz};modes={n}"
    return [
        (f"fig10_plan_wall/baseline_reference", t_ref * 1e6, tag),
        (f"fig10_plan_wall/cold_vectorized", t_cold * 1e6,
         f"{tag};speedup_vs_reference={t_ref / t_cold:.2f}",
         {"speedup_vs_reference": round(t_ref / t_cold, 2)}),
        (f"fig10_plan_wall/cache_hit", t_hit * 1e6,
         f"{tag};speedup_vs_cold={t_cold / t_hit:.1f}",
         {"speedup_vs_cold": round(t_cold / t_hit, 1)}),
        (f"fig10_plan_wall/cache_structural", t_struct * 1e6,
         f"{tag};speedup_vs_cold_fetch={t_miss / t_struct:.2f}",
         {"speedup_vs_cold_fetch": round(t_miss / t_struct, 2)}),
        (f"fig10_plan_wall/autotuned", t_tune * 1e6,
         f"{tag};block_p={best.block_p};schedule={best.schedule};"
         f"dedup={best.dedup}",
         {"chosen_knobs": dataclasses.asdict(best),
          "modeled_cost_best": result.modeled[best],
          "modeled_cost_default": result.modeled[result.default],
          "plan_cache": cache.stats()}),
    ]


if __name__ == "__main__":
    run()
