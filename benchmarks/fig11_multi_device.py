"""fig11: weak-scaling multi-device sweep (engine.dist).

For 1/2/4/8 fake CPU devices, grow the tensor with the device count
(fixed nnz and mode-0 rows per device) and measure one distributed
all-modes rotation plus the per-mode remap-exchange wire traffic of the
two strategies: the precomputed collective_permute schedule vs the
all_gather-the-element-list baseline. Traffic comes from the static
:class:`~repro.engine.dist.ExchangeSchedule` (host-side truth — identical
on real hardware); wall-clock runs in a subprocess so each point gets its
own ``--xla_force_host_platform_device_count``.

Rows: ``fig11/weak_scale_dev{n},us_per_call,permute_KB=..;all_gather_KB=..``
with the per-mode byte split recorded in ``benchmarks/out/results.json``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from .common import emit

DEVICES = (1, 2, 4, 8)
NNZ_PER_DEV = 3000
DIM0_PER_DEV = 96
SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_CHILD = """
import os
n_dev = int(os.environ["FIG11_NDEV"])
os.environ["XLA_FLAGS"] = \\
    f"--xla_force_host_platform_device_count={n_dev}"
import json, time
import jax
import numpy as np
from repro import engine
from repro.core import init_factors
from repro.core.distributed import build_sharded_flycoo
from repro.engine.dist import exchange_bytes
from repro.launch.mesh import make_mesh

nnz = int(os.environ["FIG11_NNZ"])
dims = (int(os.environ["FIG11_DIM0"]), 64, 48)
rng = np.random.default_rng(0)
idx = np.unique(np.stack([rng.integers(0, d, nnz) for d in dims], 1)
                .astype(np.int32), axis=0)
val = rng.standard_normal(idx.shape[0]).astype(np.float32)
t = build_sharded_flycoo(idx, val, dims, n_dev=n_dev, rows_pp=8, block_p=8)
factors = tuple(init_factors(jax.random.PRNGKey(0), dims, 16))
state = engine.init(t)
if n_dev == 1:
    st, run = state, lambda s: engine.all_modes(s, factors)
    per_mode = [dict(mode=d, permute_bytes=0, all_gather_bytes=0)
                for d in range(len(dims))]
else:
    mesh = make_mesh((n_dev,), ("data",))
    st = engine.dist.shard_state(state, mesh)
    per_mode = exchange_bytes(st.schedule, len(dims), st.slocs)
    run = lambda s: engine.dist.dist_all_modes(s, factors)
outs, st = run(st)  # compile + warm
jax.block_until_ready(outs)
ts = []
for _ in range(3):
    t0 = time.perf_counter()
    outs, st = run(st)
    jax.block_until_ready(outs)
    ts.append(time.perf_counter() - t0)
print(json.dumps({"us": float(np.median(ts)) * 1e6,
                  "nnz": int(val.shape[0]), "per_mode": per_mode}))
"""


def _point(n_dev: int) -> dict:
    env = dict(os.environ,
               PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH", ""),
               FIG11_NDEV=str(n_dev),
               FIG11_NNZ=str(NNZ_PER_DEV * n_dev),
               FIG11_DIM0=str(DIM0_PER_DEV * n_dev))
    out = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                         capture_output=True, text=True, timeout=900)
    if out.returncode != 0:
        raise RuntimeError(f"fig11 child (n_dev={n_dev}) failed:\n"
                           f"{out.stderr}")
    return json.loads(out.stdout.splitlines()[-1])


def run() -> None:
    rows = []
    for n_dev in DEVICES:
        rec = _point(n_dev)
        pk = sum(m["permute_bytes"] for m in rec["per_mode"]) / 1024
        ak = sum(m["all_gather_bytes"] for m in rec["per_mode"]) / 1024
        rows.append((
            f"fig11/weak_scale_dev{n_dev}",
            rec["us"],
            f"permute_KB_per_dev={pk:.1f};all_gather_KB_per_dev={ak:.1f}",
            {"n_dev": n_dev, "nnz": rec["nnz"],
             "per_mode_exchange": rec["per_mode"]},
        ))
    emit(rows)
