"""fig11: weak-scaling multi-device sweep (engine.dist) + out-of-core
streaming oversubscription points (engine.stream).

For 1/2/4/8 fake CPU devices, grow the tensor with the device count
(fixed nnz and mode-0 rows per device) and measure one distributed
all-modes rotation plus the per-mode remap-exchange wire traffic of the
two strategies: the precomputed collective_permute schedule vs the
all_gather-the-element-list baseline. Traffic comes from the static
:class:`~repro.engine.dist.ExchangeSchedule` (host-side truth — identical
on real hardware); wall-clock runs in a subprocess so each point gets its
own ``--xla_force_host_platform_device_count``.

The streaming section (:func:`run_stream`, env knob
``STREAM_BUDGET_BYTES``) runs the same tensor resident and streamed under
budgets that oversubscribe it, verifying bitwise equality and recording
the transfer-bytes / overlap-efficiency / peak-ring curves the CI
``stream-smoke`` job gates.

Rows: ``fig11/weak_scale_dev{n},us_per_call,permute_KB=..;all_gather_KB=..``
and ``fig11/stream_oversub_b{i},us_per_call,budget_KB=..;...`` with the
full byte splits recorded in ``benchmarks/out/results.json``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from .common import emit, memory_probe, time_fn

DEVICES = (1, 2, 4, 8)
NNZ_PER_DEV = 3000
DIM0_PER_DEV = 96
SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# Device budget for the streaming points; the default (and a 4x tighter
# second point) oversubscribes the synthetic tensor below, so the curve
# always exercises real chunking. CI sets it artificially tiny.
STREAM_BUDGET_BYTES = int(os.environ.get("STREAM_BUDGET_BYTES",
                                         256 * 1024))
STREAM_NNZ = 12_000
STREAM_DIMS = (384, 128, 96)
STREAM_RANK = 16

_CHILD = """
import os
n_dev = int(os.environ["FIG11_NDEV"])
os.environ["XLA_FLAGS"] = \\
    f"--xla_force_host_platform_device_count={n_dev}"
import json, time
import jax
import numpy as np
from repro import engine
from repro.core import init_factors
from repro.core.distributed import build_sharded_flycoo
from repro.engine.dist import exchange_bytes
from repro.launch.mesh import make_mesh

nnz = int(os.environ["FIG11_NNZ"])
dims = (int(os.environ["FIG11_DIM0"]), 64, 48)
rng = np.random.default_rng(0)
idx = np.unique(np.stack([rng.integers(0, d, nnz) for d in dims], 1)
                .astype(np.int32), axis=0)
val = rng.standard_normal(idx.shape[0]).astype(np.float32)
t = build_sharded_flycoo(idx, val, dims, n_dev=n_dev, rows_pp=8, block_p=8)
factors = tuple(init_factors(jax.random.PRNGKey(0), dims, 16))
state = engine.init(t)
if n_dev == 1:
    st, run = state, lambda s: engine.all_modes(s, factors)
    per_mode = [dict(mode=d, permute_bytes=0, all_gather_bytes=0)
                for d in range(len(dims))]
else:
    mesh = make_mesh((n_dev,), ("data",))
    st = engine.dist.shard_state(state, mesh)
    per_mode = exchange_bytes(st.schedule, len(dims), st.slocs)
    run = lambda s: engine.dist.dist_all_modes(s, factors)
outs, st = run(st)  # compile + warm
jax.block_until_ready(outs)
ts = []
for _ in range(3):
    t0 = time.perf_counter()
    outs, st = run(st)
    jax.block_until_ready(outs)
    ts.append(time.perf_counter() - t0)
print(json.dumps({"us": float(np.median(ts)) * 1e6,
                  "nnz": int(val.shape[0]), "per_mode": per_mode}))
"""


def _point(n_dev: int) -> dict:
    env = dict(os.environ,
               PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH", ""),
               FIG11_NDEV=str(n_dev),
               FIG11_NNZ=str(NNZ_PER_DEV * n_dev),
               FIG11_DIM0=str(DIM0_PER_DEV * n_dev))
    out = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                         capture_output=True, text=True, timeout=900)
    if out.returncode != 0:
        raise RuntimeError(f"fig11 child (n_dev={n_dev}) failed:\n"
                           f"{out.stderr}")
    return json.loads(out.stdout.splitlines()[-1])


def _stream_row(i: int, budget: int, tensor, factors, outs_res) -> tuple:
    """One oversubscription point: stream the tensor under ``budget``,
    check bitwise parity against the resident outputs, time a warm
    rotation, and record the transfer/residency stats."""
    import numpy as np

    from repro.engine.config import ExecutionConfig
    from repro.engine.stream import (resident_bytes, stream_all_modes,
                                     stream_init, stream_transfer_model)

    config = ExecutionConfig(backend="xla", rows_pp=8,
                             device_budget_bytes=budget,
                             rank_hint=STREAM_RANK)
    state = stream_init(tensor, config)
    outs, state = stream_all_modes(state, factors)
    bitwise = all(np.array_equal(np.asarray(a), np.asarray(b))
                  for a, b in zip(outs_res, outs))
    if not bitwise:
        raise RuntimeError(
            f"streamed rotation diverged from resident engine "
            f"(budget={budget})")
    stats = state.stats.as_row()          # first-rotation snapshot
    resident = resident_bytes(tensor, config)

    holder = {"state": state}

    def rotation():
        outs, holder["state"] = stream_all_modes(holder["state"], factors)
        return outs

    us = time_fn(rotation, warmup=1) * 1e6
    name = f"fig11/stream_oversub_b{i}"
    derived = (f"budget_KB={budget / 1024:.0f}"
               f";oversub_x={resident / budget:.2f}"
               f";peak_ring_KB={stats['peak_ring_bytes'] / 1024:.1f}"
               f";transfer_KB={stats['transfer_bytes'] / 1024:.1f}"
               f";overlap={stats['overlap_efficiency']:.2f}")
    return (name, us, derived, {
        "budget_bytes": budget,
        "resident_bytes": resident,
        "oversubscription_x": resident / budget,
        "bitwise_equal": bitwise,
        "chunks_per_rotation": stats["chunks_streamed"],
        "modeled_transfer": stream_transfer_model(tensor, config),
        **stats,
        **memory_probe(),
    })


def run_stream() -> None:
    """The streaming oversubscription points alone (the CI ``stream-smoke``
    entry — no fake multi-device subprocesses needed)."""
    import jax
    import numpy as np

    from repro import engine
    from repro.core import init_factors
    from repro.core.flycoo import build_flycoo
    from repro.engine.config import ExecutionConfig

    rng = np.random.default_rng(0)
    idx = np.unique(
        np.stack([rng.integers(0, d, STREAM_NNZ) for d in STREAM_DIMS], 1)
        .astype(np.int32), axis=0)
    val = rng.standard_normal(idx.shape[0]).astype(np.float32)
    tensor = build_flycoo(idx, val, STREAM_DIMS, rows_pp=8)
    factors = tuple(init_factors(jax.random.PRNGKey(0), STREAM_DIMS,
                                 STREAM_RANK))
    outs_res, _ = engine.all_modes(
        engine.init(tensor, ExecutionConfig(backend="xla", rows_pp=8)),
        factors)
    rows = [_stream_row(i, budget, tensor, factors, outs_res)
            for i, budget in enumerate(
                (STREAM_BUDGET_BYTES, STREAM_BUDGET_BYTES // 4))]
    emit(rows)


def run() -> None:
    rows = []
    for n_dev in DEVICES:
        rec = _point(n_dev)
        pk = sum(m["permute_bytes"] for m in rec["per_mode"]) / 1024
        ak = sum(m["all_gather_bytes"] for m in rec["per_mode"]) / 1024
        rows.append((
            f"fig11/weak_scale_dev{n_dev}",
            rec["us"],
            f"permute_KB_per_dev={pk:.1f};all_gather_KB_per_dev={ak:.1f}",
            {"n_dev": n_dev, "nnz": rec["nnz"],
             "per_mode_exchange": rec["per_mode"]},
        ))
    emit(rows)
    run_stream()
