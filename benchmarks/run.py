"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (contract for graders).
  fig5   remap overhead split (paper: 5-35%)
  fig6/7 compute-throughput + memory-traffic proxies (Nsight counters have
         no CPU analogue; cost_analysis bytes stand in)
  fig8   block-shape (P) sweep
  fig9   total all-modes time vs COO / mode-specific baselines (Table 4)
  fig10  preprocessing time (nnz-bound vs index-space-bound)
  fig11  multi-device weak scaling: exchange bytes permute-schedule vs
         all_gather baseline (fake CPU devices)
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (common, fig5_remap_overhead, fig6_7_throughput,
                   fig8_block_sweep, fig9_total_time, fig10_preprocessing,
                   fig11_multi_device)

    mods = [fig5_remap_overhead, fig6_7_throughput, fig8_block_sweep,
            fig9_total_time, fig10_preprocessing, fig11_multi_device]
    failed = []
    # the perf trail must exist even if every figure below fails — CI
    # uploads it as an artifact unconditionally
    common.ensure_results_file()
    print("name,us_per_call,derived")
    for mod in mods:
        try:
            mod.run()
        except Exception:
            failed.append(mod.__name__)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
