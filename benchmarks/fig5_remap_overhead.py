"""Paper Fig. 5: execution-time split between elementwise computation and
dynamic tensor remapping. The paper reports 5-35% remap overhead; we time
``mode_step`` (EC + remap fused) vs. an EC-only jit on every dataset family.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import MTTKRPExecutor, init_factors
from repro.core.mttkrp import _ec_xla, compute_lrow

from .common import BENCH_DATASETS, RANK, emit, load_bench_tensor, time_fn


def _ec_only_fn(exe, mode):
    plan = exe.tensor.plans[mode]

    @jax.jit
    def f(layout, factors, rr):
        alive = layout["alpha"][:, mode] >= 0
        lrow = compute_lrow(layout["idx"][:, mode], rr, plan.rows_pp, alive)
        return _ec_xla({"val": layout["val"], "idx": layout["idx"],
                        "lrow": lrow, "bpart": layout.get("bpart")},
                       factors, mode, rows_pp=plan.rows_pp,
                       blocks_pp=plan.blocks_pp, block_p=plan.block_p,
                       kappa=plan.kappa, schedule=plan.schedule,
                       nblocks=plan.nblocks)

    return f


def run():
    rows = []
    for name in BENCH_DATASETS:
        t = load_bench_tensor(name)
        factors = tuple(init_factors(jax.random.PRNGKey(0), t.dims, RANK))
        exe = MTTKRPExecutor(t)
        # time full mode-0 step (EC + remap) vs EC only, same layout; the
        # compact schedule needs the mode-0 block->partition descriptor
        layout0 = {**exe.layout, "bpart": jnp.asarray(t.plans[0].block_part)}
        ec = _ec_only_fn(exe, 0)
        t_ec = time_fn(ec, layout0, factors, exe.row_relabel[0])

        def fused(layout):
            from repro.core.mttkrp import mode_step
            p = t.plans[0]
            out, nxt = mode_step(layout, factors, exe.row_relabel[0],
                                 mode=0, rows_pp=p.rows_pp,
                                 blocks_pp=p.blocks_pp, block_p=p.block_p,
                                 kappa=p.kappa,
                                 next_size=t.plans[1].padded_nnz,
                                 schedule=p.schedule, nblocks=p.nblocks)
            return out

        t_full = time_fn(fused, layout0)
        overhead = max(t_full - t_ec, 0.0) / max(t_full, 1e-12)
        rows.append((f"fig5_remap_overhead/{name}", t_full * 1e6,
                     f"remap_frac={overhead:.3f}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
